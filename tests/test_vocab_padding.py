"""Vocab padding: tables pad to /256, semantics unchanged."""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import lm as lm_lib
from repro.models.config import LayerKind, ModelConfig


def _odd_vocab_cfg():
    base = get_smoke_config("tinyllama-1.1b")
    return dataclasses.replace(base, vocab_size=251)  # prime, pads to 256


def test_padded_vocab_values():
    cfg = _odd_vocab_cfg()
    assert cfg.padded_vocab == 256
    even = get_smoke_config("tinyllama-1.1b")  # 256 already
    assert even.padded_vocab == even.vocab_size


def test_tables_padded_and_logits_masked():
    cfg = _odd_vocab_cfg()
    params = lm_lib.init_params(jax.random.key(0), cfg)
    assert params["embed"].shape == (256, cfg.d_model)
    tokens = lm_batch(cfg, 2, 16, seed=0)["tokens"]
    assert int(tokens.max()) < cfg.vocab_size
    logits, _ = lm_lib.prefill(params, tokens, cfg)
    assert logits.shape[-1] == 256
    # padded columns can never win an argmax
    assert jnp.all(logits[:, cfg.vocab_size:] <= -1e29)
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size


def test_loss_ignores_padding_columns():
    """The loss over a padded table equals the loss where padding rows
    are forced to -inf by construction: finite, and invariant to the
    padding weights' values."""
    cfg = _odd_vocab_cfg()
    params = lm_lib.init_params(jax.random.key(0), cfg)
    batch = lm_batch(cfg, 2, 16, seed=1)
    l1 = lm_lib.loss_fn(params, batch, cfg)
    # perturb ONLY the padding columns of the head/embed
    p2 = dict(params)
    p2["head"] = params["head"].at[:, cfg.vocab_size:].add(37.0)
    l2 = lm_lib.loss_fn(p2, batch, cfg)
    assert jnp.isfinite(l1)
    assert jnp.allclose(l1, l2), "padding columns leaked into the loss"
