"""Engine registry: every registered backend is bit-identical to
``reference`` — the paper's claim that the mappings "simply accelerate"
BNNs without touching accuracy, encoded as the registry's contract.

The ``packed`` backend runs its Pallas kernel in interpret mode on CPU
(automatic via ``interpret=None``), so this file is a meaningful gate on
any machine; the ``tpu``-marked case compiles the same kernel for real.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_lib
from repro.core import model
from repro.core.crossbar import CrossbarSpec

ENGINES = engine_lib.list_engines()

RAGGED_SHAPES = [
    (1, 32, 1),      # minimal
    (6, 20, 7),      # everything below one packed block
    (4, 100, 30),    # ragged m/n
    (130, 513, 129), # one past packed block boundaries
]


def _signs(rng, shape):
    return jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=shape))


def _as_int(x):
    return np.asarray(x).astype(np.int64)


class TestRegistry:
    def test_required_backends_registered(self):
        assert {"reference", "tacitmap", "wdm", "packed"} <= set(ENGINES)

    def test_unknown_engine_raises(self):
        with pytest.raises(ValueError, match="unknown engine"):
            engine_lib.get_engine("does-not-exist")

    def test_resolve_passthrough_and_name(self):
        eng = engine_lib.get_engine("packed")
        assert engine_lib.resolve(eng) is eng
        assert engine_lib.resolve("tacitmap").name == "tacitmap"

    def test_resolve_rebinds_spec(self):
        spec = CrossbarSpec(rows=64, cols=32)
        eng = engine_lib.resolve(engine_lib.get_engine("tacitmap"), spec)
        assert eng.spec is spec

    def test_resolve_equal_spec_keeps_instance(self):
        """Spec comparison is by equality: an equal-but-distinct
        CrossbarSpec must NOT rebuild the engine (a rebuild would bust
        its per-instance weight/placement caches)."""
        import dataclasses

        eng = engine_lib.get_engine("tacitmap")
        twin = dataclasses.replace(eng.spec)
        assert twin is not eng.spec and twin == eng.spec
        assert engine_lib.resolve(eng, twin) is eng

    def test_info_metadata(self):
        for name in ENGINES:
            info = engine_lib.engine_info(name)
            assert info.name == name
            assert info.bit_exact
            assert info.hardware
        assert engine_lib.engine_info("wdm").native_mmm
        assert engine_lib.engine_info("packed").packed

    def test_register_replaces_and_restores(self):
        sentinel = object()
        original = engine_lib._REGISTRY["reference"]
        try:
            engine_lib.register_engine("reference", lambda spec=None: sentinel)
            assert engine_lib.get_engine("reference") is sentinel
        finally:
            engine_lib.register_engine("reference", original)
        assert isinstance(engine_lib.get_engine("reference"), engine_lib.ReferenceEngine)


class TestBitExactness:
    @pytest.mark.parametrize("name", ENGINES)
    @pytest.mark.parametrize("b,m,n", RAGGED_SHAPES)
    def test_vmm_matches_reference(self, name, b, m, n):
        if name == "custbinarymap" and b * m * n > 2**21:
            pytest.skip("row-serial sim materializes (b, n, m); keep it small")
        rng = np.random.default_rng(b * 7 + m + n)
        a, w = _signs(rng, (b, m)), _signs(rng, (m, n))
        ref = _as_int(engine_lib.get_engine("reference").binary_vmm(a, w))
        got = _as_int(engine_lib.get_engine(name).binary_vmm(a, w))
        np.testing.assert_array_equal(got, ref)

    @pytest.mark.parametrize("name", ENGINES)
    def test_vmm_leading_batch_dims(self, name):
        rng = np.random.default_rng(11)
        a, w = _signs(rng, (2, 3, 40)), _signs(rng, (40, 9))
        ref = _as_int(engine_lib.get_engine("reference").binary_vmm(a, w))
        got = _as_int(engine_lib.get_engine(name).binary_vmm(a, w))
        assert got.shape == (2, 3, 9)
        np.testing.assert_array_equal(got, ref)

    @pytest.mark.parametrize("name", ENGINES)
    def test_mmm_matches_reference(self, name):
        rng = np.random.default_rng(5)
        groups, w = _signs(rng, (3, 4, 50)), _signs(rng, (50, 12))
        ref = _as_int(engine_lib.get_engine("reference").binary_mmm(groups, w))
        got = _as_int(engine_lib.get_engine(name).binary_mmm(groups, w))
        assert got.shape == (3, 4, 12)
        np.testing.assert_array_equal(got, ref)

    @pytest.mark.parametrize("name", ENGINES)
    @pytest.mark.parametrize("b,m,n", RAGGED_SHAPES)
    def test_vmm_prepared_matches_reference(self, name, b, m, n):
        """Two-phase path: ``prepare`` once, execute against the artifact
        — bit-identical to the raw-weights path (which delegates through
        ``prepare``, so this is the contract, not a coincidence)."""
        if name == "custbinarymap" and b * m * n > 2**21:
            pytest.skip("row-serial sim materializes (b, n, m); keep it small")
        rng = np.random.default_rng(b * 7 + m + n)
        a, w = _signs(rng, (b, m)), _signs(rng, (m, n))
        eng = engine_lib.get_engine(name)
        pw = eng.prepare(w)
        assert (pw.engine, pw.m, pw.n) == (name, m, n)
        ref = _as_int(engine_lib.get_engine("reference").binary_vmm(a, w))
        np.testing.assert_array_equal(_as_int(eng.binary_vmm(a, pw)), ref)

    @pytest.mark.parametrize("name", ENGINES)
    def test_mmm_prepared_matches_reference(self, name):
        rng = np.random.default_rng(5)
        groups, w = _signs(rng, (3, 4, 50)), _signs(rng, (50, 12))
        eng = engine_lib.get_engine(name)
        ref = _as_int(engine_lib.get_engine("reference").binary_mmm(groups, w))
        got = _as_int(eng.binary_mmm(groups, eng.prepare(w)))
        np.testing.assert_array_equal(got, ref)

    def test_packed_under_jit(self):
        # the serving path closes over the engine inside jit'd decode
        rng = np.random.default_rng(3)
        a, w = _signs(rng, (6, 33)), _signs(rng, (33, 5))
        eng = engine_lib.get_engine("packed")
        got = _as_int(jax.jit(eng.binary_vmm)(a, w))
        np.testing.assert_array_equal(got, _as_int(a @ w))


class TestModelParity:
    """Full forward passes agree across every backend (odd layer widths)."""

    def setup_method(self):
        self.cfg = model.MLPConfig(dims=(20, 32, 24, 5))
        self.params = model.init_mlp(jax.random.PRNGKey(0), self.cfg)
        self.x = jax.random.normal(jax.random.PRNGKey(1), (6, 20))

    @pytest.mark.parametrize("name", [n for n in ENGINES if n != "reference"])
    def test_mlp_forward_all_engines(self, name):
        ref = model.mlp_forward_infer(self.params, self.x, self.cfg, "reference")
        got = model.mlp_forward_infer(self.params, self.x, self.cfg, name)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)

    def test_mlp_accepts_engine_instance(self):
        eng = engine_lib.get_engine("packed")
        got = model.mlp_forward_infer(self.params, self.x, self.cfg, eng)
        ref = model.mlp_forward_infer(self.params, self.x, self.cfg, "reference")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


class TestTiledPolicies:
    """The plan-driven ``tiled`` backend is bit-exact for EVERY allocator
    policy — placement permutes tile order, never the math — including
    over-subscribed plans (tile budget < block count)."""

    POLICIES = ("tacitmap", "column-major", "greedy")

    def _operands(self, b=7, m=300, n=70):
        rng = np.random.default_rng(21)
        return _signs(rng, (b, m)), _signs(rng, (m, n))

    @pytest.mark.parametrize("policy", POLICIES)
    def test_policy_bit_exact_adhoc(self, policy):
        a, w = self._operands()
        ref = _as_int(engine_lib.get_engine("reference").binary_vmm(a, w))
        eng = engine_lib.get_engine("tiled", policy=policy)
        np.testing.assert_array_equal(_as_int(eng.binary_vmm(a, w)), ref)

    @pytest.mark.parametrize("policy", POLICIES)
    def test_policy_bit_exact_with_budgeted_plan(self, policy):
        from repro.mapping import adhoc_layer, allocate

        a, w = self._operands()
        m, n = w.shape
        plan = allocate(adhoc_layer(m, n), spec=CrossbarSpec(rows=128, cols=32),
                        policy=policy, tile_budget=3)
        eng = engine_lib.get_engine("tiled", plan=plan)
        ref = _as_int(engine_lib.get_engine("reference").binary_vmm(a, w))
        np.testing.assert_array_equal(_as_int(eng.binary_vmm(a, w)), ref)

    def test_grouped_adapter_composes(self):
        a, w = self._operands(b=5)
        grouped = engine_lib.GroupedEngine(engine_lib.get_engine("tiled"), 2)
        np.testing.assert_array_equal(_as_int(grouped.binary_vmm(a, w)), _as_int(a @ w))


class TestStepCounters:
    def test_steps_interface(self):
        m, n, b = 512, 256, 48
        assert engine_lib.get_engine("reference").steps_for(m, n, b) == b
        assert engine_lib.get_engine("tacitmap").steps_for(m, n, b) == b
        assert engine_lib.get_engine("custbinarymap").steps_for(m, n, b) == b * n
        wdm = engine_lib.get_engine("wdm")
        assert wdm.steps_for(m, n, b) == -(-b // wdm.spec.wdm_k)
        assert engine_lib.get_engine("packed").steps_for(m, n, b) == 1
        # tiled, dedicated tiles on the default ePCM spec (K=1): one
        # crossbar pass per input vector, like tacitmap
        assert engine_lib.get_engine("tiled").steps_for(m, n, b) == b

    def test_tiled_steps_with_oversubscribed_plan(self):
        from repro.core.crossbar import OPCM_TILE
        from repro.mapping import adhoc_layer, allocate

        m, n = 513, 129  # 5 blocks on 256x256 oPCM tiles
        plan = allocate(adhoc_layer(m, n), spec=OPCM_TILE, tile_budget=2)
        eng = engine_lib.get_engine("tiled", plan=plan)
        spv = plan.layers[0].steps_per_vector
        assert spv == 3  # ceil(5 blocks / 2 tiles)
        # K=16 wavelengths group the stream; co-residency serializes
        assert eng.steps_for(m, n, 48) == -(-48 // 16) * spv


class TestLMServingParity:
    """cfg.bnn_engine routes the binarized LM projections bit-exactly."""

    def _logits(self, engine_name):
        import dataclasses

        from repro.configs import get_smoke_config
        from repro.data import lm_batch
        from repro.models import lm as lm_lib

        cfg = dataclasses.replace(
            get_smoke_config("tinyllama-1.1b"), quant="bnn", bnn_engine=engine_name
        )
        params = lm_lib.init_params(jax.random.key(0), cfg)
        tokens = lm_batch(cfg, 2, 16, seed=7)["tokens"]
        logits, _ = lm_lib.prefill(params, tokens, cfg)
        return np.asarray(logits, np.float32)

    def test_prefill_packed_matches_reference(self):
        np.testing.assert_allclose(
            self._logits("packed"), self._logits("reference"), atol=1e-5, rtol=1e-5
        )

    def test_continuous_batching_packed_matches_reference(self):
        import dataclasses

        from repro import compiler as compiler_lib
        from repro.configs import get_smoke_config
        from repro.models import lm as lm_lib
        from repro.serving.engine import Request

        cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
        params = lm_lib.init_params(jax.random.key(0), cfg)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab_size, (8,), dtype=np.int32) for _ in range(3)]

        def gen(engine_name):
            se = compiler_lib.compile(
                cfg, params, compiler_lib.HardwareTarget(engine=engine_name)
            ).serve(max_batch=2, max_len=32)
            for i, p in enumerate(prompts):
                se.submit(Request(rid=i, prompt=p, max_new_tokens=4))
            return {r.rid: r.generated for r in se.run_to_completion()}

        assert gen("packed") == gen("reference")


_ROOT = Path(__file__).resolve().parent.parent


def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(_ROOT / "src")] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    return env


@pytest.mark.slow
def test_serve_cli_engine_smoke():
    """`launch/serve.py --engine packed --smoke` runs end-to-end."""
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", "tinyllama-1.1b", "--smoke", "--engine", "packed",
            "--batch", "1", "--prompt-len", "8", "--gen", "2",
        ],
        capture_output=True, text=True, timeout=600, cwd=_ROOT, env=_subprocess_env(),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "engine=packed" in proc.stdout


@pytest.mark.slow
def test_benchmarks_run_help_smoke():
    """`benchmarks/run.py --help` stays wired (CI gate for the driver —
    the workflow also runs `--sections engines --smoke` as its own step,
    so benchmark code can't silently rot)."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--help"],
        capture_output=True, text=True, timeout=120, cwd=_ROOT, env=_subprocess_env(),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "--sections" in proc.stdout


@pytest.mark.tpu
def test_packed_compiled_on_tpu():
    """Same kernel, compiled (not interpret) — only runs on a TPU host."""
    rng = np.random.default_rng(0)
    a, w = _signs(rng, (128, 512)), _signs(rng, (512, 128))
    eng = engine_lib.PackedEngine(interpret=False)
    got = _as_int(eng.binary_vmm(a, w))
    np.testing.assert_array_equal(got, _as_int(a @ w))
