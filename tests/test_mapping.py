"""Mapping compiler subsystem: IR extraction, tile allocation under every
policy, schedule/cost round-trip, and the plan-consuming integrations
(tiled engine, serving BatchPlanner, costmodel pricing).

The acceptance contract: a MappingPlan for qwen1.5-0.5b round-trips
allocate -> schedule -> costmodel pricing, and placement is a *complete
partition* of every binarized matrix — each weight block placed exactly
once, under any policy, with the math untouched (bit-exactness lives in
tests/test_engines.py; here we check the artifact itself).
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import costmodel
from repro.core import engine as engine_lib
from repro.core.crossbar import EPCM_TILE, OPCM_TILE, CrossbarSpec
from repro.core.networks import NETWORKS
from repro.mapping import (
    POLICIES,
    allocate,
    adhoc_layer,
    balance_ratio,
    compile_plan,
    from_model_config,
    from_network_desc,
    report,
    required_tiles,
    schedule_plan,
    to_ir,
)

QWEN = get_config("qwen1.5-0.5b")


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------


class TestIR:
    def test_model_config_extracts_binarized_projections(self):
        ir = from_model_config(QWEN)
        names = {l.name for l in ir.layers}
        assert names == {
            "slot0.attn.q", "slot0.attn.k", "slot0.attn.v", "slot0.attn.o",
            "slot0.ffn.w1", "slot0.ffn.w3", "slot0.ffn.w2",
        }
        q = ir.layer("slot0.attn.q")
        assert (q.m, q.n, q.count) == (QWEN.d_model, QWEN.n_heads * QWEN.hd, QWEN.n_repeats)
        w2 = ir.layer("slot0.ffn.w2")
        assert (w2.m, w2.n) == (QWEN.d_ff, QWEN.d_model)

    def test_network_desc_ir_keeps_edge_layers(self):
        net = NETWORKS["CNN-S"]
        ir = from_network_desc(net)
        assert len(ir.layers) == len(net.layers)
        assert sum(l.binary for l in ir.layers) == sum(l.binary for l in net.layers)
        # edge layers survive in the IR but are not placed (checked below)
        assert not ir.layer("conv1").binary

    def test_to_ir_dispatch_and_errors(self):
        assert to_ir(QWEN).source == "model_config"
        assert to_ir(NETWORKS["MLP-S"]).source == "network_desc"
        ir = adhoc_layer(100, 30)
        assert to_ir(ir) is ir
        with pytest.raises(TypeError):
            to_ir(42)

    def test_network_desc_round_trip_macs(self):
        net = NETWORKS["CNN-M"]
        assert from_network_desc(net).to_network_desc().macs == net.macs


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


class TestAllocator:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_placement_is_complete_partition(self, policy):
        """Every (row_block, col_block) of every instance appears exactly
        once; geometry covers the full complement-stacked matrix."""
        plan = allocate(QWEN, spec=OPCM_TILE, policy=policy)
        for lp in plan.layers:
            grid = lp.grid
            seen = lp.block_order()
            assert len(seen) == len(set(seen)) == grid.row_tiles * grid.col_tiles
            rows = sum(b.rows_used for b in lp.blocks if b.col_block == 0)
            cols = sum(b.cols_used for b in lp.blocks if b.row_block == 0)
            assert rows == 2 * lp.ir.m  # complement-row layout
            assert cols == lp.ir.n

    def test_counts_expand_to_instances(self):
        plan = allocate(QWEN, spec=OPCM_TILE)
        assert len(plan.layers) == 7 * QWEN.n_repeats
        assert len(plan.instances("slot0.ffn.w1")) == QWEN.n_repeats

    def test_dedicated_tiles_no_budget(self):
        plan = allocate(QWEN, spec=OPCM_TILE)
        assert plan.n_tiles == plan.n_blocks == required_tiles(QWEN, OPCM_TILE)
        assert all(lp.steps_per_vector == 1 for lp in plan.layers)
        assert plan.utilization() <= 1.0

    @pytest.mark.parametrize("policy", POLICIES)
    def test_budget_caps_pool_and_serializes(self, policy):
        plan = allocate(QWEN, spec=EPCM_TILE, policy=policy, tile_budget=64)
        assert plan.n_tiles == 64
        assert max(b.tile for lp in plan.layers for b in lp.blocks) < 64
        # 9408 blocks on 64 tiles MUST co-schedule same-layer blocks
        assert max(lp.steps_per_vector for lp in plan.layers) > 1
        assert plan.utilization() > 1.0  # over-subscription is visible

    def test_greedy_balances_ragged_blocks(self):
        """On a workload with ragged blocks, LPT is no worse balanced
        than naive striping."""
        net = NETWORKS["CNN-M"]
        budget = 48
        striped = allocate(net, spec=EPCM_TILE, policy="tacitmap", tile_budget=budget)
        greedy = allocate(net, spec=EPCM_TILE, policy="greedy", tile_budget=budget)
        assert balance_ratio(greedy) <= balance_ratio(striped) + 1e-9

    def test_column_major_orders_blocks_by_column(self):
        plan = allocate(adhoc_layer(513, 300), spec=EPCM_TILE, policy="column-major")
        order = plan.layers[0].block_order()
        # all row blocks of col 0 come before any of col 1
        assert order[: plan.layers[0].grid.row_tiles] == tuple(
            (rb, 0) for rb in range(plan.layers[0].grid.row_tiles)
        )

    def test_edge_layers_not_placed(self):
        plan = allocate(NETWORKS["CNN-S"], spec=EPCM_TILE)
        placed = {lp.ir.name for lp in plan.layers}
        assert "conv1" not in placed and "fc3" not in placed
        assert "conv2" in placed

    def test_wdm_wavelengths_and_group_size(self):
        plan = allocate(QWEN, spec=OPCM_TILE)
        assert plan.preferred_group_size() == OPCM_TILE.wdm_k == 16
        assert plan.layers[0].wavelengths == tuple(range(16))
        assert allocate(QWEN, spec=EPCM_TILE).preferred_group_size() == 1

    def test_unknown_policy_and_bad_budget_raise(self):
        with pytest.raises(ValueError, match="unknown mapping policy"):
            allocate(QWEN, policy="fastest")
        with pytest.raises(ValueError, match="tile_budget"):
            allocate(QWEN, tile_budget=0)


# ---------------------------------------------------------------------------
# Schedule + cost round-trip (the acceptance criterion)
# ---------------------------------------------------------------------------


class TestScheduleAndPricing:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_qwen_round_trip_allocate_schedule_price(self, policy):
        plan = allocate(QWEN, spec=OPCM_TILE, policy=policy)
        sch = schedule_plan(plan)
        assert len(sch.layers) == len(plan.layers)
        for lp, ls in zip(plan.layers, sch.layers):
            assert ls.steps_per_vector == lp.steps_per_vector
            # every tile the plan placed appears in the phase ordering
            assert sorted(t for ph in ls.phases for t in ph) == sorted(
                b.tile for b in lp.blocks
            )
        cost = costmodel.price_plan(plan)
        assert cost.design == "EinsteinBarrier"  # oPCM + K=16 implies WDM
        assert cost.latency_s > 0 and cost.energy_j > 0
        assert cost.binary_steps == sch.total_steps

    def test_wdm_grouping_divides_steps(self):
        opcm = schedule_plan(allocate(QWEN, spec=OPCM_TILE))
        epcm = schedule_plan(allocate(QWEN, spec=EPCM_TILE))
        # same placement geometry; K=16 divides the batch-16 stream
        assert epcm.total_steps == 16 * opcm.total_steps

    def test_budget_serialization_shows_in_latency(self):
        free = costmodel.price_plan(allocate(QWEN, spec=OPCM_TILE))
        tight = costmodel.price_plan(
            allocate(QWEN, spec=OPCM_TILE, tile_budget=64)
        )
        assert tight.latency_s > free.latency_s
        # energy counts activations, which serialization reorders but
        # does not add
        assert tight.energy_j == pytest.approx(free.energy_j)

    def test_schedule_follows_costmodel_stream_convention(self):
        """Plan steps agree with costmodel.layer_steps on conv workloads
        (weight replication across spare tiles): plan numbers and the
        paper-figure numbers share one stream convention."""
        net = NETWORKS["CNN-M"]
        plan = allocate(net, spec=EPCM_TILE)
        sch = schedule_plan(plan)
        p = costmodel.params_for_spec(EPCM_TILE)
        for lp, ls in zip(plan.layers, sch.layers):
            expect = costmodel.layer_steps(p, lp.ir.to_layer_desc())
            assert ls.steps == expect * ls.steps_per_vector

    def test_resolve_group_size_honors_plan_and_tiled_engine(self):
        """One policy, one function: explicit > plan K > engine K > batch."""
        plan = allocate(adhoc_layer(64, 64), spec=OPCM_TILE)
        tiled = engine_lib.get_engine("tiled", plan=plan)
        # plan (or the plan-bound engine) contributes K=16
        assert engine_lib.resolve_group_size(None, None, 32, plan=plan) == 16
        assert engine_lib.resolve_group_size(tiled, None, 32) == 16
        # explicit wins; batch clamps; plain engines fall to the pool
        assert engine_lib.resolve_group_size(tiled, 4, 32, plan=plan) == 4
        assert engine_lib.resolve_group_size(tiled, None, 8, plan=plan) == 8
        assert engine_lib.resolve_group_size(engine_lib.get_engine("packed"), None, 32) == 32

    def test_price_plan_includes_edge_layers(self):
        net = NETWORKS["CNN-S"]
        cost = costmodel.price_plan(allocate(net, spec=EPCM_TILE))
        names = {r["layer"] for r in cost.layers}
        assert "conv1" in names and "conv2" in names

    def test_params_for_spec(self):
        assert costmodel.params_for_spec(EPCM_TILE).name == "TacitMap-ePCM"
        p = costmodel.params_for_spec(OPCM_TILE)
        assert p.name == "EinsteinBarrier" and p.use_wdm


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


class TestReport:
    def test_report_names_every_layer_or_elides(self):
        plan = allocate(NETWORKS["MLP-S"], spec=EPCM_TILE)
        text = report.format_plan(plan, schedule_plan(plan))
        for lp in plan.layers:
            assert lp.name in text
        assert "total:" in text

    def test_large_plan_elides(self):
        plan = allocate(QWEN, spec=OPCM_TILE)
        text = report.format_plan(plan, max_rows=10)
        assert "more layer instances" in text

    def test_summary_line(self):
        plan = allocate(QWEN, spec=OPCM_TILE, policy="greedy", tile_budget=128)
        s = report.summarize(plan)
        assert "policy=greedy" in s and "K=16" in s and "budget=128" in s

    def test_format_priced(self):
        cost = costmodel.price_plan(allocate(QWEN, spec=OPCM_TILE))
        text = report.format_priced(cost)
        assert "slot0.ffn.w1" in text and "EinsteinBarrier" in text


# ---------------------------------------------------------------------------
# Integrations: tiled engine binding, serving BatchPlanner, layers
# ---------------------------------------------------------------------------


class TestIntegrations:
    def test_tiled_engine_consumes_plan_placement(self):
        rng = np.random.default_rng(3)
        m, n = 300, 70
        a = rng.choice(np.array([-1.0, 1.0], np.float32), size=(5, m))
        w = rng.choice(np.array([-1.0, 1.0], np.float32), size=(m, n))
        ref = np.asarray(engine_lib.get_engine("reference").binary_vmm(a, w))
        for policy in POLICIES:
            plan = allocate(adhoc_layer(m, n), spec=OPCM_TILE,
                            policy=policy, tile_budget=3)
            eng = engine_lib.get_engine("tiled", plan=plan)
            np.testing.assert_array_equal(np.asarray(eng.binary_vmm(a, w)), ref)
            lp = plan.layers[0]
            assert eng.steps_for(m, n, 16) == lp.steps_per_vector  # K=16 -> 1 group
            assert eng.preferred_group_size() == 16

    def test_tiled_engine_rejects_mismatched_spec(self):
        plan = allocate(adhoc_layer(64, 64), spec=OPCM_TILE)
        with pytest.raises(ValueError, match="compiled for"):
            engine_lib.get_engine("tiled", spec=CrossbarSpec(rows=64, cols=64), plan=plan)

    def test_serving_planner_consults_plan_group_size(self):
        cfg = dataclasses.replace(get_smoke_config("qwen1.5-0.5b"), quant="bnn")
        plan = allocate(cfg, spec=OPCM_TILE)
        import jax

        from repro import compiler as compiler_lib
        from repro.models import lm as lm_lib

        params = lm_lib.init_params(jax.random.key(0), cfg)
        se = compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine="tiled"), plan=plan
        ).serve(max_batch=32, max_len=16)
        # plan's WDM capacity (16) beats the vmap'd-pool fallback (32)
        assert se.group_k == 16
        # explicit request still wins
        se2 = compiler_lib.compile(
            cfg, params,
            compiler_lib.HardwareTarget(engine="tiled", group_size=4),
            plan=plan,
        ).serve(max_batch=32, max_len=16)
        assert se2.group_k == 4

    def test_infer_engine_binds_plan_and_policy(self):
        from repro.models.layers import infer_engine

        cfg = dataclasses.replace(
            get_smoke_config("qwen1.5-0.5b"), quant="bnn",
            bnn_engine="tiled", mapping_policy="greedy",
        )
        plan = allocate(cfg, spec=EPCM_TILE)
        eng = infer_engine(cfg, plan=plan)
        assert eng.plan is plan
        eng2 = infer_engine(cfg)
        assert eng2.plan is None and eng2.policy == "greedy"

    def test_tiled_engine_exact_under_mesh_sharding_hints(self):
        """With an active activation_hints mesh, the tile axis carries a
        model-axis sharding constraint; execution stays bit-exact (on 1
        CPU device the constraint is a layout no-op — the lowering path
        is what this exercises)."""
        import jax
        import jax.numpy as jnp

        from repro.distributed.hints import activation_hints
        from jax.sharding import Mesh

        rng = np.random.default_rng(9)
        m, n = 513, 40  # 5 blocks -> tile axis length 5
        a = jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=(4, m)))
        w = jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=(m, n)))
        eng = engine_lib.get_engine("tiled")
        mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("model",))
        with activation_hints(mesh):
            got = jax.jit(eng.binary_vmm)(a, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(a @ w))

    def test_plan_survives_grouped_engine_spec_rebind(self):
        plan = allocate(adhoc_layer(64, 64), spec=OPCM_TILE)
        eng = engine_lib.get_engine("tiled", plan=plan)
        grouped = engine_lib.GroupedEngine(eng, 4)
        rebound = grouped.with_spec(OPCM_TILE)
        assert rebound.base.plan is plan  # same spec keeps the plan
        dropped = eng.with_spec(EPCM_TILE)
        assert dropped.plan is None  # different spec cannot reuse geometry
