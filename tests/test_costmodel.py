"""Cost model invariants + the paper's headline claims (Fig. 7 / Fig. 8).

The exact constants are calibrated (DESIGN.md §3), so the claims are
asserted as *bands* around the paper's reported numbers; structural laws
(TacitMap ≤ n× baseline, WDM ≤ K×, monotonicity) are asserted exactly.
"""

import dataclasses
import statistics

import pytest

from repro.core import costmodel as cm
from repro.core import einsteinbarrier as eb
from repro.core.networks import NETWORKS, LayerDesc


def all_ratios():
    out = {}
    for name, net in NETWORKS.items():
        r = cm.evaluate_all(net)
        b_lat = r["Baseline-ePCM"]["latency_s"]
        b_en = r["Baseline-ePCM"]["energy_j"]
        out[name] = {
            "tm": b_lat / r["TacitMap-ePCM"]["latency_s"],
            "eb": b_lat / r["EinsteinBarrier"]["latency_s"],
            "gpu": b_lat / r["Baseline-GPU"]["latency_s"],
            "e_tm": r["TacitMap-ePCM"]["energy_j"] / b_en,
            "e_eb": r["EinsteinBarrier"]["energy_j"] / b_en,
        }
    return out


RATIOS = all_ratios()


class TestPaperLatencyClaims:
    def test_tacitmap_improves_all_networks(self):
        # Fig. 7 obs. 1: both designs improve latency for every network
        for name, r in RATIOS.items():
            assert r["tm"] > 1, name
            assert r["eb"] > 1, name

    def test_tacitmap_average_band(self):
        # paper: ~78x average
        avg = statistics.mean(r["tm"] for r in RATIOS.values())
        assert 50 <= avg <= 110, avg

    def test_tacitmap_max_band(self):
        # paper: up to ~154x
        mx = max(r["tm"] for r in RATIOS.values())
        assert 100 <= mx <= 200, mx

    def test_einsteinbarrier_average_band(self):
        # paper: ~1205x average
        avg = statistics.mean(r["eb"] for r in RATIOS.values())
        assert 800 <= avg <= 1900, avg

    def test_einsteinbarrier_max_band(self):
        # paper: up to ~3113x
        mx = max(r["eb"] for r in RATIOS.values())
        assert 2000 <= mx <= 3600, mx

    def test_eb_over_tm_band(self):
        # paper: ~15x average, bounded by K * (t_e / t_o) = 20
        for name, r in RATIOS.items():
            ratio = r["eb"] / r["tm"]
            k_bound = cm.EINSTEINBARRIER.k * (
                cm.TACITMAP_EPCM.tile.t_vmm_ns / cm.EINSTEINBARRIER.tile.t_vmm_ns
            )
            assert ratio <= k_bound + 1e-9, name
            assert ratio >= 10, name

    def test_network_dependence(self):
        # Fig. 7 obs. 2: improvement varies network to network
        tms = [r["tm"] for r in RATIOS.values()]
        assert max(tms) / min(tms) > 5

    def test_gpu_not_always_worse_than_cim(self):
        # Fig. 7 obs. 4: baseline beats GPU on the small CNN, loses on MLP-L
        assert RATIOS["CNN-S"]["gpu"] < 1 / 2.5   # base >=2.5x faster than GPU
        assert RATIOS["MLP-L"]["gpu"] > 2         # GPU faster on MLP-L


class TestPaperEnergyClaims:
    def test_tacitmap_energy_worse_than_baseline(self):
        # Fig. 8 obs. 1: ~5.35x average increase (ADCs vs SAs)
        avg = statistics.mean(r["e_tm"] for r in RATIOS.values())
        assert 3.5 <= avg <= 7.5, avg
        assert all(r["e_tm"] > 1 for r in RATIOS.values())

    def test_einsteinbarrier_energy_better_than_baseline(self):
        # Fig. 8 obs. 2: ~1.56x average improvement => ratio ~0.64
        avg = statistics.mean(r["e_eb"] for r in RATIOS.values())
        assert 0.45 <= avg <= 0.85, avg

    def test_eb_within_60pct_envelope(self):
        # abstract: "maintaining the energy consumption within 60% of
        # the CIM baseline" — EB average stays within [0.4, 1.6]x
        avg = statistics.mean(r["e_eb"] for r in RATIOS.values())
        assert avg <= 1.6


class TestStructuralLaws:
    def test_tacitmap_layer_law(self):
        # per binary layer: baseline steps = n * tacitmap steps (Fig. 3)
        layer = LayerDesc("fc", m=512, n=777, positions=1, binary=True)
        sb = cm.layer_steps(cm.BASELINE_EPCM, layer)
        st_ = cm.layer_steps(cm.TACITMAP_EPCM, layer)
        assert sb == layer.n * st_

    def test_wdm_bound(self):
        # EB steps >= TM steps / K for every layer
        for net in NETWORKS.values():
            for layer in net.layers:
                st_ = cm.layer_steps(cm.TACITMAP_EPCM, layer)
                se = cm.layer_steps(cm.EINSTEINBARRIER, layer)
                assert se >= st_ / cm.EINSTEINBARRIER.k - 1e-9

    def test_latency_monotone_in_k(self):
        net = NETWORKS["CNN-M"]
        lats = []
        for k in (1, 2, 4, 8, 16):
            tile = dataclasses.replace(cm.OPCM_TILE, wdm_k=k)
            p = dataclasses.replace(cm.EINSTEINBARRIER, tile=tile)
            lats.append(cm.network_latency_s(p, net))
        assert all(a >= b for a, b in zip(lats, lats[1:]))

    def test_transmitter_power_eq3(self):
        # Eq. 3 literal evaluation
        p = cm.EINSTEINBARRIER
        k, m = p.k, p.tile.rows
        expected = p.p_laser_mw + 3 * k * m + (3 * k * m + 1) / k * 45
        assert cm.transmitter_power_mw(p) == pytest.approx(expected)

    def test_tia_power_eq2(self):
        assert cm.tia_power_mw(cm.EINSTEINBARRIER, 256) == pytest.approx(512.0)


class TestPlacement:
    def test_placement_capacity_and_utilization(self):
        for net in NETWORKS.values():
            pl = eb.place(net)
            assert pl.total_vcores > 0
            assert 0 < pl.utilization <= 1
            assert pl.nodes_needed >= 1

    def test_schedule_matches_costmodel(self):
        net = NETWORKS["MLP-S"]
        pl = eb.place(net)
        sched = eb.schedule_summary(pl, cm.EINSTEINBARRIER)
        total = sum(s["latency_ns"] for s in sched)
        assert total == pytest.approx(
            cm.network_latency_s(cm.EINSTEINBARRIER, net) * 1e9 * cm.EINSTEINBARRIER.batch
        )


class TestScheduledTick:
    """scheduled_decode_tick: tick pricing under partial admission."""

    @pytest.fixture(scope="class")
    def plan(self):
        from repro.configs import get_smoke_config
        from repro.mapping import compile_plan

        cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"),
                                  quant="bnn")
        return compile_plan(cfg, spec=cm.OPCM_TILE, policy="tacitmap")

    def test_zero_admitted_is_free_and_fully_idle(self, plan):
        t = cm.scheduled_decode_tick(plan, 0, 8)
        assert t.groups == 0
        assert t.latency_ns == 0.0
        assert t.energy_pj == 0.0
        assert t.idle_lane_fraction == 1.0
        assert t.tokens_per_s == 0.0

    def test_bounds_checked(self, plan):
        with pytest.raises(ValueError, match=r"n_admitted"):
            cm.scheduled_decode_tick(plan, 9, 8)
        with pytest.raises(ValueError, match=r"n_admitted"):
            cm.scheduled_decode_tick(plan, -1, 8)

    def test_matches_plan_tick_at_admitted_width(self, plan):
        # a tick only pays for the K-groups it actually issues
        for n in (1, 3, 8):
            t = cm.scheduled_decode_tick(plan, n, 8)
            base = cm.plan_decode_tick(plan, n)
            assert t.groups == base.groups
            assert t.latency_ns == pytest.approx(base.latency_ns)
            assert t.energy_pj == pytest.approx(base.energy_pj)

    def test_idle_fraction_is_dark_pool_share(self, plan):
        # 1 - n/pool even when one K-group spans the whole pool
        ticks = [cm.scheduled_decode_tick(plan, n, 8) for n in range(9)]
        for n, t in enumerate(ticks):
            assert t.idle_lane_fraction == pytest.approx(1.0 - n / 8)
        # throughput at the admitted width is monotone in admission
        tps = [t.tokens_per_s for t in ticks]
        assert all(a <= b + 1e-9 for a, b in zip(tps, tps[1:]))
