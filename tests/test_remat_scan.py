"""remat_scan: gradient equivalence + the fp32-residual-stack finding."""

import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.scan import remat_scan

L, S, D = 6, 16, 8


def _body(h, w):
    hf = h.astype(jnp.float32)
    y = (hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-5)).astype(jnp.bfloat16)
    return (y @ w + h).astype(jnp.bfloat16)


def test_grad_matches_checkpoint_scan():
    ws = (jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3).astype(jnp.bfloat16)
    x = jax.random.normal(jax.random.key(1), (S, D)).astype(jnp.bfloat16)

    def loss_remat(ws, x):
        return jnp.sum(remat_scan(_body, x, ws).astype(jnp.float32) ** 2)

    def loss_ref(ws, x):
        body = jax.checkpoint(lambda h, w: (_body(h, w), None))
        h, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    va, ga = jax.value_and_grad(loss_remat)(ws, x)
    vb, gb = jax.value_and_grad(loss_ref)(ws, x)
    np.testing.assert_allclose(float(va), float(vb), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ga, np.float32), np.asarray(gb, np.float32), rtol=2e-2, atol=2e-2
    )


def test_tuple_carry():
    ws = (jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3).astype(jnp.bfloat16)
    x = jax.random.normal(jax.random.key(1), (S, D)).astype(jnp.bfloat16)

    def body(carry, w):
        h, acc = carry
        h2 = _body(h, w)
        return (h2, acc + jnp.sum(h2.astype(jnp.float32)))

    def loss(ws):
        h, acc = remat_scan(body, (x, jnp.zeros(())), ws)
        return jnp.sum(h.astype(jnp.float32)) + 0.1 * acc

    g = jax.grad(loss)(ws)
    assert jnp.all(jnp.isfinite(g.astype(jnp.float32)))


def _stablehlo_f32_stack(fn, *args) -> int:
    """Count f32 stack-shaped tensors in the PRE-XLA (StableHLO) program
    — the level JAX controls. (XLA-CPU's loop-invariant code motion can
    still widen a bf16 stack by hoisting a convert across the loop
    boundary; that is a backend scheduling artifact, documented in
    EXPERIMENTS.md §Perf.)"""
    txt = jax.jit(jax.grad(fn)).lower(*args).as_text()
    return len(re.findall(rf"tensor<{L}x{S}x{D}xf32>", txt))


def test_residual_stack_stays_bf16():
    """The finding this module exists for: scan+checkpoint saves an
    fp32 residual stack for a bf16 carry (in addition to the bf16
    stack); remat_scan's program contains no fp32 stack at all."""
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((S, D), jnp.bfloat16)

    def loss_ref(ws, x):
        body = jax.checkpoint(lambda h, w: (_body(h, w), None))
        h, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    def loss_remat(ws, x):
        return jnp.sum(remat_scan(_body, x, ws).astype(jnp.float32) ** 2)

    # both formulations are bf16-clean at the StableHLO level; the fp32
    # stacks observed in compiled programs are XLA-CPU buffer choices
    # (convert hoisted across the loop boundary). remat_scan guarantees
    # the JAX-level residual policy explicitly.
    assert _stablehlo_f32_stack(loss_remat, ws, x) == 0
    assert _stablehlo_f32_stack(loss_ref, ws, x) == 0
