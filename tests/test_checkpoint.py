"""Checkpoint manager: atomicity, async, retention, resharding restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "ck"), t, {"step": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, extra = restore_tree(str(tmp_path / "ck"), like)
    assert extra["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, got)


def test_incomplete_checkpoint_rejected(tmp_path):
    d = tmp_path / "ck"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    with pytest.raises(FileNotFoundError):
        restore_tree(str(d), _tree())


def test_manager_resume_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20, 30):
        mgr.save(s, t, {"step": s})
    assert mgr.latest_step() == 30
    assert mgr.steps() == [20, 30]  # keep=2 retention
    _, extra = mgr.restore(t)
    assert extra["step"] == 30


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(1)
    mgr.save_async(5, t)
    mgr.wait()
    got, extra = mgr.restore(t)
    assert extra["step"] == 5
    np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(t["a"]))


def test_restore_with_sharding(tmp_path):
    """Elastic restore: device_put onto an explicit sharding."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(2)
    mgr.save(1, t)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    got, _ = mgr.restore(t, shardings=sharding)
    assert got["a"].sharding == sharding


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_crash_mid_write_leaves_previous_intact(tmp_path):
    """A stale .tmp dir (simulated crash) must not shadow the good one."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    os.makedirs(str(tmp_path / "step_2.tmp-999"))  # crashed writer remnant
    assert mgr.latest_step() == 1
    got, extra = mgr.restore(t)
    assert extra["step"] == 1
