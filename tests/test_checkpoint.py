"""Checkpoint manager: atomicity, async, retention, resharding restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    CorruptCheckpointError,
    restore_tree,
    save_tree,
)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "ck"), t, {"step": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, extra = restore_tree(str(tmp_path / "ck"), like)
    assert extra["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, got)


def test_incomplete_checkpoint_rejected(tmp_path):
    d = tmp_path / "ck"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    with pytest.raises(FileNotFoundError):
        restore_tree(str(d), _tree())


def test_manager_resume_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20, 30):
        mgr.save(s, t, {"step": s})
    assert mgr.latest_step() == 30
    assert mgr.steps() == [20, 30]  # keep=2 retention
    _, extra = mgr.restore(t)
    assert extra["step"] == 30


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(1)
    mgr.save_async(5, t)
    mgr.wait()
    got, extra = mgr.restore(t)
    assert extra["step"] == 5
    np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(t["a"]))


def test_restore_with_sharding(tmp_path):
    """Elastic restore: device_put onto an explicit sharding."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(2)
    mgr.save(1, t)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    got, _ = mgr.restore(t, shardings=sharding)
    assert got["a"].sharding == sharding


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_crash_mid_write_leaves_previous_intact(tmp_path):
    """A stale .tmp dir (simulated crash) must not shadow the good one."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    os.makedirs(str(tmp_path / "step_2.tmp-999"))  # crashed writer remnant
    assert mgr.latest_step() == 1
    got, extra = mgr.restore(t)
    assert extra["step"] == 1


def test_overwrite_is_atomic_and_updates(tmp_path):
    """Re-saving the same step swaps snapshots without a window where
    the path names a partial dir; a stale .old aside (crashed swap) is
    tolerated, never listed as a step."""
    path = str(tmp_path / "ck")
    save_tree(path, _tree(0), {"v": 1})
    os.makedirs(f"{path}.old-{os.getpid()}")  # stale aside from a crash
    save_tree(path, _tree(3), {"v": 2})
    like = _tree(3)
    got, extra = restore_tree(path, like)
    assert extra["v"] == 2
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(like["a"]))
    assert not os.path.exists(f"{path}.old-{os.getpid()}")
    # a manager-level overwrite: the aside dir never shows up in steps()
    mgr = CheckpointManager(str(tmp_path / "mgr"))
    mgr.save(1, _tree(0))
    mgr.save(1, _tree(1))
    assert mgr.steps() == [1]


def test_corrupt_manifest_named(tmp_path):
    """Marker present but manifest mangled: CorruptCheckpointError names
    the path (distinct from FileNotFoundError = no checkpoint)."""
    d = tmp_path / "ck"
    save_tree(str(d), _tree())
    (d / "manifest.json").write_text("{not json")
    with pytest.raises(CorruptCheckpointError, match="manifest"):
        restore_tree(str(d), _tree())


def test_corrupt_arrays_named(tmp_path):
    d = tmp_path / "ck"
    save_tree(str(d), _tree())
    (d / "arrays.npz").write_bytes(b"\x00" * 16)  # truncated/garbled payload
    with pytest.raises(CorruptCheckpointError, match="arrays.npz"):
        restore_tree(str(d), _tree())
