"""int8 error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    compress_grads,
    decompress_grads,
    ef_init,
)


def test_quantize_roundtrip_bounds():
    g = {"w": jnp.linspace(-3.0, 3.0, 64).reshape(8, 8)}
    ef = ef_init(g)
    comp, ef2 = compress_grads(g, ef)
    back = decompress_grads(comp)
    # error bounded by scale/2 per element
    scale = float(comp["w"]["scale"])
    assert float(jnp.abs(back["w"] - g["w"]).max()) <= scale * 0.5 + 1e-7
    # error feedback holds the residual
    np.testing.assert_allclose(
        np.asarray(ef2["w"]), np.asarray(g["w"] - back["w"]), atol=1e-6
    )


def test_error_feedback_accumulates_to_unbiased():
    """Constant gradient: sum of decompressed updates -> sum of true
    gradients (the EF property that preserves convergence)."""
    g = {"w": jnp.array([1e-4, 0.5, -0.3, 1.0])}  # tiny value quantizes to 0 alone
    ef = ef_init(g)
    total = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        comp, ef = compress_grads(g, ef)
        total = total + decompress_grads(comp)["w"]
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]), atol=1e-4)


def test_compressed_bytes_are_int8():
    g = {"w": jnp.ones((128, 128))}
    comp, _ = compress_grads(g, ef_init(g))
    assert comp["w"]["q"].dtype == jnp.int8  # 4x smaller than fp32 on the wire
