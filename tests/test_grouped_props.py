"""Property tests (tests/proptest.py harness) for the K-group batching
adapter: ``GroupedEngine(base, k)`` is bit-exact against ``reference``
for ANY (batch, m, n, k) — ragged groups (k does not divide batch),
single-row batches, degenerate m=1 vectors, k larger than the batch —
across every registered backend.

The adapter pads ragged tails with +1 signs (idle comb lines) and
discards pad outputs; these properties are what make K-grouping
semantically invisible to the serving engine for any pool composition.
"""

import numpy as np
import proptest as pt
import pytest

from repro.core import engine as engine_lib

# every registered backend must compose with the adapter; the row-serial
# simulator materializes (b, n, m) so the drawn shapes stay small
ENGINES = engine_lib.list_engines()


def _signs(rng: np.random.Generator, shape) -> np.ndarray:
    return rng.choice(np.array([-1.0, 1.0], np.float32), size=shape)


def _check_grouped(name: str, a: np.ndarray, w: np.ndarray, k: int) -> None:
    grouped = engine_lib.GroupedEngine(engine_lib.get_engine(name), k)
    ref = (a @ w).astype(np.int64)
    got = np.asarray(grouped.binary_vmm(a, w)).astype(np.int64)
    np.testing.assert_array_equal(got, ref)


@pt.given(
    b=pt.integers(1, 9),
    m=pt.integers(1, 70),
    n=pt.integers(1, 40),
    k=pt.integers(1, 12),
)
def test_grouped_vmm_any_shape_bit_exact(b, m, n, k):
    """k ∤ b, k > b, b = 1, m = 1 — all drawn; every backend must be
    exact on every draw (the engine loop lives inside the property so
    one counterexample reports the failing backend + draw together)."""
    rng = np.random.default_rng(b * 1009 + m * 31 + n * 7 + k)
    a, w = _signs(rng, (b, m)), _signs(rng, (m, n))
    for name in ENGINES:
        _check_grouped(name, a, w, k)


@pytest.mark.parametrize("name", ENGINES)
@pytest.mark.parametrize(
    "b,m,n,k",
    [
        (1, 33, 5, 4),   # single row, k > batch
        (5, 1, 7, 2),    # m=1: degenerate vectors, ragged tail
        (7, 20, 3, 3),   # k ∤ b
        (4, 16, 1, 8),   # single output column, k > batch
        (3, 1, 1, 2),    # everything degenerate at once
    ],
)
def test_grouped_vmm_edge_shapes(name, b, m, n, k):
    rng = np.random.default_rng(77)
    _check_grouped(name, _signs(rng, (b, m)), _signs(rng, (m, n)), k)


@pt.given(
    g=pt.integers(1, 4),
    k=pt.integers(1, 6),
    m=pt.integers(1, 50),
    n=pt.integers(1, 30),
    name=pt.sampled_from(ENGINES),
)
def test_grouped_mmm_passthrough_bit_exact(g, k, m, n, name):
    """binary_mmm on pre-stacked (G, K, m) groups matches reference."""
    rng = np.random.default_rng(g * 131 + k * 17 + m * 3 + n)
    groups = _signs(rng, (g, k, m))
    w = _signs(rng, (m, n))
    grouped = engine_lib.GroupedEngine(engine_lib.get_engine(name), k)
    ref = (groups @ w).astype(np.int64)
    got = np.asarray(grouped.binary_mmm(groups, w)).astype(np.int64)
    np.testing.assert_array_equal(got, ref)


@pt.given(b=pt.integers(1, 6), m=pt.integers(1, 40), k=pt.integers(1, 8))
def test_grouped_leading_batch_dims(b, m, k):
    """(2, b, m) leading dims flatten and unflatten exactly."""
    rng = np.random.default_rng(b * 13 + m + k)
    a = _signs(rng, (2, b, m))
    w = _signs(rng, (m, 9))
    grouped = engine_lib.GroupedEngine(engine_lib.get_engine("reference"), k)
    got = np.asarray(grouped.binary_vmm(a, w)).astype(np.int64)
    np.testing.assert_array_equal(got, (a @ w).astype(np.int64))


def test_grouped_rejects_bad_k():
    with pytest.raises(ValueError, match="group size"):
        engine_lib.GroupedEngine(engine_lib.get_engine("reference"), 0)
