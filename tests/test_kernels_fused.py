"""Fused decode-tick kernel (PR 6) vs the unfused BitLinear chain.

The contract under test: ``ops.fused_bnn_matmul`` — binarize + bit-pack
+ XNOR + popcount + Eq. 1 affine + α/β rescale in ONE ``pallas_call`` —
is bit-exact against the ``models.layers.dense`` reference math for any
operand shape (ragged m, B=1, stacked G·K group leading dims), through
the engine surface (``fused_dense``, GroupedEngine pass-through, the
``prepad`` programming layout), the shared-activation QKV fusion, and
the donated-cache decode step. The unfused path stays selectable
(``fused=False``) as the benchmark baseline.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import bnn
from repro.core import engine as engine_lib
from repro.kernels import ops
from repro.kernels.fused_decode import fused_bnn_matmul_kernel
from repro.models import layers, lm as lm_lib

import proptest as pt


def _reference(x, w, alpha):
    """layers.dense BNN math, no kernels: the bit-exactness oracle."""
    beta = jnp.mean(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True)
    xb = bnn.binarize_ste(x.astype(jnp.float32))
    dot = jnp.einsum("...k,kn->...n", xb, bnn.binarize_ste(w))
    return dot.astype(jnp.float32) * (alpha * beta)


def _operands(lead, m, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(*lead, m)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    alpha = jnp.asarray(rng.uniform(0.5, 2.0, size=(n,)), jnp.float32)
    return x, w, alpha


class TestFusedBnnMatmul:
    @pt.given(b=pt.integers(1, 16), m=pt.integers(1, 300), n=pt.integers(1, 64))
    def test_property_sweep_bit_exact(self, b, m, n):
        """Ragged everything incl. non-multiple-of-32 m and B=1."""
        x, w, alpha = _operands((b,), m, n, b + m * 13 + n)
        wp = ops.pack_weights(bnn.binarize_ste(w))
        got = ops.fused_bnn_matmul(x, wp, alpha, m=m, n=n, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(_reference(x, w, alpha))
        )

    @pytest.mark.parametrize(
        "lead",
        [(1,), (4, 2), (2, 3, 5)],  # B=1 / (G, K) / (G, K, b) stacks
    )
    def test_grouped_leading_dims_one_launch(self, lead):
        """The serving engine's stacked (G, K, m) groups flatten into
        one launch and match the per-row reference exactly."""
        x, w, alpha = _operands(lead, 100, 48, sum(lead))
        wp = ops.pack_weights(bnn.binarize_ste(w))
        got = ops.fused_bnn_matmul(x, wp, alpha, m=100, n=48, interpret=True)
        assert got.shape == (*lead, 48)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(_reference(x, w, alpha))
        )

    def test_scalar_alpha(self):
        x, w, _ = _operands((3,), 70, 20, 7)
        wp = ops.pack_weights(bnn.binarize_ste(w))
        alpha = jnp.float32(0.37)
        got = ops.fused_bnn_matmul(x, wp, alpha, m=70, n=20, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(_reference(x, w, alpha))
        )

    def test_zero_activations_binarize_to_plus_one(self):
        """binarize_ste maps 0 -> +1; the in-kernel ``x >= 0`` must
        agree (and beta = 0 zeroes the row either way only via scale)."""
        x = jnp.zeros((2, 64), jnp.bfloat16).at[0, :5].set(1.0)
        _, w, alpha = _operands((2,), 64, 24, 11)
        wp = ops.pack_weights(bnn.binarize_ste(w))
        got = ops.fused_bnn_matmul(x, wp, alpha, m=64, n=24, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(_reference(x, w, alpha))
        )

    def test_blocked_grid_path_bit_exact(self):
        """Force the compiled-style multi-block grid (interpret=True but
        explicit small blocks) — same results as the single-step grid."""
        x, w, alpha = _operands((9,), 520, 130, 21)
        wp = ops.pack_weights(bnn.binarize_ste(w))
        ref = _reference(x, w, alpha)
        beta = jnp.mean(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True)
        x2 = jnp.pad(
            x.astype(jnp.float32), [(0, 7), (0, 17 * 32 - 520)],
            constant_values=-1.0,
        )
        got = fused_bnn_matmul_kernel(
            jnp.pad(x2, [(0, 0), (0, 32)], constant_values=-1.0)[:16, :18 * 32],
            ops.pad_packed_weights(wp, bkw=6, bn=64)[:18],
            jnp.pad(alpha.reshape(1, -1), [(0, 0), (0, 62)]),
            jnp.pad(beta, [(0, 7), (0, 0)]),
            m=520, bm=8, bn=64, bkw=6, interpret=True,
        )
        np.testing.assert_array_equal(
            np.asarray(got[:9, :130]), np.asarray(ref)
        )

    def test_word_count_mismatch_named_error(self):
        x = jnp.zeros((8, 64), jnp.float32)
        w = jnp.zeros((4, 128), jnp.int32)
        alpha = jnp.zeros((1, 128), jnp.float32)
        beta = jnp.zeros((8, 1), jnp.float32)
        with pytest.raises(ValueError, match="words"):
            fused_bnn_matmul_kernel(
                x, w, alpha, beta, m=64, bm=8, bn=128, bkw=4, interpret=True
            )

    def test_block_divisibility_named_error(self):
        x = jnp.zeros((8, 128), jnp.float32)
        w = jnp.zeros((4, 100), jnp.int32)
        alpha = jnp.zeros((1, 100), jnp.float32)
        beta = jnp.zeros((8, 1), jnp.float32)
        with pytest.raises(ValueError, match="pre-padded to block multiples"):
            fused_bnn_matmul_kernel(
                x, w, alpha, beta, m=128, bm=8, bn=64, bkw=4, interpret=True
            )

    def test_short_weights_named_error(self):
        x = jnp.zeros((2, 128), jnp.bfloat16)
        wp = jnp.zeros((2, 16), jnp.int32)  # 2 words < ceil(128/32)
        with pytest.raises(ValueError, match="carry 2 words"):
            ops.fused_bnn_matmul(x, wp, 1.0, m=128, n=16, interpret=True)


class TestPrepadLayout:
    @pytest.mark.parametrize("m,n", [(64, 96), (100, 40), (512, 768)])
    def test_prepad_round_trip_bit_identical(self, m, n):
        """prepad=True programs block-aligned words; fused AND unfused
        execution match the unpadded artifact exactly."""
        x, w, alpha = _operands((5,), m, n, m + n)
        ws = bnn.binarize_ste(w)
        xb = bnn.binarize_ste(x.astype(jnp.float32))
        outs = {}
        for prepad in (False, True):
            eng = engine_lib.PackedEngine(interpret=True, prepad=prepad)
            pw = eng.prepare(ws)
            outs[prepad] = (
                np.asarray(eng.fused_dense(x, pw, alpha)),
                np.asarray(eng.binary_vmm(xb, pw)),
            )
        np.testing.assert_array_equal(outs[False][0], outs[True][0])
        np.testing.assert_array_equal(outs[False][1], outs[True][1])

    def test_prepad_emits_block_aligned_words(self):
        eng = engine_lib.PackedEngine(interpret=True, prepad=True)
        pw = eng.prepare(bnn.binarize_ste(jnp.ones((100, 40))))
        kw, n = pw.data.shape
        assert kw % 16 == 0 and n % 128 == 0
        assert (pw.m, pw.n) == (100, 40)  # logical dims preserved

    def test_with_spec_preserves_flags(self):
        from repro.core.crossbar import CrossbarSpec

        eng = engine_lib.PackedEngine(interpret=True, fused=False, prepad=True)
        clone = eng.with_spec(CrossbarSpec(rows=64, cols=64))
        assert clone.fused is False and clone.prepad is True


class TestEngineSurface:
    def test_unfused_flag_disables_capability(self):
        assert engine_lib.PackedEngine(fused=True).supports_fused_dense
        assert not engine_lib.PackedEngine(fused=False).supports_fused_dense

    def test_grouped_engine_delegates(self):
        base = engine_lib.PackedEngine(interpret=True)
        grouped = engine_lib.GroupedEngine(base, 2)
        assert grouped.supports_fused_dense == base.supports_fused_dense
        x, w, alpha = _operands((4,), 96, 32, 5)
        pw = base.prepare(bnn.binarize_ste(w))
        np.testing.assert_array_equal(
            np.asarray(grouped.fused_dense(x, pw, alpha)),
            np.asarray(base.fused_dense(x, pw, alpha)),
        )

    def test_non_fused_engines_lack_capability(self):
        for name in engine_lib.list_engines():
            eng = engine_lib.get_engine(name)
            if not isinstance(eng, engine_lib.PackedEngine):
                assert not getattr(eng, "supports_fused_dense", False)


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


class TestQkvFusion:
    def _programmed_attn(self, cfg, params, engine):
        programmed, _ = lm_lib.program_weights(params, cfg, engine)
        attn = programmed["blocks"]["slot0"]["attn"]
        # slice repeat 0 off every stacked artifact, as the layer scan does
        return jax.tree.map(lambda a: a[0], attn)

    def test_artifact_attached_for_fused_engines_only(self, model):
        cfg, params = model
        fused_attn = self._programmed_attn(
            cfg, params, engine_lib.PackedEngine(interpret=True)
        )
        assert "qkv" in fused_attn
        unfused_attn = self._programmed_attn(
            cfg, params, engine_lib.PackedEngine(interpret=True, fused=False)
        )
        assert "qkv" not in unfused_attn

    def test_concat_split_matches_three_dense_calls(self, model):
        """One launch over [q|k|v] splits bit-identically to three
        separate projections (packing is column-independent)."""
        cfg, params = model
        eng = engine_lib.PackedEngine(interpret=True)
        attn = self._programmed_attn(cfg, params, eng)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(2, 3, cfg.d_model)), jnp.bfloat16)
        fused = layers.fused_qkv_dense(attn, x, cfg, "bnn", eng)
        assert fused is not None
        unfused_eng = engine_lib.PackedEngine(interpret=True, fused=False)
        for got, name in zip(fused, ("q", "k", "v")):
            want = layers.dense(attn[name], x, "bnn", engine=unfused_eng)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_returns_none_without_capability(self, model):
        cfg, params = model
        eng = engine_lib.PackedEngine(interpret=True)
        attn = self._programmed_attn(cfg, params, eng)
        unfused = engine_lib.PackedEngine(interpret=True, fused=False)
        assert layers.fused_qkv_dense(attn, jnp.zeros((1, cfg.d_model)),
                                      cfg, "bnn", unfused) is None
        assert layers.fused_qkv_dense(attn, jnp.zeros((1, cfg.d_model)),
                                      cfg, "none", eng) is None


class TestTargetAndDonation:
    def test_fused_false_requires_packed(self):
        from repro.compiler import HardwareTarget
        from repro.compiler.target import TargetError

        HardwareTarget(engine="packed", fused=False).validate()  # baseline knob
        with pytest.raises(TargetError, match="fused=False"):
            HardwareTarget(engine="wdm", fused=False).validate()

    def test_describe_reports_fused_knob(self):
        from repro.compiler import HardwareTarget

        assert "fused=False" in HardwareTarget(
            engine="packed", fused=False
        ).describe()

    def test_decode_step_donates_cache_buffers(self, model):
        """The KV-cache pytree is donated: tick N's caches update in
        place of tick N-1's buffers instead of doubling resident size."""
        from repro import compiler as compiler_lib
        from repro.compiler import HardwareTarget

        cfg, params = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="packed"))
        tokens = jnp.asarray(np.arange(1, 6, dtype=np.int32))[None, :]
        logits, pre = cm.prefill(tokens)
        caches = cm.init_cache(1, 12)

        def graft(dst, src):
            if dst.ndim == 5 and dst.shape[2] >= src.shape[2]:
                return dst.at[:, :, : src.shape[2]].set(src.astype(dst.dtype))
            return src.astype(dst.dtype)

        caches = jax.tree.map(graft, caches, pre)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        old_leaf = jax.tree.leaves(caches)[0]
        _, caches = cm.decode_step(tok, jnp.asarray(5, jnp.int32), caches)
        assert old_leaf.is_deleted()
        # and the decode loop still runs on the donated-output caches
        _, caches = cm.decode_step(tok, jnp.asarray(6, jnp.int32), caches)
