"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs
one forward/train step on CPU, asserting output shapes and finiteness;
representative families also check prefill->decode consistency against
the full forward pass (the serving path must agree with training).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import lm_batch
from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib
from repro.models.config import SHAPES, shape_applicable

B, S = 2, 32


def _init(cfg, seed=0):
    init = encdec_lib.init_params if cfg.is_encdec else lm_lib.init_params
    return init(jax.random.key(seed), cfg)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = _init(cfg)
    batch = lm_batch(cfg, B, S, seed=1)
    loss_fn = encdec_lib.loss_fn if cfg.is_encdec else lm_lib.loss_fn
    loss, grads = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, batch, cfg)))(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # a reasonable loss for random init: around ln(vocab)
    assert 0.0 < float(loss) < 3 * jnp.log(cfg.vocab_size)
    leaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(l)) for l in leaves), f"{arch}: non-finite grads"
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    params = _init(cfg)
    batch = lm_batch(cfg, B, S, seed=2)
    if cfg.is_encdec:
        enc = encdec_lib.encode(params, batch["src_embeds"], cfg)
        assert enc.shape == (B, cfg.frontend_len, cfg.d_model)
        hid = encdec_lib.decoder(params, enc, batch["tokens"], cfg)
        assert hid.shape == (B, S, cfg.d_model)
        assert jnp.all(jnp.isfinite(hid.astype(jnp.float32)))
    else:
        embeds = lm_lib.embed_tokens(params, batch["tokens"])
        if "extra_embeds" in batch:
            embeds = jnp.concatenate(
                [batch["extra_embeds"].astype(embeds.dtype), embeds], axis=1
            )
        hid, aux = lm_lib.backbone(params, embeds, jnp.arange(embeds.shape[1]), cfg)
        assert hid.shape == (B, embeds.shape[1], cfg.d_model)
        assert jnp.all(jnp.isfinite(hid.astype(jnp.float32)))


@pytest.mark.parametrize(
    "arch",
    ["tinyllama-1.1b", "mamba2-2.7b", "jamba-1.5-large-398b", "qwen3-moe-235b-a22b"],
)
def test_prefill_decode_matches_forward(arch):
    """prefill(t[:n]) + decode steps == full forward logits (teacher forcing).

    MoE capacity is raised so no tokens drop: the full forward drops
    over-capacity tokens while a single decode token never does — a
    policy difference, not a math bug (drops are covered in test_moe).
    """
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config(arch), moe_capacity_factor=16.0)
    params = _init(cfg)
    tokens = lm_batch(cfg, B, S, seed=3)["tokens"]
    n = S - 2

    logits_p, pre = lm_lib.prefill(params, tokens[:, :n], cfg)
    caches = lm_lib.init_cache(cfg, B, S)

    def graft(dst, src):
        if dst.ndim == 5 and dst.shape[2] >= src.shape[2]:
            return dst.at[:, :, : src.shape[2]].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    caches = jax.tree.map(graft, caches, pre)
    logits_d1, caches = lm_lib.decode_step(params, tokens[:, n], jnp.asarray(n), caches, cfg)
    logits_d2, _ = lm_lib.decode_step(params, tokens[:, n + 1], jnp.asarray(n + 1), caches, cfg)

    # reference: full-sequence prefill gives the last-position logits.
    # tolerance: bf16 accumulation-order differences between the chunked
    # SSD/flash paths and the stepwise decode path reach ~4e-2 even on
    # dense stacks (tinyllama), PLUS top-k routing flips near gate ties
    # on MoE archs (inherent to MoE serving) reach ~5e-2 on the deepest
    # hybrid stack (jamba: mamba+attn+moe).
    tol = 8e-2 if cfg.moe_experts else 5e-2
    ref_last, _ = lm_lib.prefill(params, tokens, cfg)
    assert jnp.allclose(logits_d2, ref_last, atol=tol, rtol=tol), (
        f"{arch}: decode path diverges from full forward "
        f"(max diff {float(jnp.abs(logits_d2 - ref_last).max()):.4f})"
    )
    # token-level agreement must hold regardless
    assert jnp.mean((jnp.argmax(logits_d2, -1) == jnp.argmax(ref_last, -1))) >= 0.5


def test_encdec_decode_matches_prefill():
    cfg = get_smoke_config("seamless-m4t-large-v2")
    params = _init(cfg)
    batch = lm_batch(cfg, B, S, seed=4)
    tokens, src = batch["tokens"], batch["src_embeds"]
    n = S - 1
    _, pre = encdec_lib.prefill(params, src, tokens[:, :n], cfg)
    caches = encdec_lib.init_cache(cfg, B, S, cfg.frontend_len)
    caches = dict(
        caches,
        cross_k=pre["cross_k"],
        cross_v=pre["cross_v"],
        self_k=caches["self_k"].at[:, :, :n].set(pre["self_k"]),
        self_v=caches["self_v"].at[:, :, :n].set(pre["self_v"]),
    )
    logits_d, _ = encdec_lib.decode_step(params, tokens[:, n], jnp.asarray(n), caches, cfg)
    ref, _ = encdec_lib.prefill(params, src, tokens, cfg)
    # bf16 probability path in the chunked attention (prefill) vs fp32
    # decode attention: accumulation-order gap ~3e-2 through 2 stacks
    assert jnp.allclose(logits_d, ref, atol=5e-2, rtol=5e-2), (
        f"max diff {float(jnp.abs(logits_d - ref).max()):.4f}"
    )


def test_all_40_cells_well_defined():
    """Every (arch x shape) cell resolves to run-or-documented-skip."""
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    skips = [(a, s.name) for a, s, runs, _ in cells if not runs]
    # exactly the 8 pure full-attention archs skip long_500k
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    sub_quadratic = {"jamba-1.5-large-398b", "mamba2-2.7b"}
    assert sub_quadratic.isdisjoint({a for a, _ in skips})


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_numbers(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected
