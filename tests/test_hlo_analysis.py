"""Trip-count-aware HLO analyzer: validated against unrolled ground truth."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def _flops_of(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return H.analyze_hlo_text(c.as_text())["flops_per_dev"]


def test_scan_flops_match_unrolled():
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 128), jnp.float32)

    def f_scan(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(ws, x):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ ws[i])
        return h

    fs = _flops_of(f_scan, w, x)
    fu = _flops_of(f_unroll, w, x)
    expected = 8 * 2 * 4 * 128 * 128
    assert fs == fu == expected, (fs, fu, expected)


def test_nested_scan_flops():
    w = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 64), jnp.float32)

    def f(ws, x):
        def outer(h, w_outer):
            def inner(h2, w2):
                return h2 @ w2, None
            return jax.lax.scan(inner, h, w_outer)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    flops = _flops_of(f, w, x)
    assert flops == 3 * 5 * 2 * 2 * 64 * 64


def test_dot_general_batched_flops():
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    flops = _flops_of(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    assert flops == 2 * 4 * 16 * 8 * 32


def test_bytes_exclude_sliced_stack_reads():
    """Reading one (128,128) slice per iteration of a (64,128,128) stack
    must NOT be charged as 64 full-stack reads."""
    w = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 128), jnp.float32)

    def f(ws, x):
        def body(h, w):
            return h @ w, None
        return jax.lax.scan(body, x, ws)[0]

    r = jax.jit(f).lower(w, x).compile()
    acc = H.analyze_hlo_text(r.as_text())
    stack_bytes = 64 * 128 * 128 * 4
    # one full pass over the stacked weights (~4 MiB) plus small h
    # traffic; the old operand-sum accounting charged ~64 passes.
    assert acc["bytes_per_dev"] < 3 * stack_bytes, acc["bytes_per_dev"]
    assert acc["bytes_per_dev"] > 0.9 * stack_bytes


def test_collective_parsing_from_synthetic_text():
    hlo = """
HloModule test

ENTRY %main.1 (p0.1: f32[16,128]) -> f32[16,128] {
  %p0.1 = f32[16,128]{1,0} parameter(0)
  %all-gather.1 = f32[64,128]{1,0} all-gather(%p0.1), replica_groups=[4]<=[4], dimensions={0}
  %slice.1 = f32[16,128]{1,0} slice(%all-gather.1), slice={[0:16], [0:128]}
  ROOT %all-reduce.1 = f32[16,128]{1,0} all-reduce(%slice.1), replica_groups={}, to_apply=%add
}
"""
    acc = H.analyze_hlo_text(hlo)
    pk = acc["coll_per_kind"]
    assert pk["all-gather"]["count"] == 1
    assert pk["all-gather"]["operand_bytes"] == 16 * 128 * 4
    assert pk["all-reduce"]["operand_bytes"] == 16 * 128 * 4
    assert pk["all-reduce"]["wire_bytes"] == 2 * 16 * 128 * 4


def test_roofline_terms_dominance():
    t = H.roofline_terms(197e12, 100e9, 1e9)
    assert t["compute_s"] == 1.0
    assert t["dominant"] == "compute"
    t2 = H.roofline_terms(1e12, 819e9 * 2, 1e9)
    assert t2["dominant"] == "memory"
