"""The one-call hardware-compilation API (repro.compiler).

Three contracts:

* **Round trip** — ``compile(cfg, params, target)`` followed by
  ``prefill``/``decode_step``/``serve`` is bit-exact against the
  pre-redesign hand-wiring (engine lookup -> cfg flip -> K resolution ->
  GroupedEngine -> program_weights -> lm entry points) for every
  registered engine, with and without a compiled plan, prepared and raw.
* **Eager validation** — inconsistent targets raise NAMED errors at
  compile time (plan+engine mismatch, spec mismatch, K over plan
  capacity) instead of silently dropping knobs the way the old
  ``ServingEngine(mapping_plan=..., engine="wdm")`` did.
* **One front door** — ``ServingEngine`` accepts ONLY a
  ``CompiledModel``; the removed legacy multi-knob signature raises a
  named ``LegacyServingSignatureError`` pointing at ``compile()``.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro.compiler import (
    CompiledModel,
    GroupSizeError,
    HardwareTarget,
    PlanEngineMismatchError,
    SpecMismatchError,
    TargetError,
    add_target_args,
    target_from_args,
)
from repro.configs import get_smoke_config
from repro.core import engine as engine_lib
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE
from repro.mapping import compile_plan
from repro.models import lm as lm_lib
from repro.serving import (
    LegacyServingSignatureError,
    Request,
    ServingEngine,
    ServingStats,
)

ENGINES = tuple(engine_lib.list_engines())


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (5,), np.int32) for _ in range(2)]
    return cfg, params, prompts


# ---------------------------------------------------------------------------
# the PRE-redesign wiring, inlined — the ground truth compile() replaces
# ---------------------------------------------------------------------------


def _legacy_tokens(cfg, params, prompts, *, engine, group_size=None, plan=None,
                   prepared=True, n_steps=3):
    """Prefill + greedy decode via the old five-knob recipe."""
    base = None
    if engine != "reference":
        kw = {}
        if engine == "tiled":
            kw = {"plan": plan, "policy": cfg.mapping_policy or "tacitmap"}
        base = engine_lib.get_engine(engine, **kw)
        cfg = dataclasses.replace(cfg, quant="bnn", bnn_engine=engine)
    batch = len(prompts)
    k = engine_lib.resolve_group_size(base, group_size, batch, plan=plan)
    ex = engine_lib.GroupedEngine(base, k) if base is not None else None
    if ex is not None and prepared:
        params, _ = lm_lib.program_weights(params, cfg, ex)
    tokens = jnp.stack([jnp.asarray(p) for p in prompts])
    prompt_len = tokens.shape[1]
    logits, pre = jax.jit(
        lambda p, t: lm_lib.prefill(p, t, cfg, engine=ex)
    )(params, tokens)
    caches = lm_lib.init_cache(cfg, batch, prompt_len + n_steps + 2)

    def graft(dst, src):
        if dst.ndim == 5 and dst.shape[2] >= src.shape[2]:
            return dst.at[:, :, : src.shape[2]].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    caches = jax.tree.map(graft, caches, pre)
    decode = jax.jit(
        lambda p, t, pos, c: lm_lib.decode_step(p, t, pos, c, cfg, engine=ex)
    )
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(n_steps):
        logits, caches = decode(params, tok, jnp.asarray(prompt_len + i, jnp.int32), caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return [t.tolist() for t in out]


def _compiled_tokens(cfg, params, prompts, target, *, plan=None, n_steps=3):
    """The same loop through the one-call artifact."""
    cm = compiler_lib.compile(cfg, params, target, plan=plan)
    tokens = jnp.stack([jnp.asarray(p) for p in prompts])
    prompt_len = tokens.shape[1]
    logits, pre = cm.prefill(tokens)
    caches = cm.init_cache(len(prompts), prompt_len + n_steps + 2)

    def graft(dst, src):
        if dst.ndim == 5 and dst.shape[2] >= src.shape[2]:
            return dst.at[:, :, : src.shape[2]].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    caches = jax.tree.map(graft, caches, pre)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(n_steps):
        logits, caches = cm.decode_step(tok, jnp.asarray(prompt_len + i, jnp.int32), caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return [t.tolist() for t in out]


def _serve_gens(se, prompts, n_new=3):
    for i, p in enumerate(prompts):
        se.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    return {r.rid: tuple(r.generated) for r in se.run_to_completion()}


# ---------------------------------------------------------------------------
# Round trip: compile -> prefill/decode/serve == legacy wiring
# ---------------------------------------------------------------------------


class TestRoundTrip:
    @pytest.mark.parametrize("name", ENGINES)
    @pytest.mark.parametrize("prepared", [True, False])
    def test_direct_drive_matches_legacy(self, name, prepared, model):
        cfg, params, prompts = model
        legacy = _legacy_tokens(cfg, params, prompts, engine=name,
                                prepared=prepared, n_steps=2)
        got = _compiled_tokens(
            cfg, params, prompts,
            HardwareTarget(engine=name, prepare_weights=prepared),
            n_steps=2,
        )
        assert got == legacy

    def test_plan_bound_tiled_matches_legacy(self, model):
        cfg, params, prompts = model
        plan = compile_plan(cfg, spec=OPCM_TILE, policy="greedy")
        legacy = _legacy_tokens(cfg, params, prompts, engine="tiled",
                                plan=plan, n_steps=2)
        got = _compiled_tokens(
            cfg, params, prompts, HardwareTarget(engine="tiled"),
            plan=plan, n_steps=2,
        )
        assert got == legacy

    def test_compiled_policy_plan_matches_reference(self, model):
        """compile() compiling its own plan from the target's policy is
        still semantically invisible."""
        cfg, params, prompts = model
        ref = _compiled_tokens(cfg, params, prompts, HardwareTarget(), n_steps=2)
        for policy in ("tacitmap", "column-major", "greedy"):
            got = _compiled_tokens(
                cfg, params, prompts,
                HardwareTarget(engine="tiled", mapping_policy=policy),
                n_steps=2,
            )
            assert got == ref, policy

    @pytest.mark.parametrize("name", [n for n in ENGINES if n != "reference"])
    def test_serve_matches_reference_target(self, name, model):
        cfg, params, prompts = model
        got = _serve_gens(
            compiler_lib.compile(cfg, params, HardwareTarget(engine=name))
            .serve(max_batch=2, max_len=24),
            prompts,
        )
        ref = _serve_gens(
            compiler_lib.compile(cfg, params, HardwareTarget())
            .serve(max_batch=2, max_len=24),
            prompts,
        )
        assert got == ref

    def test_compile_programs_once(self, model):
        cfg, params, prompts = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm"))
        assert cm.programmed == cfg.n_repeats * 7  # q/k/v/o + w1/w3/w2
        assert cm.program_s > 0
        # the artifact replaced the latent weights on the compiled params
        proj = cm.params["blocks"]["slot0"]["attn"]["q"]
        assert "w" not in proj and "prepared" in proj
        # raw target: nothing programmed
        raw = compiler_lib.compile(
            cfg, params, HardwareTarget(engine="wdm", prepare_weights=False)
        )
        assert raw.programmed == 0 and "w" in raw.params["blocks"]["slot0"]["attn"]["q"]

    def test_group_size_resolution_precedence(self, model):
        cfg, params, _ = model
        # explicit target K wins
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm", group_size=3))
        assert cm.group_size_for(8) == 3
        # plan WDM capacity next (oPCM plan K=16, clamped to the pool)
        plan = compile_plan(cfg, spec=OPCM_TILE, policy="greedy")
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="tiled"), plan=plan)
        assert cm.group_size_for(32) == 16
        # engine capability next (wdm wavelength count)
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm"))
        assert cm.group_size_for(32) == engine_lib.get_engine("wdm").spec.wdm_k
        # plain path: one vmap'd group spanning the pool
        cm = compiler_lib.compile(cfg, params, HardwareTarget())
        assert cm.group_size_for(8) == 8 and cm.executor(8) is None


# ---------------------------------------------------------------------------
# Eager validation: named errors, no silently-dropped knobs
# ---------------------------------------------------------------------------


class TestValidation:
    def test_unknown_engine(self, model):
        cfg, params, _ = model
        with pytest.raises(TargetError, match="unknown engine"):
            compiler_lib.compile(cfg, params, HardwareTarget(engine="nope"))

    def test_unknown_policy(self, model):
        cfg, params, _ = model
        with pytest.raises(TargetError, match="unknown mapping policy"):
            compiler_lib.compile(
                cfg, params,
                HardwareTarget(engine="tiled", mapping_policy="alphabetical"),
            )

    def test_policy_on_non_tiled_engine(self, model):
        cfg, params, _ = model
        with pytest.raises(PlanEngineMismatchError, match="tiled"):
            compiler_lib.compile(
                cfg, params, HardwareTarget(engine="wdm", mapping_policy="greedy")
            )

    def test_budget_on_non_tiled_engine(self, model):
        cfg, params, _ = model
        with pytest.raises(PlanEngineMismatchError):
            compiler_lib.compile(
                cfg, params, HardwareTarget(engine="packed", tile_budget=8)
            )

    def test_plan_on_non_tiled_engine(self, model):
        """The old ServingEngine accepted mapping_plan= with engine="wdm"
        and silently used it only for K — now a named error."""
        cfg, params, _ = model
        plan = compile_plan(cfg, policy="greedy")
        with pytest.raises(PlanEngineMismatchError, match="silently"):
            compiler_lib.compile(
                cfg, params, HardwareTarget(engine="wdm"), plan=plan
            )

    def test_plan_spec_mismatch(self, model):
        cfg, params, _ = model
        plan = compile_plan(cfg, spec=OPCM_TILE, policy="greedy")
        with pytest.raises(SpecMismatchError, match="recompile"):
            compiler_lib.compile(
                cfg, params,
                HardwareTarget(engine="tiled", spec=EPCM_TILE),
                plan=plan,
            )

    def test_plan_policy_conflict(self, model):
        """A bound plan already fixed the allocator choices; a target
        naming different ones is a silent knob drop — named error."""
        cfg, params, _ = model
        plan = compile_plan(cfg, policy="tacitmap")
        with pytest.raises(TargetError, match="compiled under"):
            compiler_lib.compile(
                cfg, params,
                HardwareTarget(engine="tiled", mapping_policy="greedy"),
                plan=plan,
            )
        # the matching spelling stays valid
        cm = compiler_lib.compile(
            cfg, params,
            HardwareTarget(engine="tiled", mapping_policy="tacitmap"),
            plan=plan,
        )
        assert cm.plan is plan

    def test_mesh_axis_on_non_tiled_engine(self):
        """Only the tiled engine's tile axis consumes the hint today; a
        target naming it elsewhere must not silently drop it."""
        with pytest.raises(TargetError, match="mesh_axis"):
            HardwareTarget(engine="wdm", mesh_axis="model").validate()

    def test_mesh_axis_threads_to_tiled_engine(self, model):
        cfg, params, _ = model
        cm = compiler_lib.compile(
            cfg, params,
            HardwareTarget(engine="tiled", mapping_policy="greedy",
                           mesh_axis="x"),
        )
        assert cm.engine.mesh_axis == "x"

    def test_adhoc_fallback_policy_follows_bound_plan(self, model):
        """With plan= and no explicit target policy, the engine's ad-hoc
        fallback placements and the pinned cfg.mapping_policy must both
        follow the PLAN's policy (not the pre-compile config's)."""
        cfg, params, _ = model
        plan = compile_plan(cfg, policy="greedy")
        cm = compiler_lib.compile(
            cfg, params, HardwareTarget(engine="tiled"), plan=plan
        )
        assert cm.engine.policy == "greedy"
        assert cm.cfg.mapping_policy == "greedy"

    def test_plan_budget_conflict(self, model):
        cfg, params, _ = model
        plan = compile_plan(cfg, policy="greedy", tile_budget=4)
        with pytest.raises(TargetError, match="tile_budget"):
            compiler_lib.compile(
                cfg, params,
                HardwareTarget(engine="tiled", tile_budget=8),
                plan=plan,
            )

    def test_group_size_over_plan_capacity(self, model):
        cfg, params, _ = model
        plan = compile_plan(cfg, spec=OPCM_TILE, policy="greedy")
        assert plan.preferred_group_size() == 16
        with pytest.raises(GroupSizeError, match="WDM capacity"):
            compiler_lib.compile(
                cfg, params,
                HardwareTarget(engine="tiled", group_size=64),
                plan=plan,
            )

    def test_group_size_over_wdm_capacity(self, model):
        cfg, params, _ = model
        with pytest.raises(GroupSizeError, match="wavelengths"):
            compiler_lib.compile(
                cfg, params, HardwareTarget(engine="wdm", group_size=999)
            )

    def test_degenerate_knobs(self):
        with pytest.raises(GroupSizeError):
            HardwareTarget(engine="wdm", group_size=-1).validate()
        with pytest.raises(TargetError, match="tile_budget"):
            HardwareTarget(engine="tiled", tile_budget=0).validate()
        # 0 is the CLI's auto convention, normalized to None
        assert HardwareTarget(group_size=0).group_size is None

    def test_encdec_rejected(self):
        cfg = get_smoke_config("seamless-m4t-large-v2")
        with pytest.raises(TargetError, match="decoder-only"):
            compiler_lib.compile(cfg, None, HardwareTarget(engine="wdm"))


# ---------------------------------------------------------------------------
# Price-only compilation + reports
# ---------------------------------------------------------------------------


class TestPricing:
    def test_price_only_compile(self, model):
        cfg, _, _ = model
        cm = compiler_lib.compile(
            cfg, None, HardwareTarget(engine="tiled", mapping_policy="greedy")
        )
        price = cm.price()
        assert price.n_tiles == cm.plan.n_tiles
        assert price.latency_s > 0 and price.energy_j > 0
        assert price.programming_uj > 0 and price.tick_energy_pj > 0
        assert price.break_even_ticks > 0
        assert "us/inf" in price.summary()
        # execution without params is a named error, not a crash
        with pytest.raises(TargetError, match="without params"):
            cm.serve(max_batch=2, max_len=16)
        with pytest.raises(TargetError, match="without params"):
            cm.prefill(jnp.zeros((1, 4), jnp.int32))

    def test_reference_target_prices_the_mapping(self, model):
        """Pricing is static: a plain-jnp target still prices the
        paper's mapping of the binarized stack (lazily compiled)."""
        cfg, _, _ = model
        cm = compiler_lib.compile(cfg, None, HardwareTarget())
        assert cm.plan is None
        assert cm.price().n_tiles > 0

    def test_describe_names_the_pipeline(self, model):
        cfg, params, _ = model
        cm = compiler_lib.compile(
            cfg, params, HardwareTarget(engine="tiled", mapping_policy="greedy")
        )
        text = cm.describe()
        assert "policy=greedy" in text
        assert "[mapping]" in text and "[price]" in text
        assert "resident" in text  # the programming phase is reported

    def test_wdm_k_divides_priced_latency(self, model):
        cfg, _, _ = model
        def lat(k):
            spec = dataclasses.replace(OPCM_TILE, wdm_k=k)
            cm = compiler_lib.compile(
                cfg, None,
                HardwareTarget(engine="tiled", spec=spec, mapping_policy="tacitmap"),
            )
            return cm.price().latency_s
        assert lat(16) < lat(4) <= lat(1)


# ---------------------------------------------------------------------------
# ServingEngine: CompiledModel is the ONLY front door (PR 5 shim removed)
# ---------------------------------------------------------------------------


class TestServingFrontDoor:
    def test_legacy_signature_raises_named_error(self, model):
        """The PR 5 deprecation shim is gone: every legacy spelling gets
        one named error that points at compile()."""
        cfg, params, _ = model
        for kwargs in (
            {},                                        # (cfg, params) positional
            {"engine": "wdm"},
            {"engine": "wdm", "group_size": 2},
            {"mapping_plan": object()},
            {"prepare_weights": False},
        ):
            with pytest.raises(LegacyServingSignatureError, match="compile"):
                ServingEngine(cfg, params, max_batch=2, max_len=16, **kwargs)

    def test_legacy_error_is_a_type_error(self, model):
        # old call sites catching TypeError keep working
        cfg, params, _ = model
        with pytest.raises(TypeError):
            ServingEngine(cfg, params)

    def test_compiled_plus_legacy_kwargs_rejected(self, model):
        cfg, params, _ = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm"))
        with pytest.raises(LegacyServingSignatureError):
            ServingEngine(cm, params, max_batch=2)
        with pytest.raises(LegacyServingSignatureError, match="engine"):
            ServingEngine(cm, engine="wdm", max_batch=2)

    def test_serving_engine_exposes_compiled(self, model):
        cfg, params, _ = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm"))
        se = ServingEngine(cm, max_batch=2, max_len=16)
        assert se.compiled is cm
        stats = se.stats()
        assert isinstance(stats, ServingStats)
        assert stats.programmed == cm.programmed
        assert se.cfg.bnn_engine == "wdm" and se.cfg.quant == "bnn"

    def test_stats_snapshot_is_frozen(self, model):
        cfg, params, prompts = model
        se = compiler_lib.compile(
            cfg, params, HardwareTarget(engine="wdm")
        ).serve(max_batch=2, max_len=24)
        before = se.stats()
        _serve_gens(se, prompts)
        after = se.stats()
        # snapshots are immutable and independent
        assert before.ticks == 0 and after.ticks > 0
        with pytest.raises(dataclasses.FrozenInstanceError):
            after.ticks = 0
        assert after.scheduler.finished == len(prompts)


# ---------------------------------------------------------------------------
# Shared CLI surface
# ---------------------------------------------------------------------------


class TestCLI:
    def _parse(self, argv):
        ap = argparse.ArgumentParser()
        add_target_args(ap)
        return ap.parse_args(argv)

    def test_round_trip(self):
        t = target_from_args(self._parse([
            "--engine", "tiled", "--mapping-policy", "greedy",
            "--tile-budget", "64", "--group-size", "4", "--raw-weights",
        ]))
        assert t == HardwareTarget(
            engine="tiled", mapping_policy="greedy", tile_budget=64,
            group_size=4, prepare_weights=False,
        )

    def test_defaults_are_the_reference_target(self):
        t = target_from_args(self._parse([]))
        assert t == HardwareTarget()
        assert t.group_size is None and t.prepare_weights

    def test_typoed_engine_fails_at_argparse_time(self, capsys):
        with pytest.raises(SystemExit):
            self._parse(["--engine", "packedd"])
        assert "invalid choice" in capsys.readouterr().err

    def test_inconsistent_combo_fails_validation(self):
        with pytest.raises(PlanEngineMismatchError):
            target_from_args(self._parse(["--engine", "wdm",
                                          "--mapping-policy", "greedy"]))

    def test_mesh_axis_recorded(self):
        t = HardwareTarget(engine="tiled", mesh_axis="model")
        assert t.mesh_axis == "model" and "mesh_axis=model" in t.describe()
