"""End-to-end behaviour tests: the paper's full pipeline and the
framework's drivers, exercised through the public entry points."""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.core import costmodel as cm
from repro.core import model as bnn_model
from repro.core.crossbar import EPCM_TILE, OPCM_TILE
from repro.core.networks import NETWORKS
from repro.data import bnn_image_batch
from repro.optim import OptConfig, adamw_init, adamw_update

# multi-minute training loops + subprocess CLI drivers: nightly/full CI
# only (the tier1 job deselects `slow`)
pytestmark = pytest.mark.slow

ROOT = Path(__file__).parent.parent


def _train_mlp(steps=150, dims=(64, 48, 32, 10), hw=8):
    cfg = bnn_model.MLPConfig(dims=dims)
    params = bnn_model.init_mlp(jax.random.key(0), cfg)
    opt_cfg = OptConfig(weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = bnn_model.mlp_forward_train(p, x, cfg)
            return -jnp.mean(
                jnp.sum(jax.nn.one_hot(y, 10) * jax.nn.log_softmax(logits), axis=-1)
            )

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(grads, params, opt, 1e-3, opt_cfg)
        return params, opt, loss

    for i in range(steps):
        x, y = bnn_image_batch(64, shape=(hw, hw, 1), step=i)
        params, opt, loss = step(params, opt, x.reshape(64, -1), y)
    return cfg, params


class TestPaperPipeline:
    """Train BNN -> deploy through every engine -> accelerator model."""

    def test_bnn_trains_and_engines_agree(self):
        cfg, params = _train_mlp()
        x, y = bnn_image_batch(256, shape=(8, 8, 1), step=9999)
        x = x.reshape(256, -1)
        logits = {}
        for engine, spec in (
            ("reference", EPCM_TILE),
            ("tacitmap", EPCM_TILE),
            ("wdm", OPCM_TILE),
        ):
            logits[engine] = bnn_model.mlp_forward_infer(params, x, cfg, engine, spec)
        # the mappings are exact: identical logits, identical accuracy
        assert jnp.allclose(logits["reference"], logits["tacitmap"], atol=1e-4)
        assert jnp.allclose(logits["reference"], logits["wdm"], atol=1e-4)
        acc = float(jnp.mean(jnp.argmax(logits["tacitmap"], -1) == y))
        assert acc > 0.9, f"BNN failed to learn (acc {acc})"

    def test_cost_model_covers_all_networks(self):
        for name, net in NETWORKS.items():
            r = cm.evaluate_all(net)
            assert set(r) == {
                "Baseline-ePCM", "TacitMap-ePCM", "EinsteinBarrier", "Baseline-GPU"
            }
            for v in r.values():
                assert v["latency_s"] > 0 and v["energy_j"] > 0


class TestDrivers:
    """The CLI drivers run end to end (subprocess: clean jax state)."""

    def _run(self, args, timeout=420):
        env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
        # keep the platform pin: CPU containers with libtpu baked in hang
        # for minutes probing the TPU plugin if JAX_PLATFORMS is dropped
        if "JAX_PLATFORMS" in os.environ:
            env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
        out = subprocess.run(
            [sys.executable, "-m", *args],
            capture_output=True,
            text=True,
            cwd=ROOT,
            env=env,
            timeout=timeout,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        return out.stdout

    def test_train_driver_smoke(self, tmp_path):
        out = self._run([
            "repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
            "--steps", "6", "--batch", "2", "--seq", "32",
            "--ckpt", str(tmp_path / "ck"), "--ckpt-every", "3",
        ])
        assert "final_step=5" in out

    def test_serve_driver_smoke(self):
        out = self._run([
            "repro.launch.serve", "--arch", "qwen1.5-0.5b", "--smoke",
            "--batch", "2", "--prompt-len", "8", "--gen", "4",
        ])
        assert "tok/s" in out

    def test_train_driver_bnn_quant(self, tmp_path):
        out = self._run([
            "repro.launch.train", "--arch", "llama3.2-3b", "--smoke",
            "--quant", "bnn", "--steps", "4", "--batch", "2", "--seq", "16",
            "--ckpt", str(tmp_path / "ck2"),
        ])
        assert "quant=bnn" in out
