"""Optimizer + schedule unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule, global_norm


def _quadratic_losses(cfg: OptConfig, steps=200, lr=0.05):
    target = jnp.array([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    state = adamw_init(params, cfg)
    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2)
        )(params)
        params, state = adamw_update(grads, params, state, lr, cfg)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    cfg = OptConfig(weight_decay=0.0)
    losses = _quadratic_losses(cfg)
    assert losses[-1] < 1e-3 * losses[0]


def test_factored_adamw_converges():
    cfg = OptConfig(weight_decay=0.0, factored=True, factored_min_size=1)
    losses = _quadratic_losses(cfg)
    assert losses[-1] < 1e-2 * losses[0]


def test_factored_state_is_smaller():
    cfg_d = OptConfig()
    cfg_f = OptConfig(factored=True, factored_min_size=1)
    params = {"w": jnp.zeros((64, 128))}
    dense = adamw_init(params, cfg_d)
    fact = adamw_init(params, cfg_f)
    n_dense = sum(l.size for l in jax.tree.leaves(dense["v"]))
    n_fact = sum(l.size for l in jax.tree.leaves(fact["v"]))
    assert n_fact == 64 + 128 and n_dense == 64 * 128


def test_grad_clipping_applies():
    cfg = OptConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    p1, _ = adamw_update(huge, params, state, 1.0, cfg)
    # clipped: first-step Adam update magnitude is ~lr regardless of grad
    assert float(jnp.abs(p1["w"]).max()) < 2.0


def test_no_decay_on_1d_params():
    cfg = OptConfig(weight_decay=0.5)
    params = {"scale": jnp.ones((8,)), "w": jnp.ones((8, 8))}
    state = adamw_init(params, cfg)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p1, _ = adamw_update(zeros, params, state, 0.1, cfg)
    assert jnp.allclose(p1["scale"], 1.0)          # norms untouched
    assert float(p1["w"][0, 0]) < 1.0              # matrices decayed


def test_global_norm():
    t = {"a": jnp.full((3,), 2.0), "b": jnp.full((4,), 1.0)}
    assert np.isclose(float(global_norm(t)), np.sqrt(12 + 4))


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(0, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr_peak = cosine_schedule(10, peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr_end = cosine_schedule(100, peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0
    assert np.isclose(float(lr_peak), 1.0)
    assert np.isclose(float(lr_end), 0.1, atol=1e-6)
    mid = cosine_schedule(55, peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert 0.1 < float(mid) < 1.0
