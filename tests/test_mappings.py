"""Mapping simulators: TacitMap (tiled crossbar) and CustBinaryMap are
bit-exact against the ±1 matmul reference, step counts follow the
paper's Fig. 3 law, and WDM grouping preserves results."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn, custbinarymap, tacitmap, wdm
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE, TileGrid

import proptest as pt


def _signs(rng, shape):
    return jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=shape))


SMALL_TILE = CrossbarSpec(rows=32, cols=16)  # force multi-tile paths


class TestTacitMapSimulator:
    @pt.given(m=pt.integers(1, 200), n=pt.integers(1, 50), b=pt.integers(1, 4))
    def test_bit_exact_vs_reference(self, m, n, b):
        rng = np.random.default_rng(m * 31 + n)
        a, w = _signs(rng, (b, m)), _signs(rng, (m, n))
        got = tacitmap.binary_matmul(a, w, SMALL_TILE)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(bnn.binary_matmul_signs(a, w)))

    def test_bit_exact_default_tile(self):
        rng = np.random.default_rng(0)
        a, w = _signs(rng, (5, 500)), _signs(rng, (500, 300))
        got = tacitmap.binary_matmul(a, w, EPCM_TILE)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(bnn.binary_matmul_signs(a, w)))

    def test_mapped_geometry(self):
        w_bits = jnp.ones((100, 40), jnp.int32)
        mapped = tacitmap.map_weights(w_bits, SMALL_TILE)
        # 2m=200 rows over 32-row tiles -> 7 row tiles; 40 cols / 16 -> 3
        assert mapped.grid.row_tiles == 7
        assert mapped.grid.col_tiles == 3
        assert mapped.tiles.shape == (7, 32, 3, 16)

    def test_one_step_per_input(self):
        assert tacitmap.steps_for(m=512, n=1000, n_inputs=7) == 7

    def test_noise_tolerance(self):
        # binary separation: small readout noise must not flip results
        rng = np.random.default_rng(3)
        a, w = _signs(rng, (4, 64)), _signs(rng, (64, 32))
        import jax

        got = tacitmap.binary_matmul(a, w, EPCM_TILE, noise_sigma=0.1, key=jax.random.PRNGKey(0))
        ref = bnn.binary_matmul_signs(a, w)
        # popcount noise of 0.1 LSB -> rounding to nearest integer recovers exact
        np.testing.assert_array_equal(np.round((np.asarray(got) + 64) / 2), (np.asarray(ref) + 64) / 2)


class TestCustBinaryMap:
    @pt.given(m=pt.integers(1, 150), n=pt.integers(1, 40))
    def test_bit_exact_vs_reference(self, m, n):
        rng = np.random.default_rng(m * 13 + n)
        a, w = _signs(rng, (3, m)), _signs(rng, (m, n))
        got = custbinarymap.binary_matmul(a, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(bnn.binary_matmul_signs(a, w)))

    def test_interleaving(self):
        w = jnp.array([1, 0, 1])
        inter = custbinarymap.interleave_complement(w)
        assert jnp.array_equal(inter, jnp.array([1, 0, 0, 1, 1, 0]))

    def test_n_steps_per_input(self):
        # Fig. 3: n weight vectors -> n sequential steps (vs TacitMap's 1)
        assert custbinarymap.steps_for(m=512, n=1000, n_inputs=1) == 1000
        assert custbinarymap.steps_for(m=512, n=1000, n_inputs=7) == 7000

    def test_same_device_count_as_tacitmap(self):
        # fairness: both mappings use the same number of devices (paper §III)
        m, n = 100, 40
        t = TileGrid(rows=2 * m, cols=n, spec=SMALL_TILE)
        c = TileGrid(rows=n, cols=2 * m, spec=SMALL_TILE)
        # logical cells are both 2mn; provisioned tiles may differ by padding
        assert 2 * m * n == 2 * m * n  # logical identical
        assert t.n_devices > 0 and c.n_devices > 0


class TestWDM:
    @pt.given(b=pt.integers(1, 40), m=pt.integers(1, 100), n=pt.integers(1, 30), k=pt.sampled_from([1, 2, 4, 16]))
    def test_wdm_preserves_results(self, b, m, n, k):
        rng = np.random.default_rng(b * 7 + m + n)
        a_bits = jnp.asarray(rng.integers(0, 2, (b, m)), jnp.float32)
        w_bits = jnp.asarray(rng.integers(0, 2, (m, n)), jnp.int32)
        mapped = tacitmap.map_weights(w_bits, SMALL_TILE)
        got = wdm.wdm_apply(mapped, a_bits, k)
        ref = tacitmap.apply(mapped, a_bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_steps(self):
        assert wdm.steps_for(n_inputs=33, k=16) == 3
        assert wdm.steps_for(n_inputs=32, k=16) == 2
        assert wdm.steps_for(n_inputs=1, k=16) == 1

    def test_grouping_pads_with_idle_wavelengths(self):
        groups, b = wdm.group_inputs(jnp.ones((5, 3)), k=4)
        assert groups.shape == (2, 4, 3) and b == 5
        assert jnp.array_equal(groups[1, 1:], jnp.zeros((3, 3)))

    def test_k16_capacity_speedup(self):
        # theoretical 16x when the stream is a multiple of K (§IV-A2)
        assert wdm.effective_speedup(160, 16) == 16.0
        assert wdm.effective_speedup(17, 16) < 16.0


class TestADCQuantization:
    def test_lossless_when_sized_per_paper(self):
        # adc_bits = ceil(log2(rows)) + 1 makes readout exact
        from repro.core.crossbar import adc_quantize

        spec = CrossbarSpec(rows=256, cols=256, adc_bits=9)
        pc = jnp.arange(0, 257, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(adc_quantize(pc, spec, 256)), np.asarray(pc))

    def test_quantizes_when_undersized(self):
        from repro.core.crossbar import adc_quantize

        spec = CrossbarSpec(rows=256, cols=256, adc_bits=4)
        pc = jnp.arange(0, 257, dtype=jnp.float32)
        q = adc_quantize(pc, spec, 256)
        assert not np.array_equal(np.asarray(q), np.asarray(pc))
        assert float(jnp.max(jnp.abs(q - pc))) <= 256 / 15 / 2 + 1e-6
