"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernels target TPU; interpret=True executes the kernel body on
CPU — per the brief)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

import proptest as pt


def _signs(rng, shape):
    return jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=shape))


class TestPackBits:
    def test_roundtrip_values(self):
        bits = jnp.asarray([1] + [0] * 31 + [1, 1] + [0] * 30, jnp.int32).reshape(1, 64)
        packed = ops.pack_bits(bits)
        assert packed.shape == (1, 2)
        assert int(packed[0, 0]) == 1 and int(packed[0, 1]) == 3

    @pt.given(m=pt.integers(1, 200), b=pt.integers(1, 5))
    def test_popcount_preserved(self, m, b):
        rng = np.random.default_rng(m * 3 + b)
        bits = jnp.asarray(rng.integers(0, 2, (b, m)), jnp.int32)
        packed = ops.pack_bits(bits)
        pc = jax.lax.population_count(packed).sum(-1)
        np.testing.assert_array_equal(np.asarray(pc), np.asarray(bits.sum(-1)))

    def test_msb_word(self):
        # bit 31 set -> int32 sign bit; popcount must still see it
        bits = jnp.zeros((1, 32), jnp.int32).at[0, 31].set(1)
        packed = ops.pack_bits(bits)
        assert int(jax.lax.population_count(packed)[0, 0]) == 1


class TestXnorMatmul:
    @pytest.mark.parametrize(
        "b,m,n",
        [
            (1, 32, 1),        # minimal
            (4, 100, 30),      # ragged everything
            (128, 512, 128),   # exactly one block
            (130, 513, 129),   # one past block boundaries
            (16, 4096, 64),    # deep contraction
        ],
    )
    def test_vs_reference(self, b, m, n):
        rng = np.random.default_rng(b * 7 + m + n)
        a, w = _signs(rng, (b, m)), _signs(rng, (m, n))
        got = ops.xnor_matmul(a, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.xnor_matmul_ref(a, w)))

    @pt.given(b=pt.integers(1, 40), m=pt.integers(1, 300), n=pt.integers(1, 50))
    def test_property_sweep(self, b, m, n):
        rng = np.random.default_rng(b + m * 11 + n)
        a, w = _signs(rng, (b, m)), _signs(rng, (m, n))
        got = ops.xnor_matmul(a, w, bm=8, bn=8, bkw=2)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.xnor_matmul_ref(a, w)))

    def test_batch_leading_dims(self):
        rng = np.random.default_rng(0)
        a, w = _signs(rng, (2, 3, 64)), _signs(rng, (64, 16))
        got = ops.xnor_matmul(a, w)
        assert got.shape == (2, 3, 16)
        np.testing.assert_array_equal(
            np.asarray(got.reshape(6, 16)),
            np.asarray(ref.xnor_matmul_ref(a.reshape(6, 64), w)),
        )

    def test_int_dtype_input(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.choice([-1, 1], (4, 96)), jnp.int32)
        w = jnp.asarray(rng.choice([-1, 1], (96, 8)), jnp.int32)
        got = ops.xnor_matmul(a, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(a.astype(jnp.float32) @ w.astype(jnp.float32)).astype(np.int32))


class TestWdmMmm:
    @pytest.mark.parametrize("g,k,m,n", [(1, 16, 256, 64), (3, 16, 100, 30), (2, 4, 512, 128)])
    def test_vs_reference(self, g, k, m, n):
        rng = np.random.default_rng(g + k + m + n)
        groups, w = _signs(rng, (g, k, m)), _signs(rng, (m, n))
        got = ops.wdm_mmm(groups, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref.wdm_mmm_ref(groups, w)), rtol=0, atol=0)

    @pt.given(g=pt.integers(1, 5), k=pt.sampled_from([1, 4, 16]), m=pt.integers(1, 200), n=pt.integers(1, 40))
    def test_property_sweep(self, g, k, m, n):
        rng = np.random.default_rng(g * 5 + k + m + n)
        groups, w = _signs(rng, (g, k, m)), _signs(rng, (m, n))
        got = ops.wdm_mmm(groups, w, bb=8, bn=8, bm=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref.wdm_mmm_ref(groups, w)), atol=0)

    def test_matches_functional_wdm_path(self):
        # kernel result == core.wdm functional simulator result (±1 domain)
        from repro.core import bnn, tacitmap, wdm
        from repro.core.crossbar import CrossbarSpec

        rng = np.random.default_rng(9)
        a, w = _signs(rng, (8, 64)), _signs(rng, (64, 16))
        spec = CrossbarSpec(rows=32, cols=16, technology="oPCM", wdm_k=4)
        mapped = tacitmap.map_weights(bnn.signs_to_bits(w).astype(jnp.int32), spec)
        pc = wdm.wdm_apply(mapped, bnn.signs_to_bits(a), 4)
        sim = 2 * pc - 64
        kern = ops.wdm_mmm(a.reshape(2, 4, 64), w).reshape(8, 16)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(sim), atol=0)


class TestBitLinear:
    @pytest.mark.parametrize("b,m,n", [(1, 32, 8), (8, 100, 24), (128, 512, 128), (9, 513, 3)])
    def test_vs_reference(self, b, m, n):
        rng = np.random.default_rng(b * 3 + m + n)
        x = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
        w = _signs(rng, (m, n))
        alpha = jnp.asarray(rng.uniform(0.5, 2.0, (n,)), jnp.float32)
        got = ops.bitlinear(x, w, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref.bitlinear_ref(x, w, alpha)), rtol=1e-6)

    def test_leading_dims(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(2, 5, 64)), jnp.float32)
        w = _signs(rng, (64, 16))
        alpha = jnp.ones((16,), jnp.float32)
        got = ops.bitlinear(x, w, alpha)
        assert got.shape == (2, 5, 16)

    def test_zero_binarizes_to_plus_one(self):
        x = jnp.zeros((4, 32), jnp.float32)
        w = jnp.ones((32, 4), jnp.float32)
        alpha = jnp.ones((4,), jnp.float32)
        got = ops.bitlinear(x, w, alpha)
        np.testing.assert_array_equal(np.asarray(got), np.full((4, 4), 32.0, np.float32))
