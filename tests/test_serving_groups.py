"""K-group batched decode is semantically invisible: for every
registered engine, any group size — ragged tails and the single-slot
degenerate case included — produces byte-identical generations to
slot-at-a-time decode, while the crossbar group count drops ~K x."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro.configs import get_smoke_config
from repro.core import engine as engine_lib
from repro.models import lm as lm_lib
from repro.serving import BatchPlanner, Request

ENGINES = engine_lib.list_engines()


# ---------------------------------------------------------------------------
# BatchPlanner (pure host-side planning)
# ---------------------------------------------------------------------------


class TestBatchPlanner:
    def test_empty_tick_has_no_plan(self):
        assert BatchPlanner(4).plan([]) is None

    def test_invalid_group_size(self):
        with pytest.raises(ValueError, match="group size"):
            BatchPlanner(0)

    def test_exact_multiple(self):
        plan = BatchPlanner(2).plan([0, 1, 2, 3])
        assert (plan.n_groups, plan.n_lanes, plan.n_pad) == (2, 4, 0)
        np.testing.assert_array_equal(plan.gather_indices(), [0, 1, 2, 3])

    def test_ragged_tail_pads_with_last_slot(self):
        plan = BatchPlanner(2).plan([3, 0, 2])  # unsorted on purpose
        assert plan.slots == (0, 2, 3)
        assert (plan.n_groups, plan.n_lanes, plan.n_pad) == (2, 4, 1)
        np.testing.assert_array_equal(plan.gather_indices(), [0, 2, 3, 3])

    def test_single_slot_degenerate(self):
        plan = BatchPlanner(4).plan([1])
        assert (plan.n_active, plan.n_groups, plan.n_pad) == (1, 1, 3)
        np.testing.assert_array_equal(plan.gather_indices(), [1, 1, 1, 1])


# ---------------------------------------------------------------------------
# GroupedEngine: one binary_mmm call == B binary_vmm calls, bit-exact
# ---------------------------------------------------------------------------


def _signs(rng, shape):
    return jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=shape))


class TestGroupedEngine:
    @pytest.mark.parametrize("name", ENGINES)
    @pytest.mark.parametrize("b,k", [(1, 4), (5, 2), (8, 4)])
    def test_bit_exact_vs_reference(self, name, b, k):
        rng = np.random.default_rng(b * 13 + k)
        a, w = _signs(rng, (b, 40)), _signs(rng, (40, 9))
        ref = np.asarray(engine_lib.get_engine("reference").binary_vmm(a, w))
        grouped = engine_lib.GroupedEngine(engine_lib.get_engine(name), k)
        got = np.asarray(grouped.binary_vmm(a, w))
        np.testing.assert_array_equal(got.astype(np.int64), ref.astype(np.int64))

    def test_leading_batch_dims(self):
        rng = np.random.default_rng(7)
        a, w = _signs(rng, (2, 3, 40)), _signs(rng, (40, 9))
        grouped = engine_lib.GroupedEngine(engine_lib.get_engine("wdm"), 4)
        got = np.asarray(grouped.binary_vmm(a, w))
        assert got.shape == (2, 3, 9)
        ref = np.asarray(engine_lib.get_engine("reference").binary_vmm(a, w))
        np.testing.assert_array_equal(got.astype(np.int64), ref.astype(np.int64))

    def test_preferred_group_size_capability(self):
        # native-MMM backends expose their wavelength count; others 1
        wdm = engine_lib.get_engine("wdm")
        assert wdm.preferred_group_size() == wdm.spec.wdm_k
        for name in ENGINES:
            eng = engine_lib.get_engine(name)
            expect = eng.spec.wdm_k if eng.info.native_mmm else 1
            assert eng.preferred_group_size() == expect

    def test_grouped_steps_accounting(self):
        # 10 vectors in groups of 4 -> 3 group launches
        wdm = engine_lib.GroupedEngine(
            engine_lib.get_engine("wdm"), engine_lib.get_engine("wdm").spec.wdm_k
        )
        assert wdm.steps_for(64, 32, 10) == -(-10 // wdm.k)
        ref = engine_lib.GroupedEngine(engine_lib.get_engine("reference"), 4)
        assert ref.steps_for(64, 32, 10) == 3 * 4  # vmap'd group: K seq steps each
        assert ref.preferred_group_size() == 4

    def test_invalid_group_size(self):
        with pytest.raises(ValueError, match="group size"):
            engine_lib.GroupedEngine(engine_lib.get_engine("reference"), 0)


# ---------------------------------------------------------------------------
# Serving: grouped decode parity for every registered engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (5,), dtype=np.int32) for _ in range(3)]
    return cfg, params, prompts


def _compiled_ref(cfg, params):
    return compiler_lib.compile(
        cfg, params, compiler_lib.HardwareTarget(engine="reference")
    )


def _serve(cfg, params, prompts, *, engine, group_size, max_batch=3, n_new=3):
    cm = compiler_lib.compile(
        cfg, params,
        compiler_lib.HardwareTarget(engine=engine, group_size=group_size or None),
    )
    se = cm.serve(max_batch=max_batch, max_len=24)
    for i, p in enumerate(prompts):
        se.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    done = se.run_to_completion()
    return {r.rid: tuple(r.generated) for r in done}, se


@pytest.mark.parametrize("name", ENGINES)
def test_grouped_decode_matches_slot_at_a_time(name, served_model):
    """K=2 over 3 active slots (ragged: 3 % 2 != 0) == K=1 decode."""
    cfg, params, prompts = served_model
    got_k2, se2 = _serve(cfg, params, prompts, engine=name, group_size=2)
    got_k1, se1 = _serve(cfg, params, prompts, engine=name, group_size=1)
    assert got_k2 == got_k1
    # grouping reduced the crossbar group count and padded ragged tails
    # (the reference engine serves plain jnp — no registry calls to count)
    s2, s1 = se2.stats(), se1.stats()
    if name == "reference":
        assert s2.mmm_groups == s1.mmm_groups == 0
    else:
        assert s2.mmm_groups < s1.mmm_groups
    assert s2.decoded == s1.decoded
    assert s2.pad_lanes > 0


@pytest.mark.parametrize("name", [n for n in ENGINES if n != "reference"])
def test_grouped_decode_matches_reference_engine(name, served_model):
    cfg, params, prompts = served_model
    got, _ = _serve(cfg, params, prompts, engine=name, group_size=2)
    ref, _ = _serve(cfg, params, prompts, engine="reference", group_size=2)
    assert got == ref


def test_single_slot_degenerate_case(served_model):
    """One active slot under K=3: 2 idle lanes per tick, same tokens."""
    cfg, params, prompts = served_model
    got_k3, se = _serve(cfg, params, prompts[:1], engine="wdm", group_size=3)
    got_k1, _ = _serve(cfg, params, prompts[:1], engine="wdm", group_size=1)
    assert got_k3 == got_k1
    s = se.stats()
    assert s.mmm_groups == s.ticks
    assert s.pad_lanes == 2 * s.ticks


def test_group_size_auto_from_capability(served_model):
    cfg, params, _ = served_model
    # native MMM: K from the wavelength count, clamped to the pool
    se = compiler_lib.compile(
        cfg, params, compiler_lib.HardwareTarget(engine="wdm")
    ).serve(max_batch=2, max_len=16)
    assert se.group_k == min(engine_lib.get_engine("wdm").spec.wdm_k, 2)
    # non-native: one vmap'd group spanning the pool
    se = compiler_lib.compile(
        cfg, params, compiler_lib.HardwareTarget(engine="packed")
    ).serve(max_batch=2, max_len=16)
    assert se.group_k == 2


# ---------------------------------------------------------------------------
# run_to_completion hardening
# ---------------------------------------------------------------------------


def test_exhaustion_raises_with_stuck_requests(served_model):
    cfg, params, prompts = served_model
    se = _compiled_ref(cfg, params).serve(max_batch=1, max_len=64)
    se.submit(Request(rid=7, prompt=prompts[0], max_new_tokens=50))
    with pytest.raises(RuntimeError, match=r"did not drain.*\[7\].*queue_depth"):
        se.run_to_completion(max_ticks=2)


def test_submit_after_idle_drains_again(served_model):
    """Requests submitted after a drain are served, not spun on."""
    cfg, params, prompts = served_model
    se = _compiled_ref(cfg, params).serve(max_batch=2, max_len=24)
    se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=2))
    first = se.run_to_completion()
    assert [r.rid for r in first] == [0] and se.idle()
    assert se.run_to_completion() == []  # idle engine returns immediately
    se.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=2))
    second = se.run_to_completion(max_ticks=20)
    assert [r.rid for r in second] == [1]
