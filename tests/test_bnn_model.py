"""Executable BNN models: engine equivalence (reference == tacitmap ==
wdm) and trainability — the paper's 'mapping does not affect accuracy'."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import model
from repro.core.crossbar import CrossbarSpec


TILE = CrossbarSpec(rows=64, cols=32)
OTILE = CrossbarSpec(rows=64, cols=32, technology="oPCM", wdm_k=4)


class TestMLPEngines:
    def setup_method(self):
        self.cfg = model.MLPConfig(dims=(20, 32, 24, 5))
        self.params = model.init_mlp(jax.random.PRNGKey(0), self.cfg)
        self.x = jax.random.normal(jax.random.PRNGKey(1), (6, 20))

    def test_engines_bit_exact(self):
        ref = model.mlp_forward_infer(self.params, self.x, self.cfg, "reference")
        tac = model.mlp_forward_infer(self.params, self.x, self.cfg, "tacitmap", TILE)
        wdm_ = model.mlp_forward_infer(self.params, self.x, self.cfg, "wdm", OTILE)
        np.testing.assert_allclose(np.asarray(tac), np.asarray(ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(wdm_), np.asarray(ref), atol=1e-5)

    def test_train_reduces_loss(self):
        cfg, params = self.cfg, self.params
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (64, 20))
        y = jax.random.randint(jax.random.PRNGKey(3), (64,), 0, 5)

        def loss_fn(p):
            logits = model.mlp_forward_train(p, x, cfg)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

        loss0 = loss_fn(params)
        grad_fn = jax.jit(jax.grad(loss_fn))
        for _ in range(30):
            g = grad_fn(params)
            params = jax.tree.map(lambda p, g_: p - 0.05 * g_, params, g)
        assert loss_fn(params) < loss0


class TestConvEngines:
    def setup_method(self):
        self.cfg = model.ConvConfig(in_hw=12, in_ch=1, convs=((4, 3), (8, 3)), pools=(1, 2), fcs=(16, 5))
        self.params = model.init_conv(jax.random.PRNGKey(0), self.cfg)
        self.x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 12, 1))

    def test_engines_bit_exact(self):
        ref = model.conv_forward(self.params, self.x, self.cfg, train=False, engine="reference")
        tac = model.conv_forward(self.params, self.x, self.cfg, train=False, engine="tacitmap", spec=TILE)
        wdm_ = model.conv_forward(self.params, self.x, self.cfg, train=False, engine="wdm", spec=OTILE)
        np.testing.assert_allclose(np.asarray(tac), np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(wdm_), np.asarray(ref), atol=1e-4)

    def test_im2col_shapes(self):
        cols = model.im2col(self.x, 3)
        assert cols.shape == (2, 10, 10, 9)

    def test_forward_shapes_no_nan(self):
        out = model.conv_forward(self.params, self.x, self.cfg, train=True)
        assert out.shape == (2, 5)
        assert not bool(jnp.isnan(out).any())
