"""Eq. 1 arithmetic + mapping identities: the algebraic heart of the paper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn

import proptest as pt


def _rand_signs(rng, shape):
    return rng.choice(np.array([-1.0, 1.0], dtype=np.float32), size=shape)


class TestEncodings:
    def test_roundtrip(self):
        s = jnp.array([-1.0, 1.0, 1.0, -1.0])
        assert jnp.array_equal(bnn.bits_to_signs(bnn.signs_to_bits(s)), s)

    @pt.given(m=pt.integers(1, 300))
    def test_roundtrip_random(self, m):
        rng = np.random.default_rng(m)
        s = jnp.asarray(_rand_signs(rng, (m,)))
        assert jnp.array_equal(bnn.bits_to_signs(bnn.signs_to_bits(s)), s)


class TestEq1:
    """In (*) W = 2*Popcount(In' XNOR W') - VectorLength."""

    @pt.given(m=pt.integers(1, 513), n=pt.integers(1, 65), b=pt.integers(1, 5))
    def test_eq1_equals_pm1_dot(self, m, n, b):
        rng = np.random.default_rng(m * 1000 + n)
        a = jnp.asarray(_rand_signs(rng, (b, m)))
        w = jnp.asarray(_rand_signs(rng, (m, n)))
        ref = bnn.binary_matmul_signs(a, w)
        via_eq1 = 2 * bnn.xnor_popcount(
            bnn.signs_to_bits(a)[:, None, :], bnn.signs_to_bits(w).T[None, :, :]
        ) - m
        np.testing.assert_array_equal(np.asarray(via_eq1), np.asarray(ref))

    def test_xnor_truth_table(self):
        a = jnp.array([0, 0, 1, 1])
        w = jnp.array([0, 1, 0, 1])
        assert jnp.array_equal(bnn.xnor(a, w), jnp.array([1, 0, 0, 1]))

    def test_popcount(self):
        assert bnn.popcount(jnp.array([1, 0, 1, 1, 0])) == 3


class TestTacitMapIdentity:
    """[a ; ā] @ [w ; w̄] == popcount(XNOR(a, w)) — the 1-step claim."""

    @pt.given(m=pt.integers(1, 700), n=pt.integers(1, 40))
    def test_complement_vmm_is_xnor_popcount(self, m, n):
        rng = np.random.default_rng(m + n)
        a_bits = jnp.asarray(rng.integers(0, 2, size=(3, m)), jnp.float32)
        w_bits = jnp.asarray(rng.integers(0, 2, size=(m, n)), jnp.float32)
        vmm = bnn.tacitmap_vmm(a_bits, w_bits)
        direct = bnn.xnor_popcount(a_bits[:, None, :], w_bits.T[None, :, :])
        np.testing.assert_array_equal(np.asarray(vmm), np.asarray(direct))

    @pt.given(m=pt.integers(1, 700), n=pt.integers(1, 40))
    def test_tacitmap_binary_matmul(self, m, n):
        rng = np.random.default_rng(m * 7 + n)
        a = jnp.asarray(_rand_signs(rng, (2, m)))
        w = jnp.asarray(_rand_signs(rng, (m, n)))
        np.testing.assert_array_equal(
            np.asarray(bnn.tacitmap_binary_matmul(a, w)),
            np.asarray(bnn.binary_matmul_signs(a, w)),
        )


class TestSTE:
    def test_forward_is_sign(self):
        x = jnp.array([-2.0, -0.3, 0.0, 0.7, 3.0])
        assert jnp.array_equal(bnn.binarize_ste(x), jnp.array([-1.0, -1.0, 1.0, 1.0, 1.0]))

    def test_gradient_is_clipped_identity(self):
        g = jax.grad(lambda x: bnn.binarize_ste(x).sum())(jnp.array([-2.0, -0.5, 0.5, 2.0]))
        np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 1.0, 0.0])

    def test_training_signal_flows(self):
        # a tiny STE regression must reduce loss
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (8, 4)) * 0.5
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        target = jnp.sign(x @ jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (8, 4))))

        def loss(w):
            return jnp.mean((bnn.binary_matmul_signs(bnn.binarize_ste(x), bnn.binarize_ste(w)) / 8.0 - target) ** 2)

        l0 = loss(w)
        for _ in range(60):
            w = w - 0.1 * jax.grad(loss)(w)
        assert loss(w) < l0
