"""Multi-level PCM device model (paper §VI-C future work)."""

import jax.numpy as jnp
import numpy as np

from repro.core.multilevel import (
    dequantize,
    level_error_rate,
    multilevel_vmm_exact,
    noisy_vmm,
    quantize_weights,
)


def test_quantize_roundtrip_binary():
    w = jnp.array([-1.0, -0.3, 0.4, 1.0])  # (0.0 is a round-half-even edge)
    q = quantize_weights(w, 1)
    assert q.tolist() == [0, 0, 1, 1]  # binary sign mapping
    back = dequantize(q, 1)
    assert set(np.asarray(back).tolist()) <= {-1.0, 1.0}


def test_quantize_monotone_levels():
    w = jnp.linspace(-1, 1, 17)
    for bits in (1, 2, 4):
        q = np.asarray(quantize_weights(w, bits))
        assert (np.diff(q) >= 0).all()
        assert q.min() == 0 and q.max() == 2**bits - 1


def test_noise_free_binary_is_exact():
    import jax

    a = jax.random.randint(jax.random.key(0), (8, 32), 0, 2)
    w = jax.random.randint(jax.random.key(1), (32, 16), 0, 2)
    exact = multilevel_vmm_exact(a, w)
    noisy = noisy_vmm(a, w, 1, 0.0, jax.random.key(2))
    np.testing.assert_array_equal(np.asarray(noisy), np.asarray(exact))


def test_error_monotone_in_noise_and_depth():
    e_b = [level_error_rate(1, s) for s in (0.0, 0.05, 0.1)]
    assert e_b[0] == 0.0 and e_b[0] <= e_b[1] <= e_b[2]
    at_05 = [level_error_rate(b, 0.05) for b in (1, 2, 4)]
    assert at_05[0] <= at_05[1] <= at_05[2]
