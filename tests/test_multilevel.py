"""Multi-level PCM device model (paper §VI-C future work)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.multilevel import (
    dequantize,
    level_error_rate,
    multilevel_vmm_exact,
    noisy_vmm,
    quantize_weights,
)


def test_quantize_roundtrip_binary():
    w = jnp.array([-1.0, -0.3, 0.4, 1.0])  # (0.0 is a round-half-even edge)
    q = quantize_weights(w, 1)
    assert q.tolist() == [0, 0, 1, 1]  # binary sign mapping
    back = dequantize(q, 1)
    assert set(np.asarray(back).tolist()) <= {-1.0, 1.0}


def test_quantize_monotone_levels():
    w = jnp.linspace(-1, 1, 17)
    for bits in (1, 2, 4):
        q = np.asarray(quantize_weights(w, bits))
        assert (np.diff(q) >= 0).all()
        assert q.min() == 0 and q.max() == 2**bits - 1


def test_noise_free_binary_is_exact():
    import jax

    a = jax.random.randint(jax.random.key(0), (8, 32), 0, 2)
    w = jax.random.randint(jax.random.key(1), (32, 16), 0, 2)
    exact = multilevel_vmm_exact(a, w)
    noisy = noisy_vmm(a, w, 1, 0.0, jax.random.key(2))
    np.testing.assert_array_equal(np.asarray(noisy), np.asarray(exact))


def test_error_monotone_in_noise_and_depth():
    e_b = [level_error_rate(1, s) for s in (0.0, 0.05, 0.1)]
    assert e_b[0] == 0.0 and e_b[0] <= e_b[1] <= e_b[2]
    at_05 = [level_error_rate(b, 0.05) for b in (1, 2, 4)]
    assert at_05[0] <= at_05[1] <= at_05[2]


# ---------------------------------------------------------------------------
# Quantize/dequantize round-trip + exact (noise-free) VMM — the paths the
# noisy Monte-Carlo study builds on, exercised directly per cell depth.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_quantize_dequantize_roundtrip(bits):
    """dequantize(quantize(w)) lands within half a level of w, and the
    level code round-trips exactly (quantize is dequantize's left
    inverse on the level lattice)."""
    w = jnp.linspace(-1.0, 1.0, 101)
    q = quantize_weights(w, bits)
    back = dequantize(q, bits)
    levels = 2**bits - 1
    # reconstruction error bounded by half a level spacing (2/levels)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1.0 / levels + 1e-6)
    # lattice fixpoint: re-quantizing the reconstruction is the identity
    np.testing.assert_array_equal(np.asarray(quantize_weights(back, bits)), np.asarray(q))


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_quantize_clips_out_of_range(bits):
    w = jnp.array([-5.0, 5.0])
    q = np.asarray(quantize_weights(w, bits))
    assert q.tolist() == [0, 2**bits - 1]


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_exact_vmm_matches_float_matmul(bits):
    """multilevel_vmm_exact on integer levels IS the float matmul of the
    level codes — the crossbar ideal the noisy path degrades from."""
    import jax

    levels = 2**bits - 1
    k1, k2 = jax.random.split(jax.random.key(bits), 2)
    a = jax.random.randint(k1, (9, 33), 0, levels + 1)
    w = jax.random.randint(k2, (33, 17), 0, levels + 1)
    got = np.asarray(multilevel_vmm_exact(a, w))
    ref = np.asarray(a, np.float32) @ np.asarray(w, np.float32)
    np.testing.assert_array_equal(got, ref)
    # and the dequantized product relates by the level scaling: the
    # (2q/L - 1) affine maps the integer MAC onto the real-valued one
    aw_real = np.asarray(dequantize(a, bits)) @ np.asarray(dequantize(w, bits))
    m = a.shape[-1]
    sum_a = np.asarray(a, np.float64).sum(-1)
    sum_w = np.asarray(w, np.float64).sum(0)
    recovered = (
        4 * got - 2 * levels * sum_a[:, None] - 2 * levels * sum_w[None, :]
        + m * levels * levels
    ) / (levels * levels)
    np.testing.assert_allclose(recovered, aw_real, rtol=1e-5, atol=1e-5)
