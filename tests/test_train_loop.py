"""Fault-tolerance invariants of the training loop (DESIGN.md §6)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, make_train_step, train
from repro.train.loop import _Preemption


def _loop(tmp_path, **kw) -> TrainLoopConfig:
    base = dict(
        total_steps=8,
        batch_size=2,
        seq_len=16,
        checkpoint_every=3,
        checkpoint_dir=str(tmp_path),
        async_checkpoint=False,
        warmup_steps=2,
    )
    base.update(kw)
    return TrainLoopConfig(**base)


CFG = get_smoke_config("tinyllama-1.1b")


def test_loss_decreases(tmp_path):
    out = train(CFG, _loop(tmp_path, total_steps=30, peak_lr=1e-3))
    first = sum(out["losses"][:5]) / 5
    last = sum(out["losses"][-5:]) / 5
    assert last < first, out["losses"]


def test_restart_reproduces_exact_trajectory(tmp_path):
    """Kill at step 5, restart -> bit-identical final params vs uninterrupted."""
    ref = train(CFG, _loop(tmp_path / "ref"))

    calls = {"n": 0}

    def fault(step):
        calls["n"] += 1
        if step == 5 and calls["n"] <= 6:  # fail exactly once
            raise RuntimeError("injected node failure")

    out = train(CFG, _loop(tmp_path / "faulty"), fault_hook=fault)
    assert out["restarts"] == 1
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        ref["params"],
        out["params"],
    )


def test_too_many_faults_raises(tmp_path):
    def always_fail(step):
        raise RuntimeError("flaky node")

    try:
        train(CFG, _loop(tmp_path, max_restarts=1), fault_hook=always_fail)
        raised = False
    except RuntimeError:
        raised = True
    assert raised


def test_preemption_checkpoints_and_exits(tmp_path):
    loop = _loop(tmp_path, total_steps=50)

    # simulate SIGTERM at step 4 via the fault hook (same thread)
    state = {}

    def hook(step):
        if step == 4:
            # directly set the flag the signal handler would set
            import repro.train.loop as L

            state["p"] = True
            # find the active _Preemption via the loop's local — instead,
            # send the signal for real:
            import os
            import signal

            os.kill(os.getpid(), signal.SIGTERM)

    out = train(CFG, loop, fault_hook=hook)
    assert out["preempted"] is True
    assert out["final_step"] == 4
    # resuming completes the run from the preemption checkpoint
    out2 = train(CFG, loop)
    assert out2["final_step"] == 49
    assert out2["preempted"] is False


def test_nan_guard_skips_update():
    opt_cfg = OptConfig()
    loop = TrainLoopConfig(total_steps=4, batch_size=2, seq_len=8)
    step_fn = make_train_step(CFG, opt_cfg, loop)
    from repro.data import lm_batch
    from repro.models import lm as lm_lib
    from repro.optim import adamw_init

    params = lm_lib.init_params(jax.random.key(0), CFG)
    # poison one weight with NaN -> loss is NaN -> update must be skipped
    poisoned = jax.tree.map(lambda x: x, params)
    poisoned["embed"] = poisoned["embed"].at[0, 0].set(jnp.nan)
    opt = adamw_init(poisoned, opt_cfg)
    batch = lm_batch(CFG, 2, 8)
    new_params, _, metrics = step_fn(poisoned, opt, batch, jnp.asarray(0))
    assert int(metrics["skipped"]) == 1
    np.testing.assert_array_equal(
        np.asarray(new_params["final_norm"]), np.asarray(poisoned["final_norm"])
    )
