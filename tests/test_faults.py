"""Device fault injection + fault tolerance (PR 9).

The contract under test: a null fault model is bit-identical to the
plain engine on every backend; planted faults corrupt outputs by the
exact algebraic delta and are caught by the TacitMap complement-row
consistency probe; remapping onto spare tiles restores bit-exactness;
serving degrades gracefully (failed requests, rejected submits) when
the spare pool runs out — never a dead engine.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro.compiler import HardwareTarget, TargetError
from repro.configs import get_smoke_config
from repro.core import bnn
from repro.core import engine as engine_lib
from repro.core.crossbar import EPCM_TILE
from repro.faults import (
    FaultInjectionError,
    FaultMap,
    FaultModel,
    FaultModelError,
    FaultyEngine,
)
from repro.mapping import (
    SpareTilesExhaustedError,
    allocate,
    remap_plan,
)
from repro.models import lm as lm_lib
from repro.serving import (
    DegradedServiceError,
    Request,
    RequestRejectedError,
    RequestStatus,
)

MAX_LEN = 64
GEN = 6
TICKS = 500

# 4 physical tiles for a (2*16, 32) cell matrix — small enough that
# engine-level placement/locate tests are readable
SMALL_SPEC = dataclasses.replace(EPCM_TILE, rows=16, cols=16)


def _signs(rng, *shape):
    return jnp.asarray(rng.choice([-1.0, 1.0], shape).astype(np.float32))


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (n,), dtype=np.int32)
               for n in (5, 9, 7)]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def tiled_clean(model):
    """The fault-tolerant serving target, compiled fault-free, plus the
    per-request solo references every exactness assertion compares to."""
    cfg, params, prompts = model
    cm = compiler_lib.compile(cfg, params, HardwareTarget(
        engine="tiled", mapping_policy="tacitmap", spare_tiles=3,
    ))
    solo = {}
    for i, p in enumerate(prompts):
        se = cm.serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN))
        se.drain(TICKS)
        solo[i] = tuple(st.generated)
    return cm, solo


def _compile_faulty(model, fault_model, *, spare_tiles=3, engine="tiled"):
    cfg, params, _ = model
    return compiler_lib.compile(cfg, params, HardwareTarget(
        engine=engine, mapping_policy="tacitmap",
        spare_tiles=spare_tiles, fault_model=fault_model,
    ))


def _resolved_tiles(cm):
    """Physical tiles the FaultyEngine actually executes: placements
    resolve BY SHAPE (first matching instance), so failures planted for
    tests must land on these."""
    return sorted({
        t for pw in cm._fault_artifacts()
        for *_, t in cm.engine._placement_blocks(pw.m, pw.n)
    })


class TestFaultModel:
    @pytest.mark.parametrize("bad", [
        dict(seed=-1),
        dict(stuck_set_rate=-0.1),
        dict(stuck_set_rate=1.5),
        dict(stuck_set_rate=0.6, stuck_reset_rate=0.6),
        dict(drift_rate=-1e-3),
        dict(dead_lanes=(-1,)),
        dict(failed_tiles=(-2,)),
    ])
    def test_validation(self, bad):
        with pytest.raises(FaultModelError):
            FaultModel(**bad).validate()

    def test_null_and_pristine_flags(self):
        assert FaultModel().is_null
        fm = FaultModel(dead_lanes=(0,))
        assert fm.cell_pristine and not fm.is_null  # capacity, not values
        assert not FaultModel(failed_tiles=(1,)).cell_pristine

    def test_deterministic_per_tile(self):
        fm = FaultModel(seed=7, stuck_set_rate=0.1, stuck_reset_rate=0.1)
        s1, r1 = fm.tile_cell_masks(3, 32, 32)
        s2, r2 = fm.tile_cell_masks(3, 32, 32)
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(r1, r2)
        s_other, _ = fm.tile_cell_masks(4, 32, 32)
        assert not np.array_equal(s1, s_other)
        # SET wins ties: a cell is never stuck both ways
        assert not (s1 & r1).any()

    def test_drift_is_epoch_monotone(self):
        fm = FaultModel(seed=1, stuck_reset_rate=0.05, drift_rate=0.2)
        fracs = [fm.reset_fraction(e) for e in range(5)]
        assert fracs == sorted(fracs) and fracs[0] == 0.05
        prev = np.zeros((64, 64), bool)
        for epoch in range(4):
            _, reset = fm.tile_cell_masks(0, 64, 64, epoch=epoch)
            # a cell stuck at epoch e stays stuck at every later epoch
            assert (prev <= reset).all()
            prev = reset

    def test_failed_tile_reads_reset_everywhere(self):
        fm = FaultModel(failed_tiles=(2,))
        s, r = fm.tile_cell_masks(2, 8, 8)
        assert not s.any() and r.all()

    def test_fault_map_truthiness_and_union(self):
        assert not FaultMap()
        assert FaultMap(tiles=(1,))
        u = FaultMap(tiles=(1,)).union(FaultMap(lanes=(0,)))
        assert u.tiles == {1} and u.lanes == {0}


class TestFaultyEngineCore:
    def test_wrap_guards(self):
        base = engine_lib.get_engine("tacitmap")
        with pytest.raises(FaultInjectionError):
            FaultyEngine(FaultyEngine(base, FaultModel()), FaultModel())

    @pytest.mark.parametrize("name", ["tacitmap", "wdm", "packed",
                                      "custbinarymap"])
    def test_null_model_bit_identical(self, name):
        """Zero-fault wrapping is a guaranteed no-op on every backend —
        including packed, whose delta derives from raw signs at prepare
        time (bit-packed data has no cell matrix to read back)."""
        rng = np.random.default_rng(0)
        w = _signs(rng, 16, 24)
        a = _signs(rng, 16)
        g = _signs(rng, 2, 4, 16)  # (G, K, m) group batches
        plain = engine_lib.get_engine(name)
        faulty = FaultyEngine(engine_lib.get_engine(name), FaultModel())
        np.testing.assert_array_equal(
            np.asarray(plain.binary_vmm(a, plain.prepare(w))),
            np.asarray(faulty.binary_vmm(a, faulty.prepare(w))),
        )
        np.testing.assert_array_equal(
            np.asarray(plain.binary_mmm(g, plain.prepare(w))),
            np.asarray(faulty.binary_mmm(g, faulty.prepare(w))),
        )

    def test_corruption_is_the_exact_algebraic_delta(self):
        """out_faulty == out_clean + 2 * (complement_drive @ D) with
        D = SET*(1-C) - RESET*C assembled independently here."""
        rng = np.random.default_rng(1)
        m, n = 16, 32
        w = _signs(rng, m, n)
        a = _signs(rng, m)
        fm = FaultModel(seed=5, stuck_set_rate=0.05, stuck_reset_rate=0.05)
        plain = engine_lib.get_engine("tacitmap", SMALL_SPEC)
        faulty = FaultyEngine(engine_lib.get_engine("tacitmap", SMALL_SPEC), fm)

        out_clean = np.asarray(plain.binary_vmm(a, plain.prepare(w)))
        out_faulty = np.asarray(faulty.binary_vmm(a, faulty.prepare(w)))

        # assemble D independently: same per-tile masks, layer-local grid
        set_m = np.zeros((2 * m, n), bool)
        reset_m = np.zeros((2 * m, n), bool)
        R, C = SMALL_SPEC.rows, SMALL_SPEC.cols
        for rb, cb, ru, cu, tile in faulty._placement_blocks(m, n):
            s, r = fm.tile_cell_masks(tile, R, C)
            set_m[rb * R:rb * R + ru, cb * C:cb * C + cu] |= s[:ru, :cu]
            reset_m[rb * R:rb * R + ru, cb * C:cb * C + cu] |= r[:ru, :cu]
        prog = np.asarray(_cells_from_signs_np(w))
        d = set_m * (1.0 - prog) - reset_m * prog
        drive = np.asarray(bnn.concat_complement_input(bnn.signs_to_bits(a)))
        expected = out_clean + 2.0 * (drive.astype(np.float64) @ d)
        np.testing.assert_allclose(out_faulty, expected, rtol=0, atol=1e-5)

    def test_probe_cheap_equals_execute(self):
        rng = np.random.default_rng(2)
        w = _signs(rng, 16, 32)
        fm = FaultModel(seed=3, stuck_set_rate=0.03, stuck_reset_rate=0.03)
        eng = FaultyEngine(engine_lib.get_engine("tacitmap", SMALL_SPEC), fm)
        pw = eng.prepare(w)
        cheap = eng.consistency_probe(pw)
        honest = eng.consistency_probe(pw, execute=True)
        np.testing.assert_array_equal(cheap, honest)
        assert cheap.max() > 0

    def test_probe_silent_when_pristine(self):
        rng = np.random.default_rng(2)
        w = _signs(rng, 16, 32)
        eng = FaultyEngine(engine_lib.get_engine("tacitmap", SMALL_SPEC),
                           FaultModel())
        pw = eng.prepare(w)
        assert eng.consistency_probe(pw).max() == 0.0
        assert eng.consistency_probe(pw, execute=True).max() == 0.0
        assert eng.locate(pw) == frozenset()

    def test_locate_names_the_failed_tile(self):
        rng = np.random.default_rng(3)
        w = _signs(rng, 16, 32)  # (32, 32) cells -> 4 tiles under SMALL_SPEC
        eng = FaultyEngine(engine_lib.get_engine("tacitmap", SMALL_SPEC),
                           FaultModel())
        assert eng.pristine
        eng.fail_tile(3)
        assert not eng.pristine
        pw = eng.prepare(w)
        assert eng.locate(pw) == frozenset({3})
        # refresh after repair-by-remap state change recomputes the delta
        eng2 = eng.rebind(engine_lib.get_engine("tacitmap", SMALL_SPEC))
        assert eng2.failed_tiles() == frozenset({3})

    def test_drift_corrupts_and_probe_fires(self):
        rng = np.random.default_rng(4)
        w = _signs(rng, 16, 32)
        fm = FaultModel(seed=9, drift_rate=0.5)
        eng = FaultyEngine(engine_lib.get_engine("tacitmap", SMALL_SPEC), fm)
        with pytest.raises(ValueError):
            eng.advance_drift(-1)
        eng.advance_drift(3)
        pw = eng.prepare(w)
        assert eng.consistency_probe(pw).max() > 0

    def test_dead_lanes_shrink_effective_k(self):
        eng = FaultyEngine(engine_lib.get_engine("wdm"), FaultModel())
        k0 = eng.inner.preferred_group_size()
        assert k0 > 1 and eng.effective_group_cap() == k0
        eng.fail_lane(0)
        eng.fail_lane(2)
        assert eng.effective_group_cap() == k0 - 2
        assert eng.preferred_group_size() == k0 - 2


def _cells_from_signs_np(w):
    b = bnn.signs_to_bits(w)
    return np.asarray(jnp.concatenate([b, 1.0 - b], axis=-2))


class TestAllocatorFaultAwareness:
    def test_spares_and_avoid_holes(self, model):
        cfg, _, _ = model
        plan = allocate(cfg, policy="tacitmap", tile_budget=8,
                        spare_tiles=2, avoid_tiles=(0, 3))
        data_tiles = {b.tile for lp in plan.layers for b in lp.blocks}
        assert 0 not in data_tiles and 3 not in data_tiles
        assert len(plan.spares) == 2
        assert not (set(plan.spares) & data_tiles)
        assert 0 not in plan.spares and 3 not in plan.spares
        assert plan.avoid_tiles == (0, 3)

    def test_allocate_validation(self, model):
        cfg, _, _ = model
        with pytest.raises(ValueError):
            allocate(cfg, spare_tiles=-1)
        with pytest.raises(ValueError):
            allocate(cfg, avoid_tiles=(-3,))

    def test_remap_moves_only_displaced_blocks(self, model):
        cfg, _, _ = model
        plan = allocate(cfg, policy="tacitmap", tile_budget=8, spare_tiles=2)
        victim = next(b.tile for lp in plan.layers for b in lp.blocks)
        new_plan, delta = remap_plan(plan, [victim])
        moved = {(mv.layer, mv.row_block, mv.col_block) for mv in delta.moves}
        assert all(mv.src == victim and mv.dst in plan.spares
                   for mv in delta.moves)
        for lp_old, lp_new in zip(plan.layers, new_plan.layers):
            for b_old, b_new in zip(lp_old.blocks, lp_new.blocks):
                key = (lp_old.name, b_old.row_block, b_old.col_block)
                if key in moved:
                    assert b_old.tile == victim and b_new.tile != victim
                else:
                    assert b_old == b_new  # untouched blocks keep their cells
        assert victim in new_plan.avoid_tiles
        assert victim not in new_plan.spares
        assert delta.cost.cells == sum(mv.cells for mv in delta.moves)
        assert delta.cost.energy_pj > 0 and delta.cost.time_ns > 0

    def test_remap_empty_failure_set_is_free(self, model):
        cfg, _, _ = model
        plan = allocate(cfg, policy="tacitmap", tile_budget=8, spare_tiles=1)
        same, delta = remap_plan(plan, [])
        assert same is plan and delta.moves == () and delta.cost.cells == 0

    def test_remap_exhaustion_and_bist_veto(self, model):
        cfg, _, _ = model
        plan = allocate(cfg, policy="tacitmap", tile_budget=8, spare_tiles=0)
        victim = next(b.tile for lp in plan.layers for b in lp.blocks)
        with pytest.raises(SpareTilesExhaustedError):
            remap_plan(plan, [victim])
        plan2 = allocate(cfg, policy="tacitmap", tile_budget=8, spare_tiles=2)
        # a BIST predicate that condemns every spare exhausts the pool too
        with pytest.raises(SpareTilesExhaustedError):
            remap_plan(plan2, [victim], tile_ok=lambda t: False)


class TestTargetValidation:
    def test_negative_spares(self, model):
        with pytest.raises(TargetError):
            HardwareTarget(engine="tiled", mapping_policy="tacitmap",
                           spare_tiles=-1).validate()

    def test_invalid_fault_model_is_target_error(self):
        with pytest.raises(TargetError):
            HardwareTarget(engine="tacitmap",
                           fault_model=FaultModel(seed=-1)).validate()

    def test_reference_engine_rejects_fault_model(self):
        with pytest.raises(TargetError):
            HardwareTarget(engine="reference",
                           fault_model=FaultModel()).validate()

    def test_describe_mentions_faults_and_spares(self):
        t = HardwareTarget(engine="tiled", mapping_policy="tacitmap",
                           spare_tiles=2,
                           fault_model=FaultModel(failed_tiles=(1,)))
        d = t.describe()
        assert "spares=2" in d and "failed_tiles=[1]" in d


class TestCompiledRemap:
    def test_null_injection_compiled_bit_identical(self, model, tiled_clean):
        cfg, params, prompts = model
        cm_clean, _ = tiled_clean
        cm = _compile_faulty(model, FaultModel())
        toks = np.concatenate([prompts[0], prompts[1]])[None, :].astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(cm_clean.prefill(toks)[0]),
            np.asarray(cm.prefill(toks)[0]),
        )

    def test_remap_round_trip_restores_bit_exactness(self, model, tiled_clean):
        cfg, params, prompts = model
        cm_clean, _ = tiled_clean
        cm = _compile_faulty(model, FaultModel())
        toks = prompts[0][None, :].astype(np.int32)
        ref = np.asarray(cm_clean.prefill(toks)[0])

        victim = _resolved_tiles(cm)[0]
        cm.engine.fail_tile(victim)
        cm.refresh_faults()
        assert not np.array_equal(np.asarray(cm.prefill(toks)[0]), ref)

        sweep = cm.scan_faults()
        assert sweep.tiles == {victim}
        report = cm.remap(sweep)
        assert len(report.moves) >= 1
        assert all(mv.src == victim for mv in report.moves)
        assert report.cost.cells > 0
        np.testing.assert_array_equal(np.asarray(cm.prefill(toks)[0]), ref)
        assert not cm.scan_faults().tiles  # post-remap sweep is clean

    def test_remap_without_plan_or_wrapper_raises(self, model, tiled_clean):
        cm_clean, _ = tiled_clean
        with pytest.raises(TargetError):
            cm_clean.remap(FaultMap(tiles=(0,)))  # no FaultyEngine bound
        cfg, params, _ = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(
            engine="tacitmap", fault_model=FaultModel()))
        with pytest.raises(TargetError):
            cm.remap(FaultMap(tiles=(0,)))  # wrapper but no mapping plan

    def test_compiled_group_size_respects_dead_lanes(self, model):
        cfg, params, _ = model
        fm = FaultModel(dead_lanes=(0, 1))
        cm = compiler_lib.compile(cfg, params, HardwareTarget(
            engine="wdm", fault_model=fm))
        cm_plain = compiler_lib.compile(cfg, params,
                                        HardwareTarget(engine="wdm"))
        k_plain = cm_plain.group_size_for(32)
        assert cm.group_size_for(32) == k_plain - 2


class TestServingFaultTolerance:
    def test_mid_serve_failure_remap_solo_exact(self, model, tiled_clean):
        """The headline gate: a tile dies mid-serve, the health monitor
        detects + remaps + restarts, every generation stays solo-exact."""
        cfg, params, prompts = model
        _, solo = tiled_clean
        cm = _compile_faulty(model, FaultModel())
        victim = _resolved_tiles(cm)[0]
        se = cm.serve(max_batch=len(prompts), max_len=MAX_LEN)
        assert se.health is not None
        sts = [se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN))
               for i, p in enumerate(prompts)]
        for tick in range(TICKS):
            if tick == 2:
                cm.engine.fail_tile(victim)
                cm.refresh_faults()
                se._rebind()
            se.step()
            if se.idle():
                break
        assert se.health.remaps == 1 and not se.health.degraded
        assert victim in se.health.quarantined
        assert se.stats().scheduler.restarted >= 1
        for st in sts:
            assert st.status is RequestStatus.FINISHED
            assert tuple(st.generated) == solo[st.rid]

    def test_preempted_during_remap_restores_bit_exact(self, model,
                                                       tiled_clean):
        """Satellite: a request preempted (priority eviction, snapshot
        taken) while the fault->remap window is open must come back
        bit-exact — post-fault snapshots are discarded by the
        clean-tick watermark, pre-fault ones restore."""
        cfg, params, prompts = model
        _, solo = tiled_clean
        cm = _compile_faulty(model, FaultModel())
        victim = _resolved_tiles(cm)[0]
        se = cm.serve(max_batch=2, max_len=MAX_LEN)
        sts = [se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN,
                                 priority=0))
               for i, p in enumerate(prompts[:2])]
        for tick in range(TICKS):
            if tick == 1:
                # high-priority arrival evicts a running low-priority
                # request: its snapshot is taken INSIDE the fault window
                sts.append(se.submit(Request(
                    rid=2, prompt=prompts[2], max_new_tokens=GEN,
                    priority=5)))
                cm.engine.fail_tile(victim)
                cm.refresh_faults()
                se._rebind()
            se.step()
            if se.idle():
                break
        assert se.health.remaps == 1 and not se.health.degraded
        for st in sts:
            assert st.status is RequestStatus.FINISHED
            assert tuple(st.generated) == solo[st.rid]

    def test_expired_partial_output_is_strict_solo_prefix(self, model,
                                                          tiled_clean):
        """Satellite: a request whose deadline passes after a
        fault-induced restart keeps a partial output that is a STRICT
        prefix of the solo generation — restarts never leak corrupt
        tokens into what the client saw."""
        cfg, params, prompts = model
        _, solo = tiled_clean
        cm = _compile_faulty(model, FaultModel())
        victim = _resolved_tiles(cm)[0]
        se = cm.serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=GEN,
                               deadline_ticks=8))
        for tick in range(TICKS):
            if tick == 2:
                cm.engine.fail_tile(victim)
                cm.refresh_faults()
                se._rebind()
            se.step()
            if se.idle():
                break
        assert st.status is RequestStatus.EXPIRED
        got = tuple(st.generated)
        assert 0 < len(got) < len(solo[0])
        assert got == solo[0][:len(got)]

    def test_spare_exhaustion_degrades_gracefully(self, model):
        cfg, params, prompts = model
        cm = _compile_faulty(model, FaultModel(), spare_tiles=0)
        victim = _resolved_tiles(cm)[0]
        se = cm.serve(max_batch=2, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=12))
        se.step()
        cm.engine.fail_tile(victim)
        cm.refresh_faults()
        se._rebind()
        for _ in range(20):
            se.step()
            if se.health.degraded:
                break
        assert se.health.degraded
        assert st.status is RequestStatus.FAILED
        assert st.fail_reason and "spare" in st.fail_reason.lower()
        # new submissions are rejected with the degradation reason
        st2 = se.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=4))
        assert st2.status is RequestStatus.REJECTED
        with pytest.raises(RequestRejectedError, match="degraded"):
            list(se.stream(Request(rid=2, prompt=prompts[2],
                                   max_new_tokens=4)))

    def test_stream_raises_degraded_service_error(self, model):
        """An in-flight STREAMED request whose service degrades
        surfaces DegradedServiceError to the consuming client."""
        cfg, params, prompts = model
        cm = _compile_faulty(model, FaultModel(), spare_tiles=0)
        victim = _resolved_tiles(cm)[0]
        se = cm.serve(max_batch=1, max_len=MAX_LEN)
        cm.engine.fail_tile(victim)
        cm.refresh_faults()
        se._rebind()
        with pytest.raises(DegradedServiceError, match="failed"):
            # health check fires a few ticks in; the remap fails (no
            # spares) and the request is terminated FAILED mid-stream
            list(se.stream(Request(rid=0, prompt=prompts[0],
                                   max_new_tokens=24)))

    def test_dead_lane_k_shrink_is_bit_exact(self, model):
        """Dead WDM lanes are a capacity loss, never a correctness
        loss: generations under a shrunken K match the plain engine."""
        cfg, params, prompts = model
        cm_plain = compiler_lib.compile(cfg, params,
                                        HardwareTarget(engine="wdm"))
        solo = {}
        for i, p in enumerate(prompts):
            se = cm_plain.serve(max_batch=1, max_len=MAX_LEN)
            st = se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN))
            se.drain(TICKS)
            solo[i] = tuple(st.generated)
        cm = compiler_lib.compile(cfg, params, HardwareTarget(
            engine="wdm", fault_model=FaultModel(dead_lanes=(0, 3))))
        se = cm.serve(max_batch=len(prompts), max_len=MAX_LEN)
        sts = [se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN))
               for i, p in enumerate(prompts)]
        se.drain(TICKS)
        for st in sts:
            assert tuple(st.generated) == solo[st.rid]

    def test_runtime_lane_death_shrinks_k(self, model, tiled_clean):
        """A lane dying mid-serve shrinks the K-group via the monitor
        (no remap needed) and the pool keeps draining bit-exactly."""
        cfg, params, prompts = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(
            engine="wdm", fault_model=FaultModel()))
        # max_batch >= preferred K so group_k isn't batch-clamped and
        # the lane-death shrink is observable
        se = cm.serve(max_batch=16, max_len=MAX_LEN)
        k0 = se.group_k
        sts = [se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN))
               for i, p in enumerate(prompts)]
        for tick in range(TICKS):
            if tick == 2:
                cm.engine.fail_lane(1)
            se.step()
            if se.idle():
                break
        assert se.group_k == k0 - 1
        assert not se.health.degraded
        assert all(st.status is RequestStatus.FINISHED for st in sts)

    def test_drain_max_ticks_validation(self, model, tiled_clean):
        cm_clean, _ = tiled_clean
        se = cm_clean.serve(max_batch=1, max_len=MAX_LEN)
        with pytest.raises(ValueError, match="max_ticks"):
            se.drain(0)
