"""Fleet serving (PR 10): the prefix-affinity router, prefix-grafted
continuation prefill, the replica pool's failover path — and the one
invariant that matters one level up from the scheduler's: no routing
policy, replica count, prefix graft or mid-serve failover may change a
request's generated tokens vs running it alone on one replica.

Also covers the PR 10 satellites: cross-pool ``SlotSnapshot``
portability, end-to-end request latency on ``RequestState``, the
scheduler's ``adopt`` seam, ``costmodel.fleet_price`` and the
``benchmarks.run`` section-listing CLI.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro.configs import get_smoke_config
from repro.fleet import (
    FleetEngine,
    FleetRouter,
    PrefixIndex,
    Replica,
    RoutingConfigError,
    chain_hashes,
)
from repro.models import lm as lm_lib
from repro.serving import (
    PrefixGraft,
    Request,
    RequestStatus,
    SlotSnapshot,
)

MAX_LEN = 40
GEN = 4
BLOCK = 4


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, (2 * BLOCK,), dtype=np.int32)
    prompts = []
    for i in range(6):
        if i % 2 == 0:   # half share one block-aligned prefix
            tail = rng.integers(1, cfg.vocab_size, (2 + i % 3,), np.int32)
            prompts.append(np.concatenate([shared, tail]))
        else:
            prompts.append(rng.integers(1, cfg.vocab_size, (5,), np.int32))
    return cfg, params, prompts


@pytest.fixture(scope="module")
def compiled(model):
    cfg, params, _ = model
    return {
        name: compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine=name)
        )
        for name in ("reference", "packed")
    }


@pytest.fixture(scope="module")
def solo(model, compiled):
    """Per-request reference generations: each alone in a 1-slot pool."""
    _, _, prompts = model
    out = {}
    for name, cm in compiled.items():
        for i, p in enumerate(prompts):
            se = cm.serve(max_batch=1, max_len=MAX_LEN)
            st = se.submit(Request(rid=i, prompt=p, max_new_tokens=GEN))
            se.drain()
            out[(name, i)] = tuple(st.generated)
    return out


def _drive_staggered(fleet, prompts, gen=GEN):
    """One submit per fleet tick (the prefix library fills as later
    requests arrive), then drain."""
    states = []
    for i, p in enumerate(prompts):
        states.append(
            fleet.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        )
        fleet.step()
    fleet.drain()
    return states


# ---------------------------------------------------------------------------
# router units (no model involved)
# ---------------------------------------------------------------------------


class TestChainHashes:
    def test_chained_prefix_identity(self):
        a = np.arange(100, 116, dtype=np.int32)
        b = np.concatenate([a[:8], np.arange(900, 908, dtype=np.int32)])
        ha, hb = chain_hashes(a, 4), chain_hashes(b, 4)
        # identical first two blocks -> identical first two chain links;
        # divergence at block 2 changes every later link
        assert ha[:2] == hb[:2]
        assert ha[2:] != hb[2:]

    def test_partial_block_unhashed(self):
        toks = np.arange(10, dtype=np.int32)
        assert len(chain_hashes(toks, 4)) == 2
        assert len(chain_hashes(toks[:3], 4)) == 0

    def test_chain_covers_prefix_not_content(self):
        # same block content at a different chain position hashes
        # differently (the chain carries position)
        a = np.array([1, 2, 3, 4, 1, 2, 3, 4], np.int32)
        h = chain_hashes(a, 4)
        assert h[0] != h[1]


class TestPrefixIndex:
    def test_match_exceeds_block_boundary(self):
        idx = PrefixIndex(block_size=4)
        donor = np.arange(10, dtype=np.int32)
        idx.insert(donor, rows="rows")
        entry, common = idx.match(np.arange(9, dtype=np.int32))
        assert entry is not None
        assert common == 9      # element-wise, past the last full block

    def test_no_match_below_one_block(self):
        idx = PrefixIndex(block_size=4)
        idx.insert(np.arange(8, dtype=np.int32), rows=None)
        query = np.concatenate([
            np.arange(2, dtype=np.int32),
            np.full((6,), 999, np.int32),
        ])
        entry, common = idx.match(query)
        assert entry is None and common == 0

    def test_lru_eviction_bounds_entries(self):
        idx = PrefixIndex(block_size=2, capacity=2)
        for base in (0, 100, 200):
            idx.insert(np.arange(base, base + 4, dtype=np.int32), rows=base)
        assert len(idx) == 2
        # the oldest donor is gone; the newest two still match
        assert idx.match(np.arange(0, 4, dtype=np.int32))[0] is None
        assert idx.match(np.arange(200, 204, dtype=np.int32))[0] is not None

    def test_longest_chain_wins_contested_hash(self):
        idx = PrefixIndex(block_size=2)
        idx.insert(np.arange(4, dtype=np.int32), rows="short")
        idx.insert(np.arange(8, dtype=np.int32), rows="long")
        entry, common = idx.match(np.arange(8, dtype=np.int32))
        assert entry.rows == "long" and common == 8

    def test_bad_config(self):
        with pytest.raises(RoutingConfigError, match="block_size"):
            PrefixIndex(block_size=0)
        with pytest.raises(RoutingConfigError, match="capacity"):
            PrefixIndex(block_size=2, capacity=0)


class TestFleetRouter:
    def test_unknown_policy(self):
        with pytest.raises(RoutingConfigError, match="lifo"):
            FleetRouter([0, 1], policy="lifo")

    def test_round_robin_cycles(self):
        r = FleetRouter([0, 1, 2], policy="round-robin")
        toks = np.arange(8, dtype=np.int32)
        loads = {0: 0.0, 1: 0.0, 2: 0.0}
        got = [r.route(toks, loads).replica for _ in range(6)]
        assert got == [0, 1, 2, 0, 1, 2]

    def test_least_loaded_picks_freest(self):
        r = FleetRouter([0, 1], policy="least-loaded")
        d = r.route(np.arange(8, dtype=np.int32), {0: 5.0, 1: 1.0})
        assert d.replica == 1

    def test_prefix_routes_to_library_holder(self):
        r = FleetRouter([0, 1], policy="prefix", block_size=4)
        donor = np.arange(12, dtype=np.int32)
        r.observe_prefill(1, donor, rows="kv")
        # replica 1 holds the prefix but is more loaded — affinity wins
        d = r.route(donor, {0: 0.0, 1: 50.0})
        assert d.replica == 1
        assert d.graft_length == 11     # capped at prompt_len - 1
        assert d.entry.rows == "kv"

    def test_prefix_miss_falls_back_to_load(self):
        r = FleetRouter([0, 1], policy="prefix", block_size=4)
        d = r.route(np.arange(12, dtype=np.int32), {0: 9.0, 1: 2.0})
        assert d.replica == 1 and d.graft_length == 0
        assert r.prefix_hits == 0

    def test_forget_replica_stops_routing_to_it(self):
        r = FleetRouter([0, 1], policy="prefix", block_size=4)
        donor = np.arange(12, dtype=np.int32)
        r.observe_prefill(1, donor, rows="kv")
        r.forget_replica(1)
        d = r.route(donor, {0: 0.0})
        assert d.replica == 0 and d.graft_length == 0


# ---------------------------------------------------------------------------
# prefix-grafted continuation prefill
# ---------------------------------------------------------------------------


class TestPrefillContinue:
    @pytest.mark.parametrize("engine", ["reference", "packed"])
    def test_matches_full_prefill_bitwise(self, model, compiled, engine):
        """The load-bearing numeric invariant: prefilling a suffix over
        donated prefix KV rows reproduces the full prefill's logits AND
        caches bit-for-bit (the suffix goes through the same prefill
        attention graph, and cached rows are prompt-length-invariant)."""
        _, _, prompts = model
        cm = compiled[engine]
        prompt = prompts[0][None, :]
        full_logits, full_caches = cm.prefill(prompt)
        cut = BLOCK
        _, donor = cm.prefill(prompt[:, :cut])
        cont_logits, cont_caches = jax.jit(
            lambda p, t, pre: lm_lib.prefill_continue(
                p, t, pre, cm.cfg, engine=cm.engine
            )
        )(cm.params, prompt[:, cut:], donor)
        assert (np.asarray(full_logits) == np.asarray(cont_logits)).all()
        for a, b in zip(jax.tree.leaves(full_caches),
                        jax.tree.leaves(cont_caches)):
            assert (np.asarray(a) == np.asarray(b)).all()

    def test_rejects_non_attention_stacks(self):
        ssm_cfg = get_smoke_config("mamba2-2.7b")
        ssm_params = lm_lib.init_params(jax.random.key(0), ssm_cfg)
        toks = np.arange(4, dtype=np.int32)[None, :]
        with pytest.raises(lm_lib.PrefixContinuationError, match="mixer"):
            lm_lib.prefill_continue(ssm_params, toks, {}, ssm_cfg)

    def test_grafted_admission_is_exact_and_counted(self, compiled, model,
                                                    solo):
        """ServingEngine.prefill_into with a PrefixGraft: same tokens,
        fewer prompt tokens prefilled, ledger split between counters."""
        _, _, prompts = model
        cm = compiled["reference"]
        prompt = prompts[0]
        _, donor = cm.prefill(prompt[None, :BLOCK])
        rows = jax.tree.map(lambda c: c[:, 0], donor)

        se = cm.serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(
            rid=0, prompt=prompt, max_new_tokens=GEN,
            prefix=PrefixGraft(length=BLOCK, rows=rows),
        ))
        se.drain()
        assert tuple(st.generated) == solo[("reference", 0)]
        s = se.stats()
        assert s.grafted_tokens == BLOCK
        assert s.prefill_tokens == len(prompt) - BLOCK


# ---------------------------------------------------------------------------
# the fleet invariant: routed == solo, bit-exact
# ---------------------------------------------------------------------------


class TestFleetExactness:
    @pytest.mark.parametrize("policy",
                             ["prefix", "least-loaded", "round-robin"])
    @pytest.mark.parametrize("n_replicas", [1, 2, 3])
    def test_routed_equals_solo(self, compiled, model, solo, policy,
                                n_replicas):
        _, _, prompts = model
        cm = compiled["reference"]
        fleet = FleetEngine(
            [Replica(r, cm, max_batch=2, max_len=MAX_LEN)
             for r in range(n_replicas)],
            routing=policy, block_size=BLOCK,
        )
        states = _drive_staggered(fleet, prompts)
        for st in states:
            assert st.status is RequestStatus.FINISHED
            assert tuple(st.generated) == solo[("reference", st.request.rid)]
        s = fleet.stats()
        assert s.finished == len(prompts) and s.failed == 0

    def test_packed_engine_with_grafts(self, compiled, model, solo):
        _, _, prompts = model
        cm = compiled["packed"]
        fleet = FleetEngine(
            [Replica(r, cm, max_batch=2, max_len=MAX_LEN) for r in range(2)],
            routing="prefix", block_size=BLOCK,
        )
        states = _drive_staggered(fleet, prompts)
        s = fleet.stats()
        assert s.prefix_hits > 0 and s.grafted_tokens > 0
        for st in states:
            assert tuple(st.generated) == solo[("packed", st.request.rid)]

    def test_prefix_saves_prefill_tokens(self, compiled, model):
        """The routing policies differ ONLY in work placement: prefix
        must strictly out-hit and out-save round-robin on the
        shared-prefix mix."""
        _, _, prompts = model
        cm = compiled["reference"]
        by_policy = {}
        for policy in ("prefix", "round-robin"):
            fleet = FleetEngine(
                [Replica(r, cm, max_batch=2, max_len=MAX_LEN)
                 for r in range(2)],
                routing=policy, block_size=BLOCK,
            )
            _drive_staggered(fleet, prompts)
            by_policy[policy] = fleet.stats()
        pfx, rr = by_policy["prefix"], by_policy["round-robin"]
        assert pfx.prefix_hits > 0 and rr.prefix_hits == 0
        assert pfx.prefix_hit_rate > rr.prefix_hit_rate
        assert pfx.prefill_tokens < rr.prefill_tokens
        assert pfx.grafted_tokens > 0 and rr.grafted_tokens == 0

    def test_stream_through_fleet(self, compiled, model, solo):
        _, _, prompts = model
        cm = compiled["reference"]
        fleet = FleetEngine(
            [Replica(r, cm, max_batch=2, max_len=MAX_LEN) for r in range(2)],
            routing="prefix", block_size=BLOCK,
        )
        got = list(fleet.stream(
            Request(rid=0, prompt=prompts[0], max_new_tokens=GEN)
        ))
        assert tuple(got) == solo[("reference", 0)]

    def test_duplicate_replica_ids_rejected(self, compiled):
        cm = compiled["reference"]
        with pytest.raises(RoutingConfigError, match="duplicate"):
            FleetEngine([
                Replica(0, cm, max_batch=1, max_len=MAX_LEN),
                Replica(0, cm, max_batch=1, max_len=MAX_LEN),
            ])


# ---------------------------------------------------------------------------
# failover off a degraded replica
# ---------------------------------------------------------------------------


class TestFailover:
    def test_degrade_fails_over_with_zero_failed(self, model):
        """Replica 0 (fault-injected, zero spares) loses a tile
        mid-serve -> degrades; every in-flight request must finish on
        replica 1 with solo-exact tokens and zero fleet-wide FAILED."""
        from repro.faults import FaultModel

        cfg, params, prompts = model
        gen = 16
        max_len = max(len(p) for p in prompts) + gen + 2
        clean = compiler_lib.HardwareTarget(
            engine="tiled", mapping_policy="tacitmap", spare_tiles=0
        )
        cm_ref = compiler_lib.compile(cfg, params, clean)
        refs = {}
        for i, p in enumerate(prompts[:4]):
            se = cm_ref.serve(max_batch=1, max_len=max_len)
            st = se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
            se.drain()
            refs[i] = tuple(st.generated)

        cm0 = compiler_lib.compile(
            cfg, params, dataclasses.replace(clean, fault_model=FaultModel())
        )
        r0 = Replica(0, cm0, max_batch=4, max_len=max_len)
        r1 = Replica(1, cm_ref, max_batch=4, max_len=max_len)
        fleet = FleetEngine([r0, r1], routing="least-loaded")
        states = [
            fleet.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
            for i, p in enumerate(prompts[:4])
        ]
        victim = sorted({
            t for pw in cm0._fault_artifacts()
            for *_, t in cm0.engine._placement_blocks(pw.m, pw.n)
        })[0]
        ticks = 0
        while not fleet.idle() and ticks < 300:
            if ticks == 2:
                cm0.engine.fail_tile(victim)
                cm0.refresh_faults()
                r0.serving._rebind()
            fleet.step()
            ticks += 1

        assert r0.degraded_reason is not None and r1.healthy
        s = fleet.stats()
        assert s.failed == 0 and s.failovers > 0
        assert s.healthy_replicas == 1
        for st in states:
            assert st.status is RequestStatus.FINISHED
            assert tuple(st.generated) == refs[st.request.rid]
        moved = [st for st in states if st.failovers > 0]
        assert moved and all(st.replica == 1 for st in moved)

    def test_all_replicas_degraded_rejects(self, compiled, model):
        """With no healthy replica left, a new submission is REJECTED
        with the named degraded reason — same surface as a solo engine."""
        _, _, prompts = model
        cm = compiled["reference"]
        fleet = FleetEngine(
            [Replica(0, cm, max_batch=1, max_len=MAX_LEN)],
            routing="least-loaded",
        )
        fleet.replicas[0].scheduler.degrade("synthetic wipeout")
        st = fleet.submit(Request(rid=0, prompt=prompts[0],
                                  max_new_tokens=2))
        assert st.status is RequestStatus.REJECTED
        assert "wipeout" in st.reject_reason


# ---------------------------------------------------------------------------
# cross-pool snapshot portability (the failover salvage primitive)
# ---------------------------------------------------------------------------


class TestSnapshotPortability:
    def test_snapshot_restores_into_sibling_engine(self, model, solo):
        """A SlotSnapshot taken on one ServingEngine restores bit-exactly
        into a DIFFERENT engine compiled separately from the same
        HardwareTarget: prefill rows are prompt-length-invariant and the
        cache layout is target-determined, so KV rows are portable
        across pools — which is what fleet failover salvage relies on."""
        from repro.serving.scheduler import RequestState

        cfg, params, prompts = model
        target = compiler_lib.HardwareTarget(engine="reference")
        cm_a = compiler_lib.compile(cfg, params, target)
        cm_b = compiler_lib.compile(cfg, params, target)

        prompt = prompts[0]
        req = Request(rid=0, prompt=prompt, max_new_tokens=GEN)
        se_a = cm_a.serve(max_batch=1, max_len=MAX_LEN)
        slot = se_a.acquire_slot()
        st = RequestState(request=req, seq=0, submit_tick=0)
        se_a.prefill_into(slot, st)
        se_a.decode_tick({slot: st})
        snap = se_a.evict_slot(slot)
        assert isinstance(snap, SlotSnapshot)
        carried = list(st.generated)

        se_b = cm_b.serve(max_batch=1, max_len=MAX_LEN)
        slot_b = se_b.acquire_slot()
        se_b.restore_slot(slot_b, snap)
        st_b = RequestState(request=req, seq=0, submit_tick=0)
        st_b.generated = carried
        while len(st_b.generated) < GEN:
            se_b.decode_tick({slot_b: st_b})
        assert tuple(st_b.generated) == solo[("reference", 0)]

    def test_adopt_carries_tokens_and_snapshot(self, compiled, model, solo):
        """RequestScheduler.adopt: the fleet's failover admission —
        carried tokens don't re-fire, the snapshot resumes at admission,
        and the finished request matches its solo reference."""
        from repro.serving.scheduler import RequestState

        _, _, prompts = model
        cm = compiled["reference"]
        prompt = prompts[0]

        # interrupt a solo run mid-decode via the engine surface
        se_a = cm.serve(max_batch=1, max_len=MAX_LEN)
        slot = se_a.acquire_slot()
        st_a = RequestState(
            request=Request(rid=0, prompt=prompt, max_new_tokens=GEN),
            seq=0, submit_tick=0,
        )
        se_a.prefill_into(slot, st_a)
        se_a.decode_tick({slot: st_a})
        snap = se_a.evict_slot(slot)

        seen = []
        se_b = cm.serve(max_batch=2, max_len=MAX_LEN)
        st_b = se_b.scheduler.adopt(
            Request(rid=0, prompt=prompt, max_new_tokens=GEN,
                    on_token=lambda r, t, i: seen.append(t)),
            generated=list(st_a.generated),
            snapshot=snap,
        )
        assert st_b.status is RequestStatus.WAITING
        assert st_b.snapshot is snap
        se_b.drain()
        ref = solo[("reference", 0)]
        assert tuple(st_b.generated) == ref
        # only the resumed tokens fired the callback, not the carried ones
        assert tuple(seen) == ref[len(st_a.generated):]
        assert se_b.stats().scheduler.resumed == 1


# ---------------------------------------------------------------------------
# satellites: latency ledger, fleet pricing, section CLI
# ---------------------------------------------------------------------------


class TestLatencyLedger:
    def test_finish_tick_and_latency_recorded(self, compiled, model):
        _, _, prompts = model
        cm = compiled["reference"]
        se = cm.serve(max_batch=2, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=GEN))
        assert st.latency_ticks is None
        se.drain()
        assert st.finish_tick is not None
        assert st.latency_ticks == st.finish_tick - st.submit_tick
        assert st.latency_ticks > 0
        assert se.stats().scheduler.request_latency_ticks == pytest.approx(
            st.latency_ticks
        )

    def test_latency_histogram_exported(self, compiled, model):
        from repro import obs

        _, _, prompts = model
        cm = compiled["reference"]
        tel = obs.start()
        try:
            se = cm.serve(max_batch=1, max_len=MAX_LEN)
            se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=GEN))
            se.drain()
            text = tel.metrics.render()
        finally:
            obs.stop()
        assert "repro_request_latency_ticks" in text


class TestFleetPrice:
    def test_linear_area_flat_wall_clock(self, compiled):
        from repro.core import costmodel

        base = compiled["reference"].price(n_active=2)
        fp = costmodel.fleet_price(base, 3, n_active=2)
        assert fp.tiles_total == 3 * base.n_tiles
        assert fp.programming_uj == pytest.approx(3 * base.programming_uj)
        assert fp.programming_us == base.programming_us
        assert fp.tick_latency_ns == base.tick_latency_ns
        assert fp.fleet_tokens_per_s == pytest.approx(
            3 * 2 / (base.tick_latency_ns * 1e-9)
        )
        assert fp.break_even_ticks == base.break_even_ticks
        assert "3 x" in fp.summary()

    def test_engine_price_matches_costmodel(self, compiled, model):
        from repro.core import costmodel

        cm = compiled["reference"]
        fleet = FleetEngine(
            [Replica(r, cm, max_batch=2, max_len=MAX_LEN) for r in range(2)]
        )
        fp = fleet.price(n_active=2)
        ref = costmodel.fleet_price(cm.price(n_active=2), 2, n_active=2)
        assert fp.tiles_total == ref.tiles_total
        assert fp.fleet_tokens_per_s == pytest.approx(ref.fleet_tokens_per_s)

    def test_rejects_zero_replicas(self, compiled):
        from repro.core import costmodel

        with pytest.raises(ValueError, match="n_replicas"):
            costmodel.fleet_price(compiled["reference"].price(), 0)


class TestSectionCLI:
    def test_list_sections(self, capsys):
        from benchmarks import run as bench_run

        assert bench_run.main(["--list-sections"]) == 0
        out = capsys.readouterr().out
        for section in ("fleet", "faults", "scheduler", "dse"):
            assert section in out.split() or section in out

    def test_unknown_section_names_the_menu(self, capsys):
        from benchmarks import run as bench_run

        with pytest.raises(SystemExit):
            bench_run.main(["--sections", "flet"])
        err = capsys.readouterr().err
        assert "unknown sections: flet" in err
        # the error must carry the menu, not send the user hunting
        assert "fleet" in err and "scheduler" in err and "engines" in err
