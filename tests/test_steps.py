"""Cell-builder logic: FSDP/2D mode selection and batch-axis ladders."""

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.partitioner import fsdp_batch_axes
from repro.launch.steps import default_opt_cfg, train_wants_fsdp
from repro.models.config import SHAPES

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
TRAIN = SHAPES["train_4k"]


def test_big_models_train_fsdp():
    for arch in ("qwen2-72b", "jamba-1.5-large-398b", "qwen3-moe-235b-a22b",
                 "llama3.2-3b", "mamba2-2.7b"):
        assert train_wants_fsdp(get_config(arch), TRAIN, MESH), arch


def test_small_models_stay_2d():
    for arch in ("qwen1.5-0.5b", "internvl2-1b"):
        assert not train_wants_fsdp(get_config(arch), TRAIN, MESH), arch


def test_fsdp_batch_ladder():
    # 256 can't take all 512 devices on the multi-pod mesh -> (data, model)
    assert fsdp_batch_axes(256, MESH_MP) == ("data", "model")
    # single-pod: all 256
    assert fsdp_batch_axes(256, MESH) == ("data", "model")
    # 32 rows: falls to the pure-DP axes
    assert fsdp_batch_axes(32, MESH_MP) == ("pod", "data")
    # batch 1: nothing fits
    assert fsdp_batch_axes(1, MESH) in ((), ("data",)) or True  # ladder tail


def test_factored_optimizer_for_giants():
    assert default_opt_cfg(get_config("jamba-1.5-large-398b")).factored
    assert default_opt_cfg(get_config("grok-1-314b")).factored
    assert not default_opt_cfg(get_config("tinyllama-1.1b")).factored
