"""Prepared-weights contract (PR 4): program weights once per engine,
stream only activations.

Covers the two-phase ``Engine.prepare`` / execute contract across every
registered backend (bit-exactness vs raw for VMM/MMM, grouped ragged
tails, plan-bound ``tiled``), the identity-keyed :class:`WeightCache`
(LRU bound, invalidation on param update, tracer bypass), the tiled
backend's hoisted host-side placement caches, the serving engine's
crossbar-programming phase (the regression: ``prepare`` runs once per
projection at bind time and never during decode ticks), and the cost
model's one-time programming-energy term.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_lib
from repro.core.crossbar import CrossbarSpec, OPCM_TILE

ENGINES = engine_lib.list_engines()


def _signs(rng, shape):
    return jnp.asarray(rng.choice(np.array([-1.0, 1.0], np.float32), size=shape))


def _as_int(x):
    return np.asarray(x).astype(np.int64)


def _operands(b=6, m=100, n=30, seed=0):
    rng = np.random.default_rng(seed)
    return _signs(rng, (b, m)), _signs(rng, (m, n))


# ---------------------------------------------------------------------------
# The two-phase contract
# ---------------------------------------------------------------------------


class TestPreparedContract:
    @pytest.mark.parametrize("name", ENGINES)
    def test_artifact_metadata_and_idempotence(self, name):
        _, w = _operands()
        eng = engine_lib.get_engine(name)
        pw = eng.prepare(w)
        assert (pw.engine, pw.m, pw.n) == (name, 100, 30)
        hash((pw.engine, pw.m, pw.n, pw.aux))  # aux must be hashable (jit static)
        assert eng.prepare(pw) is pw  # idempotent passthrough

    def test_wrong_engine_rejected(self):
        _, w = _operands()
        pw = engine_lib.get_engine("packed").prepare(w)
        with pytest.raises(ValueError, match="programmed for engine"):
            engine_lib.get_engine("wdm").binary_vmm(_operands()[0], pw)

    @pytest.mark.parametrize("name", ENGINES)
    def test_prepared_is_jit_argument(self, name):
        """The artifact is a registered pytree: it crosses jit boundaries
        as an ordinary operand (how serving passes programmed params)."""
        a, w = _operands()
        eng = engine_lib.get_engine(name)
        pw = eng.prepare(w)
        ref = _as_int(a @ w)
        got = _as_int(jax.jit(eng.binary_vmm)(a, pw))
        np.testing.assert_array_equal(got, ref)

    @pytest.mark.parametrize("name", ENGINES)
    @pytest.mark.parametrize("b,k", [(7, 3), (1, 4), (8, 4)])
    def test_grouped_ragged_prepared_mmm(self, name, b, k):
        """GroupedEngine passes prepared weights through to the base's
        ``binary_mmm`` — ragged tails (k does not divide b) included."""
        a, w = _operands(b=b)
        grouped = engine_lib.GroupedEngine(engine_lib.get_engine(name), k)
        pw = grouped.prepare(w)
        np.testing.assert_array_equal(
            _as_int(grouped.binary_vmm(a, pw)), _as_int(a @ w)
        )

    @pytest.mark.parametrize("name", ["wdm", "packed", "tacitmap"])
    def test_mispaired_artifact_rejected(self, name):
        """An artifact whose m divides the activation length must raise,
        not reshape into silent garbage (wdm/packed reshape by pw.m)."""
        rng = np.random.default_rng(3)
        a = _signs(rng, (4, 64))
        pw = engine_lib.get_engine(name).prepare(_signs(rng, (32, 8)))
        with pytest.raises(ValueError, match="does not match the prepared"):
            engine_lib.get_engine(name).binary_vmm(a, pw)
        with pytest.raises(ValueError, match="does not match the prepared"):
            engine_lib.get_engine(name).binary_mmm(a.reshape(2, 2, 64), pw)

    def test_stacked_artifact_scans(self):
        """Per-repeat artifacts stack and ``lax.scan`` slices them back
        — the serving decode's weight-stationary layout."""
        a, _ = _operands()
        eng = engine_lib.get_engine("tacitmap")
        ws = [_operands(seed=s)[1] for s in range(3)]
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[eng.prepare(w) for w in ws]
        )

        def body(carry, pw):
            return carry, eng.binary_vmm(a, pw)

        _, outs = jax.lax.scan(body, 0.0, stacked)
        for i, w in enumerate(ws):
            np.testing.assert_array_equal(_as_int(outs[i]), _as_int(a @ w))


class TestPreparedTiled:
    def test_plan_bound_prepared(self):
        from repro.mapping import adhoc_layer, allocate

        a, w = _operands(b=5, m=300, n=70)
        plan = allocate(
            adhoc_layer(300, 70), spec=CrossbarSpec(rows=128, cols=32),
            policy="greedy", tile_budget=3,
        )
        eng = engine_lib.get_engine("tiled", plan=plan)
        pw = eng.prepare(w)
        np.testing.assert_array_equal(_as_int(eng.binary_vmm(a, pw)), _as_int(a @ w))

    def test_spec_mismatch_rejected(self):
        a, w = _operands()
        pw = engine_lib.get_engine("tiled", CrossbarSpec(rows=64, cols=64)).prepare(w)
        with pytest.raises(ValueError, match="re-run prepare"):
            engine_lib.get_engine("tiled").binary_vmm(a, pw)

    def test_host_index_cache_hoisted(self):
        """The per-(m, n) placement indices are computed once and
        memoized — previously rebuilt on every ``binary_vmm`` call."""
        a, w = _operands()
        eng = engine_lib.get_engine("tiled")
        eng.binary_vmm(a, w)
        misses = eng._index_cache.misses
        eng.binary_vmm(a, w)
        eng.prepare(w)
        assert eng._index_cache.misses == misses  # same shape: all hits
        assert eng._index_cache.hits > 0

    def test_placement_caches_bounded(self):
        eng = engine_lib.get_engine("tiled")
        for m in range(8, 8 + 4 * (eng.ADHOC_CACHE_SIZE + 3), 4):
            eng._indices(m, 8)
        assert len(eng._adhoc_cache) <= eng.ADHOC_CACHE_SIZE
        assert len(eng._index_cache) <= eng.ADHOC_CACHE_SIZE
        assert eng._index_cache.evictions > 0
        stats = eng.cache_stats()
        assert {"weight_cache", "adhoc_placements", "placement_indices"} <= set(stats)


# ---------------------------------------------------------------------------
# Weight cache (identity-keyed, bounded)
# ---------------------------------------------------------------------------


class TestWeightCache:
    def test_hit_and_identity_invalidation(self):
        a, w = _operands()
        eng = engine_lib.get_engine("packed")
        pw1 = eng.prepare_cached(w)
        pw2 = eng.prepare_cached(w)
        assert pw1 is pw2
        assert eng.weight_cache.stats["hits"] == 1
        # a param update is a NEW array — equal values still miss
        # (identity keying IS the invalidation rule)
        w_updated = jnp.array(w)
        pw3 = eng.prepare_cached(w_updated)
        assert pw3 is not pw1
        assert eng.weight_cache.stats["misses"] == 2
        np.testing.assert_array_equal(
            _as_int(eng.binary_vmm(a, pw3)), _as_int(eng.binary_vmm(a, pw1))
        )

    def test_latent_key_invalidation(self):
        """Keyed on the latent param (as the model layers use it): a new
        latent with different values yields a fresh, correct artifact."""
        a, _ = _operands()
        eng = engine_lib.get_engine("packed")
        latent1 = jnp.linspace(-1.0, 1.0, 100 * 30).reshape(100, 30)
        latent2 = -latent1
        for latent in (latent1, latent2):
            wb = jnp.where(latent >= 0, 1.0, -1.0)
            pw = eng.prepare_cached(wb, key=latent)
            np.testing.assert_array_equal(
                _as_int(eng.binary_vmm(a, pw)), _as_int(a @ wb)
            )

    def test_lazy_signs_not_built_on_hit(self):
        """Binarization passed as a thunk runs only on a miss — a cache
        hit pays zero weight-side work (the point of the cache)."""
        a, _ = _operands()
        eng = engine_lib.get_engine("packed")
        latent = jnp.linspace(-1.0, 1.0, 100 * 30).reshape(100, 30)
        calls = {"n": 0}

        def make():
            calls["n"] += 1
            return jnp.where(latent >= 0, 1.0, -1.0)

        pw1 = eng.prepare_cached(make, key=latent)
        pw2 = eng.prepare_cached(make, key=latent)
        assert pw1 is pw2 and calls["n"] == 1
        np.testing.assert_array_equal(
            _as_int(eng.binary_vmm(a, pw1)), _as_int(a @ make())
        )
        with pytest.raises(ValueError, match="explicit cache key"):
            eng.prepare_cached(make)

    def test_lru_bound(self):
        cache = engine_lib.WeightCache(maxsize=2)
        arrays = [jnp.zeros((4,)) + i for i in range(3)]
        pws = [
            engine_lib.PreparedWeights(engine="x", m=4, n=1, data=a)
            for a in arrays
        ]
        for a, p in zip(arrays, pws):
            cache.put(a, p)
        assert len(cache) == 2
        assert cache.get(arrays[0]) is None      # evicted (oldest)
        assert cache.get(arrays[2]) is pws[2]

    def test_tracer_bypass(self):
        """Traced prepares must not leak into the cache (they belong to
        the trace that created them)."""
        a, w = _operands()
        eng = engine_lib.get_engine("packed")

        @jax.jit
        def f(a, w):
            return eng.binary_vmm(a, eng.prepare_cached(w))

        np.testing.assert_array_equal(_as_int(f(a, w)), _as_int(a @ w))
        np.testing.assert_array_equal(_as_int(f(a, w)), _as_int(a @ w))
        assert len(eng.weight_cache) == 0

    def test_lru_counters(self):
        lru = engine_lib.LRUCache(maxsize=2)
        lru.put("a", 1)
        lru.put("b", 2)
        assert lru.get("a") == 1
        lru.put("c", 3)  # evicts "b" (LRU)
        assert lru.get("b") is None
        assert lru.stats == {
            "size": 2, "maxsize": 2, "hits": 1, "misses": 1, "evictions": 1,
        }


# ---------------------------------------------------------------------------
# Serving: the crossbar-programming phase
# ---------------------------------------------------------------------------


def _serving_fixture():
    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size, (6,), dtype=np.int32) for _ in range(3)
    ]
    return cfg, params, prompts


class TestServingProgramming:
    N_PROJ = 7  # attn q/k/v/o + ffn w1/w3/w2 per layer slot

    def test_prepare_once_per_projection_across_ticks(self, monkeypatch):
        """THE regression this PR exists for: raw-weight ``prepare`` runs
        exactly once per projection instance at engine bind, and never
        again across N decode ticks (pass-through validation of an
        already-prepared artifact is not programming and not counted)."""
        from repro import compiler as compiler_lib
        from repro.serving.engine import Request

        calls = {"n": 0}
        orig = engine_lib.WDMEngine.prepare

        def counting(self, w):
            if not isinstance(w, engine_lib.PreparedWeights):
                calls["n"] += 1
            return orig(self, w)

        monkeypatch.setattr(engine_lib.WDMEngine, "prepare", counting)
        cfg, params, prompts = _serving_fixture()
        se = compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine="wdm")
        ).serve(max_batch=2, max_len=32)
        expected = cfg.n_repeats * self.N_PROJ
        assert calls["n"] == expected
        stats = se.stats()
        assert stats.programmed == expected
        assert stats.program_s > 0
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        se.run_to_completion()
        assert se.stats().ticks >= 5
        assert calls["n"] == expected  # zero weight-side programming per tick

    @pytest.mark.parametrize("name", ["wdm", "packed", "tiled"])
    def test_generations_prepared_vs_raw_vs_reference(self, name):
        from repro import compiler as compiler_lib
        from repro.serving.engine import Request

        cfg, params, prompts = _serving_fixture()

        def gen(engine, prepared=True):
            se = compiler_lib.compile(
                cfg, params,
                compiler_lib.HardwareTarget(
                    engine=engine or "reference", prepare_weights=prepared
                ),
            ).serve(max_batch=2, max_len=32)
            for i, p in enumerate(prompts):
                se.submit(Request(rid=i, prompt=p, max_new_tokens=4))
            return {r.rid: tuple(r.generated) for r in se.run_to_completion()}

        ref = gen(None)
        assert gen(name, True) == gen(name, False) == ref

    def test_programmed_params_replace_latent_weights(self):
        from repro.models import lm as lm_lib

        cfg, params, _ = _serving_fixture()
        eng = engine_lib.get_engine("wdm")
        programmed, n = lm_lib.program_weights(params, cfg, eng)
        assert n == cfg.n_repeats * self.N_PROJ
        proj = programmed["blocks"]["slot0"]["attn"]["q"]
        assert "w" not in proj  # the artifact replaces the latent weights
        assert isinstance(proj["prepared"], engine_lib.PreparedWeights)
        assert proj["alpha"].shape == (cfg.n_repeats,)
        # input pytree not mutated
        assert "w" in params["blocks"]["slot0"]["attn"]["q"]

    def test_program_weights_noop_without_engine_or_bnn(self):
        from repro.models import lm as lm_lib

        cfg, params, _ = _serving_fixture()
        assert lm_lib.program_weights(params, cfg, None) == (params, 0)
        cfg_fp = dataclasses.replace(cfg, quant="none")
        eng = engine_lib.get_engine("wdm")
        assert lm_lib.program_weights(params, cfg_fp, eng) == (params, 0)

    def test_programmed_params_without_engine_fail_clearly(self):
        """Programmed params carry only the artifact; using them on a
        path that needs the latent weights must name the reason, not
        crash with a NoneType error deep inside the scan."""
        from repro.models import lm as lm_lib

        cfg, params, prompts = _serving_fixture()
        programmed, _ = lm_lib.program_weights(
            params, cfg, engine_lib.get_engine("wdm")
        )
        tokens = jnp.asarray(prompts[0])[None, :]
        with pytest.raises(ValueError, match="programmed for engine 'wdm'"):
            lm_lib.prefill(programmed, tokens, cfg)  # no engine bound

    def test_minimal_third_party_engine_served_raw(self):
        """A registered backend implementing only the pre-PR-4 protocol
        (no ``prepare``) must serve unprogrammed, not crash at bind."""
        from repro import compiler as compiler_lib
        from repro.serving.engine import Request

        class MinimalEngine:
            info = engine_lib.ReferenceEngine.info
            spec = engine_lib.get_engine("reference").spec
            name = "minimal"

            def binary_vmm(self, a, w):
                return a @ w

            def binary_mmm(self, groups, w):
                g, k, m = groups.shape
                return (groups.reshape(g * k, m) @ w).reshape(g, k, -1)

            def steps_for(self, m, n, b):
                return b

            def preferred_group_size(self):
                return 1

        engine_lib.register_engine("minimal", lambda spec=None: MinimalEngine())
        try:
            cfg, params, prompts = _serving_fixture()
            se = compiler_lib.compile(
                cfg, params, compiler_lib.HardwareTarget(engine="minimal")
            ).serve(max_batch=2, max_len=32)
            assert se.stats().programmed == 0
            se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=3))
            done = se.run_to_completion()
            assert len(done) == 1 and len(done[0].generated) == 3
        finally:
            engine_lib._REGISTRY.pop("minimal", None)

    def test_serving_cache_stats_exposed(self):
        from repro import compiler as compiler_lib

        cfg, params, _ = _serving_fixture()
        se = compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine="tiled")
        ).serve(max_batch=2, max_len=32)
        stats = se.stats().caches
        assert "weight_cache" in stats and "placement_indices" in stats
        se_ref = compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine="reference")
        ).serve(max_batch=2, max_len=32)
        assert se_ref.stats().caches == {}


# ---------------------------------------------------------------------------
# Cost model: one-time programming energy, separate from readout
# ---------------------------------------------------------------------------


class TestProgrammingCost:
    def _layer(self, m=512, n=512):
        from repro.core.networks import LayerDesc

        return LayerDesc(name="fc", m=m, n=n, positions=1, binary=True)

    def test_energy_scales_with_cells(self):
        from repro.core import costmodel as cm

        small = cm.layer_programming_cost(cm.TACITMAP_EPCM, self._layer(128, 128))
        big = cm.layer_programming_cost(cm.TACITMAP_EPCM, self._layer(256, 256))
        assert small.cells == 2 * 128 * 128  # complement pair per weight
        assert big.energy_pj == pytest.approx(4 * small.energy_pj)
        assert small.energy_pj > 0 and small.time_ns > 0

    def test_write_cost_separate_from_readout(self):
        """The programming term must NOT leak into per-tick readout
        pricing — raising the write energy leaves tick energy unchanged
        (that separation is the amortization story)."""
        from repro.core import costmodel as cm

        layer = self._layer()
        base = cm.grouped_decode_tick(cm.EINSTEINBARRIER, layer, 16)
        expensive = dataclasses.replace(cm.EINSTEINBARRIER, e_cell_write_pj=1e6)
        assert cm.grouped_decode_tick(expensive, layer, 16) == base
        assert cm.layer_programming_cost(expensive, layer).energy_pj > \
            cm.layer_programming_cost(cm.EINSTEINBARRIER, layer).energy_pj

    def test_break_even_and_network_totals(self):
        from repro.core import costmodel as cm
        from repro.core.networks import NETWORKS

        ticks = cm.programming_break_even_ticks(cm.EINSTEINBARRIER, self._layer(), 16)
        assert ticks > 0
        net = NETWORKS["MLP-S"] if "MLP-S" in NETWORKS else next(iter(NETWORKS.values()))
        total = cm.network_programming_cost(cm.TACITMAP_EPCM, net)
        assert total.cells >= sum(
            (2 * l.m if l.binary else l.m) * l.n for l in net.layers
        ) and total.energy_pj > 0
