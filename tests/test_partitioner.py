"""Partitioner rules: divisibility fallback, FSDP+TP+EP specs, cache SP.

Uses AbstractMesh — no devices needed, same spec inference the dry-run
runs on 512 devices.
"""

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.distributed import (
    batch_specs,
    cache_specs,
    infer_specs,
    opt_state_specs,
    validate_specs,
)
from repro.launch.steps import default_opt_cfg, opt_shapes, param_shapes
from repro.models import lm as lm_lib
from repro.models.config import SHAPES

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _leaf(tree, *path):
    for p in path:
        tree = tree[p]
    return tree


def test_dense_arch_specs():
    cfg = get_config("qwen2-72b")
    sds = param_shapes(cfg)
    specs = infer_specs(sds, MESH)
    assert not validate_specs(sds, specs, MESH)
    # TP on head projections, FSDP on the other dim
    assert _leaf(specs, "blocks", "slot0", "attn", "q", "w") == P(None, "data", "model")
    assert _leaf(specs, "blocks", "slot0", "attn", "o", "w") == P(None, "model", "data")
    assert _leaf(specs, "blocks", "slot0", "ffn", "w2", "w") == P(None, "model", "data")
    # vocab 152064 divides 16 -> vocab-parallel embed
    assert _leaf(specs, "embed") == P("model", "data")


def test_vocab_padding_makes_tables_shardable():
    cfg = get_config("internvl2-1b")  # vocab 151655 is odd...
    assert cfg.padded_vocab == 151808 and cfg.padded_vocab % 256 == 0
    sds = param_shapes(cfg)
    specs = infer_specs(sds, MESH)
    assert not validate_specs(sds, specs, MESH)
    # ...but the padded table shards vocab-parallel anyway
    assert _leaf(specs, "embed") == P("model", "data")


def test_vocab_fallback_when_indivisible():
    """The divisibility-fallback mechanism itself (synthetic odd table)."""
    sds = {"embed": jax.ShapeDtypeStruct((151655, 896), "float32")}
    specs = infer_specs(sds, MESH)
    assert specs["embed"] == P(None, "model")  # d 896 % 16 == 0, V odd


def test_moe_expert_parallel_vs_fallback():
    # qwen3: 128 experts % 16 == 0 -> EP over model
    specs = infer_specs(param_shapes(get_config("qwen3-moe-235b-a22b")), MESH)
    assert _leaf(specs, "blocks", "slot0", "moe", "w1") == P(None, "model", "data", None)
    # grok: 8 experts on a 16-way axis -> fallback to f-dim TP
    specs_g = infer_specs(param_shapes(get_config("grok-1-314b")), MESH)
    assert _leaf(specs_g, "blocks", "slot0", "moe", "w1") == P(None, None, "data", "model")


def test_mamba_specs():
    cfg = get_config("mamba2-2.7b")
    sds = param_shapes(cfg)
    specs = infer_specs(sds, MESH)
    assert not validate_specs(sds, specs, MESH)
    assert _leaf(specs, "blocks", "slot0", "mamba", "x_proj", "w") == P(None, "data", "model")
    assert _leaf(specs, "blocks", "slot0", "mamba", "out_proj", "w") == P(None, "model", "data")
    # dt/A/D head-sharded: 80 heads % 16 == 0
    assert _leaf(specs, "blocks", "slot0", "mamba", "A_log") == P(None, "model")


def test_opt_state_inherits_param_specs():
    cfg = get_config("jamba-1.5-large-398b")
    opt_cfg = default_opt_cfg(cfg)
    assert opt_cfg.factored  # 398B -> factored second moment
    sds = param_shapes(cfg)
    specs = infer_specs(sds, MESH)
    o_sds = opt_shapes(sds, opt_cfg)
    o_specs = opt_state_specs(specs, o_sds)
    assert o_specs["step"] == P()
    assert not validate_specs(o_sds["m"], o_specs["m"], MESH)
    assert not validate_specs(o_sds["v"], o_specs["v"], MESH)


def test_batch_specs_multipod():
    specs = {"tokens": jax.ShapeDtypeStruct((256, 4096), "int32")}
    b = batch_specs(specs, MESH_MP)
    assert b["tokens"] == P(("pod", "data"), None)
    # batch=1 can't shard -> replicated
    one = batch_specs({"x": jax.ShapeDtypeStruct((1, 8), "float32")}, MESH_MP)
    assert one["x"] == P()


def test_cache_specs_sequence_parallel():
    cfg = get_smoke_config("tinyllama-1.1b")
    cache = jax.eval_shape(lambda: lm_lib.init_cache(cfg, 128, 32768))
    cs = cache_specs(cache, MESH)
    k = cs["slot0"]["k"]  # (L, B, T, KV, D): batch over data, T over model
    assert k == P(None, "data", "model", None, None)
    # batch=1 long-context: T takes (data, model)
    cache1 = jax.eval_shape(lambda: lm_lib.init_cache(cfg, 1, 524288))
    cs1 = cache_specs(cache1, MESH)
    assert cs1["slot0"]["k"] == P(None, None, ("data", "model"), None, None)


def test_all_archs_validate_on_both_meshes():
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        sds = param_shapes(get_config(arch))
        for mesh in (MESH, MESH_MP):
            specs = infer_specs(sds, mesh)
            problems = validate_specs(sds, specs, mesh)
            assert not problems, f"{arch}: {problems[:3]}"
