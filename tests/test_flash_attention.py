"""Fused flash-attention kernel vs the dense oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _qkv(b, h, kvh, sq, skv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kvh, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kvh, skv, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize(
    "b,h,kvh,sq,skv,d",
    [
        (1, 2, 2, 128, 128, 32),     # MHA square
        (2, 4, 1, 128, 256, 16),     # GQA g=4, longer KV
        (1, 8, 2, 256, 256, 64),     # GQA g=4
    ],
)
def test_flash_matches_ref_causal(b, h, kvh, sq, skv, d, dtype, tol):
    q, k, v = _qkv(b, h, kvh, sq, skv, d, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_non_causal():
    q, k, v = _qkv(1, 2, 2, 128, 128, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_ragged_seq_pads_correctly():
    """Sq=200 (not a block multiple): padded rows must not pollute."""
    q, k, v = _qkv(1, 2, 2, 200, 200, 32, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert out.shape == want.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_small_blocks_sweep():
    q, k, v = _qkv(1, 2, 1, 64, 64, 16, jnp.float32, seed=4)
    want = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in [(16, 16), (32, 64), (64, 32)]:
        out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5,
            err_msg=f"bq={bq} bk={bk}",
        )


def test_model_attn_impl_pallas_matches_jnp():
    """attn_impl='pallas' is a drop-in for the jnp flash path."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.data import lm_batch
    from repro.models import lm as lm_lib

    base = get_smoke_config("tinyllama-1.1b")
    tokens = lm_batch(base, 2, 32, seed=7)["tokens"]
    params = lm_lib.init_params(jax.random.key(0), base)
    outs = {}
    for impl in ("jnp", "pallas"):
        cfg = dataclasses.replace(base, attn_impl=impl)
        logits, _ = lm_lib.prefill(params, tokens, cfg)
        outs[impl] = logits
    np.testing.assert_allclose(
        np.asarray(outs["jnp"], np.float32),
        np.asarray(outs["pallas"], np.float32),
        # bf16 path differences: the jnp flash path contracts the
        # probability tensor in bf16 while the fused kernel accumulates
        # fp32 in VMEM, so per-logit deviations reach a few 1e-2
        atol=6e-2, rtol=3e-2,
    )
