"""Minimal property-based testing harness (hypothesis is not installable
in this container — see DESIGN.md §8).

Provides a ``@given(**strategies)`` decorator that runs the test body
over ``N_TRIALS`` seeded random draws and reports the failing draw
(seed + concrete values) on the first counterexample, so failures are
reproducible with ``PROPTEST_SEED=<seed>``.
"""

from __future__ import annotations

import functools
import os

import numpy as np

N_TRIALS = int(os.environ.get("PROPTEST_TRIALS", "10"))
BASE_SEED = int(os.environ.get("PROPTEST_SEED", "20240514"))


class Strategy:
    def __init__(self, draw_fn, desc: str):
        self._draw = draw_fn
        self.desc = desc

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):
        return f"Strategy({self.desc})"


def integers(lo: int, hi: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(lo, hi + 1)), f"int[{lo},{hi}]")


def sampled_from(options) -> Strategy:
    options = list(options)
    return Strategy(lambda rng: options[int(rng.integers(0, len(options)))], f"in{options}")


def floats(lo: float, hi: float) -> Strategy:
    return Strategy(lambda rng: float(rng.uniform(lo, hi)), f"float[{lo},{hi}]")


def bit_matrix(rows: Strategy, cols: Strategy) -> Strategy:
    def draw(rng):
        r, c = rows.draw(rng), cols.draw(rng)
        return rng.integers(0, 2, size=(r, c)).astype(np.int32)

    return Strategy(draw, "bit_matrix")


def given(**strategies):
    """Run the decorated test over N_TRIALS seeded draws."""

    def deco(fn):
        # NOTE: no functools.wraps — pytest must not see the drawn
        # parameter names in the wrapper signature (it would treat them
        # as fixtures).
        def wrapper(*args, **kwargs):
            for trial in range(N_TRIALS):
                seed = BASE_SEED + trial
                rng = np.random.default_rng(seed)
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:  # noqa: BLE001 — re-raise with repro info
                    short = {
                        k: (v.shape if isinstance(v, np.ndarray) else v)
                        for k, v in drawn.items()
                    }
                    raise AssertionError(
                        f"property failed at trial {trial} (PROPTEST_SEED={seed}): "
                        f"draw={short}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
