"""Activation-hint resolution logic (mesh-agnostic parts)."""

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh

from repro.distributed import hints

MESH = AbstractMesh((("pod", 2), ("data", 8), ("model", 4)))


def test_hint_is_noop_outside_context():
    x = jnp.ones((4, 4))
    assert hints.hint(x, "dp", "model") is x


def test_resolve_dp_default_and_override():
    with hints.activation_hints(MESH):
        assert hints._resolve("dp", MESH) == ("pod", "data")
        assert hints._resolve("dp_strict", MESH) == ("pod", "data")
        assert hints._resolve("model", MESH) == "model"
    with hints.activation_hints(MESH, batch_axes=("data", "model"), tp=False):
        assert hints._resolve("dp", MESH) == ("data", "model")
        assert hints._resolve("dp_strict", MESH) == ("pod", "data")  # ignores override
        assert hints._resolve("model", MESH) is None                 # tp off
        assert hints._resolve("model_strict", MESH) == "model"       # survives tp off


def test_axis_size():
    assert hints._axis_size(("pod", "data"), MESH) == 16
    assert hints._axis_size("model", MESH) == 4
    assert hints._axis_size(None, MESH) == 1


def test_indivisible_dims_drop_to_replicated():
    """hint() must silently drop axes that don't divide the dim."""
    mesh = AbstractMesh((("model", 4),))
    with hints.activation_hints(mesh):
        # 4 divides 8 -> spec applies; 4 does not divide 6 -> dropped
        r8 = hints._resolve("model", mesh)
        assert r8 == "model"
        # the division check lives in hint(); emulate it:
        assert 8 % hints._axis_size(r8, mesh) == 0
        assert 6 % hints._axis_size(r8, mesh) != 0
