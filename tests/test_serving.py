"""Continuous batching must be semantically invisible: any interleaving
of requests produces the same tokens as running each alone."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro.configs import get_smoke_config
from repro.models import lm as lm_lib
from repro.serving import Request


def _compiled(cfg, params):
    return compiler_lib.compile(
        cfg, params, compiler_lib.HardwareTarget(engine="reference")
    )


def _reference_generate(cfg, params, prompt, n_new):
    """Isolated greedy generation via prefill + per-token decode."""
    tokens = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, pre = lm_lib.prefill(params, tokens, cfg)
    caches = lm_lib.init_cache(cfg, 1, 64)
    caches = jax.tree.map(
        lambda d, s: d.at[:, :, : s.shape[2]].set(s.astype(d.dtype))
        if d.ndim == 5 and d.shape[2] >= s.shape[2]
        else s.astype(d.dtype),
        caches, pre,
    )
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, caches = lm_lib.decode_step(params, tok, jnp.asarray(pos), caches, cfg)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([out[-1]], jnp.int32)
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-2.7b"])
def test_continuous_batching_matches_isolated(arch):
    cfg = get_smoke_config(arch)
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    n_new = 6

    refs = [_reference_generate(cfg, params, p, n_new) for p in prompts]

    # 3 requests, only 2 slots: forces queueing + slot reuse
    eng = _compiled(cfg, params).serve(max_batch=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=n_new) for i, p in enumerate(prompts)]
    states = [eng.submit(reqs[0]), eng.submit(reqs[1])]
    eng.step()          # tick 1: both admitted
    states.append(eng.submit(reqs[2]))  # arrives mid-flight
    done = eng.run_to_completion()

    assert len(done) == 3 and all(s.done for s in states)
    for st, ref in zip(states, refs):
        assert st.generated == ref, (
            f"req {st.rid}: continuous batching changed the output\n"
            f"  batched:  {st.generated}\n  isolated: {ref}"
        )


def test_slots_free_and_reuse():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = lm_lib.init_params(jax.random.key(1), cfg)
    eng = _compiled(cfg, params).serve(max_batch=1, max_len=32)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, size=4).astype(np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert [r.rid for r in done] == [0, 1, 2]  # sequential through 1 slot
    assert all(len(r.generated) == 3 for r in done)
