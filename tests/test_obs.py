"""Telemetry subsystem (repro.obs): tracing spans, metrics, crosscheck.

Four contracts:

* **Recorder correctness** — spans nest (depth, completion order),
  carry attributes, honor an injected deterministic clock, and export
  valid JSONL / Chrome-trace / Prometheus text (golden outputs).
* **Off by default, no-op when off** — with no active session the
  module helpers return the shared ``NULL_SPAN`` (identity — no
  allocation), record nothing, and never touch the clock or the device.
* **Semantically invisible** — serving with tracing on produces
  byte-identical generations to serving with telemetry off, across the
  engine grid (the instrumentation's hard acceptance gate).
* **Crosscheck** — every traced decode tick pairs with a finite,
  positive modeled price per (engine, K), through the public
  ``CompiledModel.pricing_plan()`` seam.
"""

from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro import obs
from repro.compiler import HardwareTarget
from repro.configs import get_smoke_config
from repro.core import engine as engine_lib
from repro.models import lm as lm_lib
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer

ENGINES = tuple(engine_lib.list_engines())


@pytest.fixture(autouse=True)
def _no_leaked_session():
    """Every test starts and ends with telemetry off."""
    obs.stop()
    yield
    obs.stop()


class FakeClock:
    """Deterministic ns clock: each read advances by ``step``."""

    def __init__(self, step: int = 1000):
        self.t = 0
        self.step = step

    def __call__(self) -> int:
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_depth_and_order(self):
        tr = Tracer(clock=FakeClock())
        with tr.span("outer", track="t") as outer:
            with tr.span("inner", track="t") as inner:
                pass
        # completion order: child lands before parent
        assert [s.name for s in tr.spans()] == ["inner", "outer"]
        assert outer.depth == 0 and inner.depth == 1
        # fake clock: every read advances 1000ns, so durations are exact
        assert inner.duration_ns == 1000   # start read + end read
        assert outer.duration_ns == 3000   # spans inner's two reads
        assert outer.t_start_ns < inner.t_start_ns
        assert outer.t_end_ns > inner.t_end_ns

    def test_span_attrs_entry_and_set(self):
        tr = Tracer(clock=FakeClock())
        with tr.span("tick", engine="wdm", k=4) as sp:
            sp.set(n_active=3)
        assert tr.spans("tick")[0].attrs == {
            "engine": "wdm", "k": 4, "n_active": 3,
        }

    def test_fence_blocks_device_work(self):
        tr = Tracer()
        x = jax.jit(lambda v: v * 2)(np.arange(8, dtype=np.float32))
        with tr.span("work") as sp:
            sp.fence(x)
        assert sp._fences == []          # drained at exit
        assert sp.duration_ns >= 0

    def test_events_and_filters(self):
        tr = Tracer(clock=FakeClock())
        tr.event("request.submit", track="sched", rid=7)
        with tr.span("tick"):
            pass
        assert len(tr.events()) == 1
        assert tr.events("request.submit")[0].attrs == {"rid": 7}
        assert tr.events("nope") == []
        assert [s.name for s in tr.spans("tick")] == ["tick"]

    def test_open_span_duration_raises(self):
        tr = Tracer()
        cm = tr.span("open")
        sp = cm.__enter__()
        with pytest.raises(ValueError, match="has not exited"):
            _ = sp.duration_ns
        cm.__exit__(None, None, None)

    def test_chrome_trace_golden(self):
        tr = Tracer(clock=FakeClock(step=500))
        with tr.span("compile", track="compile", engine="wdm"):
            pass
        tr.event("request.submit", track="sched", rid=0)
        doc = tr.to_chrome_trace()
        assert doc == {
            "traceEvents": [
                {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": "compile"}},
                {"name": "thread_name", "ph": "M", "pid": 0, "tid": 1,
                 "args": {"name": "sched"}},
                {"name": "compile", "ph": "X", "pid": 0, "tid": 0,
                 "ts": 0.5, "dur": 0.5, "args": {"engine": "wdm"}},
                {"name": "request.submit", "ph": "i", "s": "t", "pid": 0,
                 "tid": 1, "ts": 1.5, "args": {"rid": 0}},
            ],
            "displayTimeUnit": "ms",
        }

    def test_exports_round_trip(self, tmp_path):
        tr = Tracer(clock=FakeClock())
        with tr.span("tick", k=2):
            pass
        tr.event("mark")
        chrome = tmp_path / "trace.json"
        jsonl = tmp_path / "trace.jsonl"
        assert tr.export_chrome(str(chrome)) == 2
        assert tr.export_jsonl(str(jsonl)) == 2
        doc = json.loads(chrome.read_text())
        assert {e["ph"] for e in doc["traceEvents"]} == {"M", "X", "i"}
        rows = [json.loads(line) for line in jsonl.read_text().splitlines()]
        assert [r["type"] for r in rows] == ["span", "event"]
        assert rows[0]["attrs"] == {"k": 2}


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_monotonic(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total", "things")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError, match="only go up"):
            c.inc(-1)

    def test_labeled_series_are_independent(self):
        reg = MetricsRegistry()
        c = reg.counter("ticks_total")
        c.labels(engine="wdm").inc(3)
        c.labels(engine="tiled").inc(4)
        assert c.labels(engine="wdm").value == 3
        assert c.value == 7   # family sum

    def test_gauge_set_inc_dec(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(5)
        g.inc(2)
        g.dec()
        assert g.value == 6

    def test_histogram_bucket_math(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
        child = h.labels()
        for v in (0.5, 1.0, 1.5, 3.0, 100.0):
            child.observe(v)
        # per-bucket: <=1 gets 0.5 and 1.0; <=2 gets 1.5; <=4 gets 3.0;
        # 100.0 lands only in the implicit +Inf
        assert child.counts == [2, 1, 1]
        assert child.cumulative() == [2, 3, 4]
        assert child.total == 5
        assert child.sum == pytest.approx(106.0)
        assert child.mean == pytest.approx(21.2)
        assert child.quantile(0.5) == 2.0
        assert child.quantile(1.0) == float("inf")   # past the last bound
        assert child.quantile(0.0) == 1.0

    def test_histogram_validates_buckets(self):
        with pytest.raises(ValueError, match="sorted, unique"):
            MetricsRegistry().histogram("bad", buckets=(2.0, 1.0))
        with pytest.raises(ValueError, match="quantile"):
            MetricsRegistry().histogram("h").labels().quantile(1.5)

    def test_kind_conflict_is_an_error(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered as counter"):
            reg.gauge("x")

    def test_prometheus_render_golden(self):
        reg = MetricsRegistry()
        reg.counter("repro_ticks_total", "decode ticks").labels(
            engine="wdm"
        ).inc(3)
        reg.gauge("repro_depth", "queue depth").set(2)
        h = reg.histogram("repro_lat", "latency", buckets=(0.5, 1.0))
        h.labels(k=4).observe(0.25)
        h.labels(k=4).observe(2.0)
        assert reg.render() == (
            "# HELP repro_depth queue depth\n"
            "# TYPE repro_depth gauge\n"
            "repro_depth 2\n"
            "# HELP repro_lat latency\n"
            "# TYPE repro_lat histogram\n"
            'repro_lat_bucket{k="4",le="0.5"} 1\n'
            'repro_lat_bucket{k="4",le="1"} 1\n'
            'repro_lat_bucket{k="4",le="+Inf"} 2\n'
            'repro_lat_sum{k="4"} 2.25\n'
            'repro_lat_count{k="4"} 2\n'
            "# HELP repro_ticks_total decode ticks\n"
            "# TYPE repro_ticks_total counter\n"
            'repro_ticks_total{engine="wdm"} 3\n'
        )

    def test_export(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("x").inc()
        path = tmp_path / "metrics.txt"
        reg.export(str(path))
        assert path.read_text() == "# TYPE x counter\nx 1\n"


# ---------------------------------------------------------------------------
# session + disabled path
# ---------------------------------------------------------------------------


class TestSession:
    def test_off_by_default_helpers_are_noops(self):
        assert not obs.enabled() and obs.active() is None
        # identity: the one shared no-op span, no allocation per call
        assert obs.span("tick", engine="wdm") is NULL_SPAN
        assert obs.span("other") is NULL_SPAN
        with obs.span("tick") as sp:
            sp.set(k=4).fence(object())
        obs.event("x")
        obs.count("c", 2)
        obs.gauge_set("g", 1)
        obs.observe("h", 0.5)
        obs.cache_event("weight_cache", "hit")
        assert obs.active() is None   # nothing sprang into existence

    def test_start_stop_and_session_scope(self):
        tel = obs.start()
        assert obs.active() is tel and obs.enabled()
        with obs.span("tick"):
            pass
        assert len(tel.tracer.spans("tick")) == 1
        assert obs.stop() is tel
        assert not obs.enabled()
        with obs.session() as tel2:
            assert obs.active() is tel2
        assert not obs.enabled()

    def test_helpers_record_on_active_session(self):
        with obs.session() as tel:
            obs.count("repro_x_total", 2, engine="wdm")
            obs.gauge_set("repro_g", 7)
            obs.observe("repro_h", 0.1, buckets=(1.0,))
            obs.event("mark", rid=3)
        assert tel.metrics.counter("repro_x_total").value == 2
        assert tel.metrics.gauge("repro_g").value == 7
        assert tel.metrics.histogram("repro_h", buckets=(1.0,)).total == 1
        assert tel.tracer.events("mark")[0].attrs == {"rid": 3}

    def test_telemetry_write(self, tmp_path):
        with obs.session() as tel:
            with obs.span("tick"):
                pass
            obs.count("c")
        tel.write(
            trace_out=str(tmp_path / "t.json"),
            jsonl_out=str(tmp_path / "t.jsonl"),
            metrics_out=str(tmp_path / "m.txt"),
        )
        assert json.loads((tmp_path / "t.json").read_text())["traceEvents"]
        assert (tmp_path / "t.jsonl").read_text().count("\n") == 1
        assert "c 1" in (tmp_path / "m.txt").read_text()

    def test_disabled_overhead_loose_bound(self):
        # the gate is structural (no allocation / clock / sync creep),
        # with a CI-safe bound: 3 orders of magnitude above the real cost
        import time

        n = 10_000
        t0 = time.perf_counter_ns()
        for _ in range(n):
            with obs.span("tick", track="serve", engine="none", k=1):
                pass
        per_call = (time.perf_counter_ns() - t0) / n
        assert per_call < 100_000, f"disabled span cost {per_call:.0f}ns/call"


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def _model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size, (3 + i,), np.int32) for i in range(3)
    ]
    return cfg, params, prompts


def _serve_tokens(cfg, params, prompts, target):
    from repro.serving import Request

    se = compiler_lib.compile(cfg, params, target).serve(max_batch=2, max_len=32)
    states = [
        se.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        for i, p in enumerate(prompts)
    ]
    se.drain()
    return {st.rid: tuple(st.generated) for st in states}, se


@pytest.fixture(scope="module")
def model():
    return _model()


class TestServingIntegration:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_tracing_is_bit_exact(self, model, engine):
        """The hard gate: telemetry must never change generated tokens."""
        cfg, params, prompts = model
        target = HardwareTarget(engine=engine, group_size=2)
        obs.stop()
        plain, _ = _serve_tokens(cfg, params, prompts, target)
        with obs.session():
            traced, _ = _serve_tokens(cfg, params, prompts, target)
        assert traced == plain and plain

    def test_compile_stage_spans(self, model):
        cfg, params, _ = model
        with obs.session() as tel:
            compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm"))
        names = [s.name for s in tel.tracer.spans()]
        assert names == [
            "compile.validate", "compile.map", "compile.resolve",
            "compile.program", "compile",
        ]
        root = tel.tracer.spans("compile")[0]
        assert root.attrs["engine"] == "wdm"
        assert root.attrs["programmed"] > 0
        # stage spans nest under the root
        assert all(s.depth == 1 for s in tel.tracer.spans()[:-1])
        assert root.depth == 0

    def test_decode_tick_spans_and_metrics(self, model):
        cfg, params, prompts = model
        with obs.session() as tel:
            _, se = _serve_tokens(
                cfg, params, prompts, HardwareTarget(engine="wdm", group_size=2)
            )
        ticks = tel.tracer.spans("decode_tick")
        assert ticks and len(ticks) == se.stats().ticks
        for sp in ticks:
            assert sp.attrs["engine"] == "wdm"
            assert sp.attrs["k"] == se.group_k
            assert 1 <= sp.attrs["n_active"] <= se.max_batch
            assert sp.attrs["n_groups"] >= 1
            assert "cache_hits" in sp.attrs and "cache_misses" in sp.attrs
            assert sp.duration_ns > 0
        # the registry saw the same tick count and lane totals
        m = tel.metrics
        assert m.counter("repro_decode_ticks_total").value == len(ticks)
        assert (
            m.counter("repro_decoded_tokens_total").value
            == se.stats().decoded
        )
        assert m.counter("repro_mmm_groups_total").value == se.stats().mmm_groups
        assert m.histogram("repro_tick_latency_seconds").total == len(ticks)

    def test_request_lifecycle_events_and_histograms(self, model):
        cfg, params, prompts = model
        with obs.session() as tel:
            _, se = _serve_tokens(
                cfg, params, prompts, HardwareTarget(engine="wdm", group_size=2)
            )
        tr = tel.tracer
        n = len(prompts)
        assert len(tr.events("request.submit")) == n
        assert len(tr.events("request.admit")) == n
        assert len(tr.events("request.finish")) == n
        rids = {e.attrs["rid"] for e in tr.events("request.finish")}
        assert rids == set(range(n))
        assert tel.metrics.histogram("repro_ttft_ticks").total == n
        assert tel.metrics.histogram("repro_admission_wait_ticks").total == n
        sch = se.scheduler.stats()
        assert tel.metrics.gauge("repro_queue_depth").value == sch.queue_depth

    def test_cache_live_counters(self):
        # eager prepare_cached traffic mirrors into the live counter
        # (inside jit the cache is bypassed — tracer keys — so drive the
        # seam eagerly: first lookup misses, repeat hits)
        eng = engine_lib.get_engine("wdm")
        w = jax.numpy.asarray(
            np.where(
                np.random.default_rng(0).standard_normal((8, 8)) >= 0, 1, -1
            ),
            dtype=jax.numpy.float32,
        )
        with obs.session() as tel:
            eng.prepare_cached(w)
            eng.prepare_cached(w)
        c = tel.metrics.counter("repro_cache_events_total")
        assert c.labels(cache="weight_cache", kind="miss").value == 1
        assert c.labels(cache="weight_cache", kind="hit").value == 1
        assert eng.cache_stats()["weight_cache"]["hits"] == 1

    def test_prefill_spans(self, model):
        cfg, params, prompts = model
        with obs.session() as tel:
            _serve_tokens(
                cfg, params, prompts, HardwareTarget(engine="wdm", group_size=2)
            )
        pre = tel.tracer.spans("prefill")
        assert len(pre) == len(prompts)
        assert {sp.attrs["rid"] for sp in pre} == set(range(len(prompts)))
        assert all(sp.attrs["prompt_len"] == len(prompts[sp.attrs["rid"]])
                   for sp in pre)


# ---------------------------------------------------------------------------
# crosscheck
# ---------------------------------------------------------------------------


class TestCrosscheck:
    def test_crosscheck_serving(self, model):
        cfg, params, prompts = model
        with obs.session():
            _, se = _serve_tokens(
                cfg, params, prompts,
                HardwareTarget(engine="tiled", group_size=2),
            )
            rows = obs.crosscheck_serving(se)
        assert rows
        for r in rows:
            assert r.engine == "tiled"
            assert r.finite                       # finite and > 0
            assert r.ticks == se.stats().ticks
            assert r.modeled_ns > 0
            assert r.measured_total_ns >= r.measured_ns
        report = obs.format_report(rows)
        assert "tiled" in report and "ratio" in report

    def test_crosscheck_requires_session_or_tracer(self, model):
        cfg, params, prompts = model
        with obs.session() as tel:
            _, se = _serve_tokens(
                cfg, params, prompts,
                HardwareTarget(engine="tiled", group_size=2),
            )
        # session over: explicit tracer still works, no session raises
        assert obs.crosscheck_serving(se, tracer=tel.tracer)
        with pytest.raises(ValueError, match="no active telemetry session"):
            obs.crosscheck_serving(se)

    def test_pricing_plan_public_accessor(self, model):
        cfg, params, _ = model
        cm = compiler_lib.compile(cfg, params, HardwareTarget(engine="wdm"))
        plan = cm.pricing_plan()
        assert plan is cm.pricing_plan()   # memoized
        assert plan.n_tiles > 0

    def test_crosscheck_ticks_widths(self, model):
        """Partially-admitted ticks price at their own width, clamped to
        the pool; one row aggregates each (engine, K)."""
        cfg, params, _ = model
        cm = compiler_lib.compile(
            cfg, params, HardwareTarget(engine="tiled", group_size=2)
        )
        plan = cm.pricing_plan()
        tr = Tracer(clock=FakeClock())
        for width in (1, 2, 2):
            with tr.span("decode_tick", engine="tiled", k=2, n_active=width):
                pass
        rows = obs.crosscheck_ticks(tr, plan, pool=2)
        assert len(rows) == 1
        r = rows[0]
        assert (r.engine, r.k, r.ticks) == ("tiled", 2, 3)
        assert r.n_active_mean == pytest.approx(5 / 3)
        assert r.finite
