"""The request scheduler: admission control, KV budgets, SLOs,
preemption, streaming — and the one invariant that matters: no policy,
budget, admission mode or preemption pattern may change a request's
generated tokens vs running it alone."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import compiler as compiler_lib
from repro.configs import get_smoke_config
from repro.models import lm as lm_lib
from repro.serving import (
    Request,
    RequestRejectedError,
    RequestScheduler,
    RequestStatus,
    SchedulerConfig,
    SchedulerConfigError,
    SchedulerExhaustedError,
)

MAX_LEN = 64


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (n,), dtype=np.int32)
               for n in (5, 9, 7, 4)]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def compiled(model):
    cfg, params, _ = model
    return {
        name: compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine=name)
        )
        for name in ("reference", "wdm")
    }


@pytest.fixture(scope="module")
def solo(model, compiled):
    """Per-request reference generations: each alone in a 1-slot pool."""
    _, _, prompts = model
    out = {}
    for name, cm in compiled.items():
        for i, p in enumerate(prompts):
            se = cm.serve(max_batch=1, max_len=MAX_LEN)
            st = se.submit(Request(rid=i, prompt=p, max_new_tokens=8))
            se.drain()
            out[(name, i)] = list(st.generated)
    return out


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


class TestConfig:
    def test_bad_policy(self):
        with pytest.raises(SchedulerConfigError, match="policy"):
            SchedulerConfig(policy="lifo").validate()

    def test_bad_admission(self):
        with pytest.raises(SchedulerConfigError, match="admission"):
            SchedulerConfig(admission="eager").validate()

    def test_bad_reserve(self):
        with pytest.raises(SchedulerConfigError, match="kv_reserve_ratio"):
            SchedulerConfig(kv_reserve_ratio=1.5).validate()

    def test_bad_max_waiting(self):
        with pytest.raises(SchedulerConfigError, match="max_waiting"):
            SchedulerConfig(max_waiting=0).validate()

    def test_validated_at_serve(self, compiled):
        with pytest.raises(SchedulerConfigError):
            compiled["reference"].serve(
                max_batch=2, scheduler=SchedulerConfig(policy="nope")
            )


# ---------------------------------------------------------------------------
# THE invariant: engine x policy x budget grid == solo generations
# ---------------------------------------------------------------------------


GRID = [
    ("reference", SchedulerConfig()),
    ("reference", SchedulerConfig(policy="deadline")),
    ("reference", SchedulerConfig(admission="partial")),
    # usable = floor(2*64*0.16) = 20: two growing requests overflow
    # mid-decode, forcing budget preemption + bit-exact resume
    ("reference", SchedulerConfig(admission="partial", kv_reserve_ratio=0.84)),
    ("wdm", SchedulerConfig()),
    ("wdm", SchedulerConfig(admission="partial", kv_reserve_ratio=0.84)),
]


@pytest.mark.parametrize("engine,config", GRID)
def test_scheduled_equals_solo(engine, config, model, compiled, solo):
    _, _, prompts = model
    se = compiled[engine].serve(max_batch=2, max_len=MAX_LEN, scheduler=config)
    states = [se.submit(Request(rid=i, prompt=p, max_new_tokens=8))
              for i, p in enumerate(prompts)]
    done = se.drain()
    assert len(done) == len(prompts)
    for st in states:
        assert st.status is RequestStatus.FINISHED
        assert st.generated == solo[(engine, st.rid)], (
            f"{engine}/{config.policy}/{config.admission}: scheduling "
            f"changed request {st.rid}'s output"
        )


def test_oversubscribed_load_drains_without_deadlock(model, compiled, solo):
    """4x more requests than slots under a tight partial budget: every
    request completes, preemptions happen, nothing deadlocks."""
    _, _, prompts = model
    cfg = SchedulerConfig(admission="partial", kv_reserve_ratio=0.84)
    se = compiled["reference"].serve(max_batch=2, max_len=MAX_LEN, scheduler=cfg)
    states = [se.submit(Request(rid=i, prompt=prompts[i % len(prompts)],
                                max_new_tokens=8))
              for i in range(8)]
    se.drain(max_ticks=500)
    assert all(st.status is RequestStatus.FINISHED for st in states)
    for st in states:
        assert st.generated == solo[("reference", st.rid % len(prompts))]
    stats = se.stats().scheduler
    assert stats.finished == 8 and stats.preempted > 0
    assert stats.preempted == stats.resumed  # every victim came back


# ---------------------------------------------------------------------------
# admission control edge cases
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_zero_budget_rejects_gracefully(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(
            max_batch=2, max_len=MAX_LEN,
            scheduler=SchedulerConfig(kv_reserve_ratio=1.0),
        )
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
        assert st.status is RequestStatus.REJECTED
        assert "usable budget" in st.reject_reason
        assert se.idle() and se.stats().scheduler.rejected == 1

    def test_whole_admission_rejects_oversized_request(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0],
                               max_new_tokens=10 * MAX_LEN))
        # kv_need clamps at the slot, so this still fits (finishes early
        # on cache exhaustion) — but a prompt past the slot cannot
        assert st.status is RequestStatus.WAITING
        long = np.arange(MAX_LEN, dtype=np.int32)
        st2 = se.submit(Request(rid=1, prompt=long, max_new_tokens=2))
        assert st2.status is RequestStatus.REJECTED
        assert "slot" in st2.reject_reason

    def test_invalid_token_budget_rejected(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=0))
        assert st.status is RequestStatus.REJECTED

    def test_queue_depth_cap(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(
            max_batch=1, max_len=MAX_LEN,
            scheduler=SchedulerConfig(max_waiting=2),
        )
        states = [se.submit(Request(rid=i, prompt=prompts[0], max_new_tokens=4))
                  for i in range(3)]
        assert [s.status for s in states] == [
            RequestStatus.WAITING, RequestStatus.WAITING, RequestStatus.REJECTED,
        ]
        assert "queue full" in states[2].reject_reason

    def test_whole_admission_never_preempts_for_budget(self, model, compiled):
        """Whole admission commits the full need up front, so the budget
        can never overcommit — no preemptions at equal priority."""
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=2, max_len=MAX_LEN)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=8))
        se.drain()
        assert se.stats().scheduler.preempted == 0


# ---------------------------------------------------------------------------
# SLOs: deadlines + priorities
# ---------------------------------------------------------------------------


class TestSLO:
    def test_deadline_expiry_mid_decode(self, model, compiled, solo):
        """A running request past its deadline is EXPIRED with a partial
        output that is a strict prefix of the solo generation."""
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=20,
                               deadline_ticks=3))
        done = se.drain()
        assert st.status is RequestStatus.EXPIRED
        assert done == [st]
        ref = solo[("reference", 0)]
        assert 0 < len(st.generated) < 20
        assert st.generated == ref[: len(st.generated)]
        assert se.stats().scheduler.expired == 1

    def test_deadline_expiry_while_waiting(self, model, compiled):
        """A queued request starves behind a long one and times out
        without ever taking a slot — graceful, not silent."""
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        long = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=12))
        short = se.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=4,
                                  deadline_ticks=2))
        se.drain()
        assert long.status is RequestStatus.FINISHED
        assert short.status is RequestStatus.EXPIRED
        assert short.generated == [] and short.admitted_tick is None

    def test_deadline_policy_orders_queue(self, model, compiled, solo):
        """Under the deadline policy, a later-submitted but tighter
        request is admitted first (EDF), yet outputs stay solo-exact."""
        _, _, prompts = model
        se = compiled["reference"].serve(
            max_batch=1, max_len=MAX_LEN,
            scheduler=SchedulerConfig(policy="deadline", preempt=False),
        )
        loose = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                                  deadline_ticks=100))
        tight = se.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=4,
                                  deadline_ticks=30))
        se.drain()
        assert tight.admitted_tick < loose.admitted_tick
        assert loose.generated == solo[("reference", 0)][:4]
        assert tight.generated == solo[("reference", 1)][:4]

    def test_priority_preempts_and_resumes_bit_exact(self, model, compiled, solo):
        """A high-priority arrival evicts the running low-priority
        request mid-decode; the victim resumes in a fresh slot and still
        produces byte-identical output."""
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        lo = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=8,
                               priority=0))
        se.step()
        se.step()   # lo is mid-decode
        hi = se.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=8,
                               priority=5))
        se.drain()
        assert lo.preemptions >= 1
        assert hi.admitted_tick == hi.submit_tick  # preempted its way in
        assert lo.generated == solo[("reference", 0)]
        assert hi.generated == solo[("reference", 1)]
        s = se.stats()
        assert s.evictions >= 1 and s.restores >= 1

    def test_equal_priority_never_preempts(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        a = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=6))
        se.step()
        b = se.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=6,
                              priority=0))
        se.drain()
        assert a.preemptions == 0 and b.preemptions == 0
        assert a.finish_tick <= b.finish_tick  # FIFO at equal priority

    def test_mixed_priority_fairness(self, model, compiled, solo):
        """High priority jumps the queue, low priority still completes
        (no starvation), both solo-exact."""
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        states = [
            se.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=6,
                              priority=i % 2))
            for i in range(4)
        ]
        se.drain()
        assert all(st.status is RequestStatus.FINISHED for st in states)
        # odd rids (priority 1) admitted before even rids behind them
        assert states[3].admitted_tick <= states[2].admitted_tick
        for st in states:
            assert st.generated == solo[("reference", st.rid)][:6]


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


class TestStreaming:
    def test_callback_ordering(self, model, compiled, solo):
        """on_token fires once per token, in order, with a running
        index, even across queueing and slot reuse."""
        _, _, prompts = model
        events = []
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        states = [
            se.submit(Request(
                rid=i, prompt=prompts[i], max_new_tokens=5,
                on_token=lambda rid, tok, idx: events.append((rid, tok, idx)),
            ))
            for i in range(3)
        ]
        se.drain()
        for i in range(3):
            mine = [(t, idx) for rid, t, idx in events if rid == i]
            assert [idx for _, idx in mine] == list(range(5))
            assert [t for t, _ in mine] == states[i].generated
            assert mine == list(zip(solo[("reference", i)][:5], range(5)))

    def test_stream_iterator(self, model, compiled, solo):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=2, max_len=MAX_LEN)
        toks = list(se.stream(Request(rid=0, prompt=prompts[0],
                                      max_new_tokens=8)))
        assert toks == solo[("reference", 0)]

    def test_stream_rejection_raises(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(
            max_batch=1, max_len=MAX_LEN,
            scheduler=SchedulerConfig(kv_reserve_ratio=1.0),
        )
        with pytest.raises(RequestRejectedError, match="rejected"):
            list(se.stream(Request(rid=0, prompt=prompts[0], max_new_tokens=4)))


# ---------------------------------------------------------------------------
# drain hardening + typed stats
# ---------------------------------------------------------------------------


class TestDrain:
    def test_exhaustion_error_carries_budget_context(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        se.submit(Request(rid=7, prompt=prompts[0], max_new_tokens=50))
        with pytest.raises(
            SchedulerExhaustedError,
            match=r"did not drain.*\[7\].*queue_depth=.*kv_committed=",
        ):
            se.drain(max_ticks=2)

    def test_idle_drain_returns_immediately(self, model, compiled):
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        assert se.drain() == [] and se.idle()

    def test_run_to_completion_is_drain(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=1, max_len=MAX_LEN)
        st = se.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=3))
        assert se.run_to_completion() == [st]

    def test_stats_counters(self, model, compiled):
        _, _, prompts = model
        se = compiled["reference"].serve(max_batch=2, max_len=MAX_LEN)
        for i in range(3):
            se.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=4))
        se.drain()
        s = se.stats().scheduler
        assert s.submitted == 3 and s.finished == 3
        assert s.queue_depth == 0 and s.running == 0
        assert s.max_queue_depth >= 1          # third request queued
        assert s.kv_budget == 2 * MAX_LEN and s.kv_usable == s.kv_budget
        assert s.kv_committed == 0             # everything released
        assert s.ticks_to_first_token >= 0.0
        assert s.admission_wait_ticks >= 0.0


# ---------------------------------------------------------------------------
# scheduler over a fake pool: pure host-side logic, no model
# ---------------------------------------------------------------------------


class FakePool:
    """Deterministic slot pool: token t for request r is 1000*r + t."""

    def __init__(self, n_slots=2, slot_capacity=32):
        self.n_slots = n_slots
        self.slot_capacity = slot_capacity
        self._free = set(range(n_slots))
        self.pos = [0] * n_slots
        self.state = [None] * n_slots   # (rid, tokens emitted)

    @property
    def free_slots(self):
        return len(self._free)

    def acquire_slot(self):
        s = min(self._free)
        self._free.remove(s)
        return s

    def release_slot(self, slot):
        self.pos[slot] = 0
        self.state[slot] = None
        self._free.add(slot)

    def prefill_into(self, slot, st):
        self.pos[slot] = st.request.prompt_len
        self.state[slot] = st.rid
        st.emit(1000 * st.rid + len(st.generated))

    def decode_tick(self, running):
        for slot, st in running.items():
            st.emit(1000 * st.rid + len(st.generated))
            self.pos[slot] += 1

    def slot_exhausted(self, slot):
        return self.pos[slot] + 1 >= self.slot_capacity

    def evict_slot(self, slot):
        from repro.serving import SlotSnapshot

        snap = SlotSnapshot(pos=self.pos[slot], tok=0, rows=self.state[slot])
        self.release_slot(slot)
        return snap

    def restore_slot(self, slot, snap):
        self.pos[slot] = snap.pos
        self.state[slot] = snap.rows


def test_fifo_order_on_fake_pool():
    pool = FakePool(n_slots=1)
    sched = RequestScheduler(pool)
    prompts = [np.arange(3, dtype=np.int32)] * 3
    states = [sched.submit(Request(rid=i, prompt=p, max_new_tokens=3))
              for i, p in enumerate(prompts)]
    sched.drain()
    # strict FIFO through one slot; tokens follow the deterministic rule
    assert [s.rid for s in sorted(states, key=lambda s: s.finish_tick)] == [0, 1, 2]
    for s in states:
        assert s.generated == [1000 * s.rid, 1000 * s.rid + 1, 1000 * s.rid + 2]


def test_partial_budget_reconcile_never_starves_fake_pool():
    # capacity 8, 2 slots, usable floor(16*0.5)=8: two prompt-5 requests
    # cannot coexist for long — reconcile must keep exactly one moving
    pool = FakePool(n_slots=2, slot_capacity=8)
    sched = RequestScheduler(
        pool, SchedulerConfig(admission="partial", kv_reserve_ratio=0.5)
    )
    states = [sched.submit(Request(rid=i, prompt=np.arange(5, dtype=np.int32),
                                   max_new_tokens=3))
              for i in range(2)]
    sched.drain(max_ticks=50)
    assert all(s.status is RequestStatus.FINISHED for s in states)
