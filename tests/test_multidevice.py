"""Multi-device behaviour (shard_map pipeline, compressed all-reduce,
mini dry-run) — run in subprocesses with XLA_FLAGS forcing 8 host
devices, so the main test process keeps its single-device view.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# every test spawns a subprocess with 8 forced host devices (minutes
# each on CPU): nightly/full CI only (the tier1 job deselects `slow`)
pytestmark = pytest.mark.slow

ROOT = Path(__file__).parent.parent


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=480,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_gpipe_matches_sequential():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.pipeline import gpipe, stage_split

        mesh = make_test_mesh((4,), ("pod",))
        n_stages, layers_per_stage, d = 4, 2, 16

        key = jax.random.key(0)
        ws = jax.random.normal(key, (n_stages, layers_per_stage, d, d)) * 0.3

        def stage_fn(sp, x):
            for i in range(layers_per_stage):
                x = jnp.tanh(x @ sp[i])
            return x

        x = jax.random.normal(jax.random.key(1), (8, d))  # 4 microbatches of 2
        pipelined = gpipe(stage_fn, mesh=mesh, axis="pod", n_microbatches=4)
        y = jax.jit(pipelined)(ws, x)

        ref = x
        for s in range(n_stages):
            ref = stage_fn(ws[s], ref)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
        print("GPIPE_OK")
    """)


def test_gpipe_gradients_flow():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.pipeline import gpipe

        mesh = make_test_mesh((4,), ("pod",))
        d = 8
        ws = jax.random.normal(jax.random.key(0), (4, d, d)) * 0.3
        x = jax.random.normal(jax.random.key(1), (8, d))

        def stage_fn(sp, h):
            return jnp.tanh(h @ sp)

        pipe = gpipe(stage_fn, mesh=mesh, axis="pod", n_microbatches=4)
        def loss_pipe(ws): return jnp.sum(pipe(ws, x) ** 2)
        def loss_seq(ws):
            h = x
            for s in range(4): h = stage_fn(ws[s], h)
            return jnp.sum(h ** 2)
        g_pipe = jax.jit(jax.grad(loss_pipe))(ws)
        g_seq = jax.grad(loss_seq)(ws)
        np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq), atol=1e-4)
        print("GPIPE_GRAD_OK")
    """)


def test_compressed_all_reduce_shard_map():
    _run("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.compression import compressed_all_reduce_mean, ef_init

        mesh = make_test_mesh((8,), ("data",))
        per_rank = jax.random.normal(jax.random.key(0), (8, 32))  # rank r owns row r

        @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")), check_rep=False)
        def reduce(g, ef):
            out, ef2 = compressed_all_reduce_mean({"g": g}, {"g": ef}, "data")
            return out["g"], ef2["g"]

        ef = jnp.zeros((8, 32))
        got, ef2 = jax.jit(reduce)(per_rank, ef)
        want = jnp.mean(per_rank, axis=0)
        # int8 wire: loose tolerance; every rank must agree exactly
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want), atol=0.05)
        np.testing.assert_allclose(np.asarray(got), np.tile(np.asarray(got[0]), (8,1)), atol=1e-7)
        print("CAR_OK")
    """)


def test_mini_dryrun_all_cell_kinds():
    """lower+compile every cell kind on a (2,4) mesh with a smoke config
    — the same steps.build_cell plumbing the 512-device dry-run uses."""
    _run("""
        import jax
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import lower_cell
        from repro.models.config import ShapeConfig
        from repro.launch import hlo_analysis

        mesh = make_test_mesh((2, 4), ("data", "model"))
        shapes = [
            ShapeConfig("t", 64, 4, "train"),
            ShapeConfig("p", 64, 4, "prefill"),
            ShapeConfig("d", 64, 4, "decode"),
        ]
        for arch in ("tinyllama-1.1b", "qwen3-moe-235b-a22b", "mamba2-2.7b",
                     "seamless-m4t-large-v2", "internvl2-1b", "jamba-1.5-large-398b"):
            cfg = get_smoke_config(arch)
            for sh in shapes:
                lowered = lower_cell(cfg, sh, mesh)
                compiled = lowered.compile()
                rec = hlo_analysis.analyze_compiled(compiled, mesh.size)
                assert rec["flops_per_dev"] > 0, (arch, sh.name)
                print(arch, sh.name, "ok", f"{rec['flops_per_dev']:.2e}")
        print("MINI_DRYRUN_OK")
    """, devices=8)


def test_elastic_restore_across_meshes():
    """Checkpoint sharded on a (2,4) mesh restores onto (4,2) and (8,)
    meshes — the elastic-scaling path."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_test_mesh

        root = tempfile.mkdtemp()
        mesh_a = make_test_mesh((2, 4), ("data", "model"))
        w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
        w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        mgr = CheckpointManager(root)
        mgr.save(3, {"w": w_a})

        for shape, axes, spec in (
            ((4, 2), ("data", "model"), P("data", "model")),
            ((8,), ("data",), P("data")),
        ):
            mesh_b = make_test_mesh(shape, axes)
            sh = NamedSharding(mesh_b, spec)
            got, extra = mgr.restore({"w": w}, shardings={"w": sh})
            assert extra["step"] == 3
            assert got["w"].sharding == sh
            np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(w))
        print("ELASTIC_OK")
    """)


def test_ep_moe_matches_pjit_reference():
    """Hand-written shard_map EP dispatch == the pjit moe_ffn at
    drop-free capacity (same params, same routing)."""
    _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.distributed.ep import ep_moe_ffn
        from repro.launch.mesh import make_test_mesh
        from repro.models import moe as moe_lib

        mesh = make_test_mesh((8,), ("model",))
        cfg = dataclasses.replace(
            get_smoke_config("qwen3-moe-235b-a22b"),  # 8 experts top-4 smoke
            moe_capacity_factor=64.0,                  # drop-free
        )
        params = moe_lib.moe_init(jax.random.key(0), cfg)
        x = (jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model)) * 0.5
             ).astype(jnp.bfloat16)

        ref, aux_ref = moe_lib.moe_ffn(params, x, cfg)
        got, aux = jax.jit(
            lambda p, x: ep_moe_ffn(p, x, cfg, mesh)
        )(params, x)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            atol=3e-2, rtol=3e-2,
        )
        # gradients flow through the all_to_alls
        def loss(p):
            y, _ = ep_moe_ffn(p, x, cfg, mesh)
            return jnp.sum(y.astype(jnp.float32) ** 2)
        g = jax.jit(jax.grad(loss))(params)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
        assert float(jnp.abs(g["w1"]).max()) > 0
        print("EP_OK")
    """)
