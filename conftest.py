import sys
from pathlib import Path

# make `src/repro` importable and tests/proptest.py reachable from test files
ROOT = Path(__file__).parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))
