import sys
from pathlib import Path

import pytest

# make `src/repro` importable and tests/proptest.py reachable from test files
ROOT = Path(__file__).parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu: compiled Pallas path — needs a real TPU backend. The default "
        "CPU run executes all kernels in interpret mode instead, so the "
        "plain `pytest` gate is meaningful on any machine.",
    )
    config.addinivalue_line(
        "markers",
        "slow: long-running (multi-minute training loops, subprocess-spawning "
        "drivers, forced-multi-device runs). CI's tier1 job deselects these "
        'with -m "not slow and not tpu"; the nightly full job runs everything.',
    )


def pytest_collection_modifyitems(config, items):
    import jax

    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(
        reason="requires a TPU backend; CPU runs cover the same kernels in "
        "Pallas interpret mode"
    )
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)
