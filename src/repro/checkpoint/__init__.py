"""Fault-tolerant checkpointing (atomic writes, async snapshots,
mesh-agnostic restore)."""

from repro.checkpoint.manager import (
    CheckpointManager,
    CorruptCheckpointError,
    restore_tree,
    save_tree,
)
