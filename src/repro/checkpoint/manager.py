"""Checkpointing for 1000-node posture.

Design decisions:

* **Atomicity**: every snapshot is written to ``step_XXXX.tmp-<pid>``
  and ``os.replace``d into place — a job killed mid-write never corrupts
  the latest checkpoint, and ``latest_step()`` only ever sees complete
  snapshots (a marker file is written last inside the directory).
* **Async**: ``save_async`` snapshots device arrays to host
  (jax.device_get — a synchronization point, cheap relative to a step)
  then hands serialization to a daemon thread, overlapping disk I/O
  with subsequent training steps. ``wait()`` joins before the next save
  or shutdown.
* **Mesh-agnostic restore**: arrays are stored with their tree paths in
  a flat ``.npz`` (+ msgpack manifest of paths/dtypes/shapes). Restore
  takes an optional target-sharding pytree and ``jax.device_put``s each
  leaf onto it — restoring a 512-chip checkpoint onto 256 chips (or a
  differently-factored mesh) is the elastic-scaling path and is tested.
* **Retention**: keep the newest ``keep`` snapshots, delete older ones
  (never the one being written).

No orbax dependency: the container is offline; this is a complete,
self-contained implementation.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"
_MARKER = "COMPLETE"


class CorruptCheckpointError(RuntimeError):
    """A checkpoint directory carries the COMPLETE marker but its
    manifest or array payload does not load — on-disk corruption (bit
    rot, truncated copy, concurrent writer). Named so restore callers
    can distinguish 'no checkpoint' (FileNotFoundError) from 'a
    checkpoint that must not be trusted'."""


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save_tree(path: str, tree: Any, extra: dict | None = None) -> None:
    """Atomic snapshot of a pytree into directory ``path``."""
    tmp = f"{path}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "keys": list(flat.keys()),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    # Overwrite atomically: rename the old snapshot ASIDE first, then
    # rename the complete tmp dir INTO place, then drop the old one.
    # At no instant does `path` name a partially-deleted or
    # partially-written snapshot (the pre-PR 9 rmtree-then-replace had
    # a window where a crash left NO checkpoint at all). The aside dir
    # never shadows a real snapshot: steps() requires an int suffix.
    old = f"{path}.old-{os.getpid()}"
    shutil.rmtree(old, ignore_errors=True)  # stale aside from a crash
    if os.path.isdir(path):
        os.replace(path, old)
    os.replace(tmp, path)
    shutil.rmtree(old, ignore_errors=True)


def restore_tree(path: str, like: Any, shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; optionally device_put onto
    ``shardings`` (same pytree structure, or a single sharding)."""
    if not os.path.exists(os.path.join(path, _MARKER)):
        raise FileNotFoundError(f"no complete checkpoint at {path}")
    manifest_path = os.path.join(path, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CorruptCheckpointError(
            f"checkpoint at {path} is marked complete but its manifest "
            f"({manifest_path}) does not load: {e}"
        ) from e
    npz_path = os.path.join(path, "arrays.npz")
    try:
        data = np.load(npz_path)
    except (OSError, ValueError) as e:
        raise CorruptCheckpointError(
            f"checkpoint at {path} is marked complete but its array "
            f"payload ({npz_path}) does not load: {e}"
        ) from e
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = SEP.join(_path_str(p) for p in path_keys)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(jax.tree.structure(like), leaves)
    if shardings is not None:
        if isinstance(shardings, jax.sharding.Sharding):
            tree = jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
        else:
            tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest["extra"]


class CheckpointManager:
    """Directory layout: ``<root>/step_<n>/{arrays.npz,manifest.json,COMPLETE}``."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # -- discovery ---------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.root, name, _MARKER)
            ):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        save_tree(self._dir(step), tree, dict(extra or {}, step=step))
        self._gc()

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        """Snapshot to host now, write to disk on a daemon thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            save_tree(self._dir(step), host_tree, dict(extra or {}, step=step))
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -------------------------------------------------------------
    def restore(
        self, like: Any, step: int | None = None, shardings: Any | None = None
    ) -> tuple[Any, dict]:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_tree(self._dir(step), like, shardings)

    # -- retention -----------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)
