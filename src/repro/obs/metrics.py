"""Counters, gauges and bounded histograms with a Prometheus-style
text snapshot.

The metrics registry is the aggregate half of the telemetry subsystem
(:mod:`repro.obs`): where the tracer records *each* tick, the registry
keeps distributions and running totals — TTFT, admission wait, tick
latency, queue depth, cache hits — cheap enough to leave on for a whole
serving run and render at the end:

    reg = MetricsRegistry()
    reg.counter("repro_ticks_total", "decode ticks").inc()
    reg.histogram("repro_tick_latency_seconds", "tick wall time")\\
       .labels(engine="wdm", k=4).observe(0.0012)
    print(reg.render())          # Prometheus text exposition format

Design constraints (mirroring the zero-dependency premise):

* **Bounded**: a histogram is a fixed bucket vector + count + sum —
  observing a million ticks costs the same memory as observing ten.
* **Labeled**: every instrument supports ``.labels(engine="wdm")``
  child series, keyed by sorted (name, value) tuples, so one metric
  covers an engine x K grid without string formatting on the hot path.
* **Deterministic render**: metrics and series print sorted, so golden
  tests can compare the full exposition text.
"""

from __future__ import annotations

import math

# default latency buckets (seconds): 100us .. 10s, roughly log-spaced
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
    0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

# small-integer buckets (ticks, queue depths)
TICK_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Instrument:
    """Shared label plumbing: an instrument is a family of child series
    keyed by label tuples; the bare instrument is the unlabeled child."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, object] = {}

    def labels(self, **labels):
        key = _label_key(labels)
        child = self._series.get(key)
        if child is None:
            child = self._new_child()
            self._series[key] = child
        return child

    def _child(self):
        return self.labels()

    def _new_child(self):  # pragma: no cover - abstract
        raise NotImplementedError

    def _render_series(self, key: tuple, child) -> list[str]:  # pragma: no cover
        raise NotImplementedError

    def render(self) -> list[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        for key in sorted(self._series):
            lines.extend(self._render_series(key, self._series[key]))
        return lines


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n


class Counter(_Instrument):
    """Monotonic running total."""

    kind = "counter"

    def _new_child(self) -> _CounterChild:
        return _CounterChild()

    def inc(self, n: float = 1.0) -> None:
        self._child().inc(n)

    @property
    def value(self) -> float:
        """Sum across every labeled series."""
        return sum(c.value for c in self._series.values())

    def _render_series(self, key, child) -> list[str]:
        return [f"{self.name}{_label_str(key)} {_num(child.value)}"]


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Gauge(_Instrument):
    """Point-in-time value (queue depth, running slots, KV commitment)."""

    kind = "gauge"

    def _new_child(self) -> _GaugeChild:
        return _GaugeChild()

    def set(self, v: float) -> None:
        self._child().set(v)

    def inc(self, n: float = 1.0) -> None:
        self._child().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._child().dec(n)

    @property
    def value(self) -> float:
        return self._child().value

    def _render_series(self, key, child) -> list[str]:
        return [f"{self.name}{_label_str(key)} {_num(child.value)}"]


class _HistogramChild:
    __slots__ = ("buckets", "counts", "total", "sum")

    def __init__(self, buckets: tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * len(buckets)   # per-bucket (non-cumulative)
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.total += 1
        self.sum += v
        for i, bound in enumerate(self.buckets):
            if v <= bound:
                self.counts[i] += 1
                return
        # beyond the last bound: lands only in the implicit +Inf bucket

    def cumulative(self) -> list[int]:
        """Prometheus-style cumulative counts per ``le`` bound (without
        the trailing +Inf, which equals ``total``)."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the
        bucket holding the q-th observation; +inf if it lies past the
        last bound)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.total == 0:
            return 0.0
        rank = q * self.total
        for bound, cum in zip(self.buckets, self.cumulative()):
            if cum >= rank:
                return bound
        return math.inf


class Histogram(_Instrument):
    """Bounded-bucket distribution (fixed memory, any observation count)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(
                f"histogram buckets must be sorted, unique and non-empty, "
                f"got {buckets!r}"
            )
        self.buckets = tuple(float(b) for b in buckets)

    def _new_child(self) -> _HistogramChild:
        return _HistogramChild(self.buckets)

    def observe(self, v: float) -> None:
        self._child().observe(v)

    @property
    def total(self) -> int:
        return sum(c.total for c in self._series.values())

    def _render_series(self, key, child) -> list[str]:
        lines = []
        for bound, cum in zip(child.buckets, child.cumulative()):
            labels = _label_str(key + (("le", _num(bound)),))
            lines.append(f"{self.name}_bucket{labels} {cum}")
        inf_labels = _label_str(key + (("le", "+Inf"),))
        lines.append(f"{self.name}_bucket{inf_labels} {child.total}")
        lines.append(f"{self.name}_sum{_label_str(key)} {_num(child.sum)}")
        lines.append(f"{self.name}_count{_label_str(key)} {child.total}")
        return lines


def _num(v: float) -> str:
    """Render 4.0 as "4" but keep real fractions — Prometheus accepts
    both; the short form keeps golden outputs readable."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


class MetricsRegistry:
    """Named instruments, memoized by name, rendered sorted.

    ``counter``/``gauge``/``histogram`` create-or-return, so
    instrumentation sites can call them unconditionally; re-registering
    a name as a different kind is a hard error (two call sites fighting
    over one metric name is a bug worth surfacing).
    """

    def __init__(self):
        self._metrics: dict[str, _Instrument] = {}

    def _get(self, cls, name: str, help: str, **kw):
        inst = self._metrics.get(name)
        if inst is None:
            inst = cls(name, help, **kw)
            self._metrics[name] = inst
        elif not isinstance(inst, cls):
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {cls.kind}"
            )
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Instrument | None:
        return self._metrics.get(name)

    def render(self) -> str:
        """The Prometheus text exposition snapshot (deterministic)."""
        lines = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + ("\n" if lines else "")

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.render())
