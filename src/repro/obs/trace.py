"""Nestable tracing spans with JSON-lines and Chrome-trace export.

The tracer is the wall-clock half of the telemetry subsystem
(:mod:`repro.obs`): host code wraps a unit of work in
``with tracer.span("decode_tick", engine="wdm", k=4) as sp`` and the
tracer records when it ran, how long it took, how deep it nested and
whatever structured attributes the instrumentation attached. Two export
formats cover the two consumers:

* :meth:`Tracer.export_jsonl` — one JSON object per record, the
  machine-readable event log (crosscheck, benchmarks, ad-hoc grep).
* :meth:`Tracer.export_chrome` — the Chrome trace-event format, loadable
  in ``chrome://tracing`` / Perfetto for a visual timeline of compile
  stages and serving ticks.

Async-dispatch honesty: JAX returns before device work finishes, so a
naive span around a jitted call measures only the dispatch. A span
therefore accepts **fences** — ``sp.fence(logits)`` registers a pytree
that the tracer passes to ``jax.block_until_ready`` *before* stamping
the span's end time, so the device work is actually inside the span.
Fencing only happens on an enabled tracer: the :class:`NullTracer`'s
span ignores ``fence`` entirely, so disabled telemetry adds **no host
synchronization** to the hot path (and no timestamps, no allocation —
one shared no-op span object is returned).

The clock is injectable (``Tracer(clock=...)``) so golden-output tests
are deterministic; the default is ``time.perf_counter_ns``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable


@dataclasses.dataclass
class Span:
    """One traced unit of work (open until the ``with`` block exits)."""

    name: str
    track: str                   # timeline row ("compile", "serve", ...)
    t_start_ns: int              # tracer-relative start
    depth: int                   # nesting depth at entry (0 = top level)
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    t_end_ns: int | None = None  # stamped at exit, after fences drain
    _fences: list[Any] = dataclasses.field(default_factory=list, repr=False)

    def set(self, **attrs) -> "Span":
        """Attach structured attributes (merged over the entry attrs)."""
        self.attrs.update(attrs)
        return self

    def fence(self, *values) -> "Span":
        """Register pytrees to ``block_until_ready`` before the end
        timestamp — the span then covers the device work it dispatched,
        not just the host-side enqueue."""
        self._fences.extend(values)
        return self

    @property
    def duration_ns(self) -> int:
        if self.t_end_ns is None:
            raise ValueError(f"span {self.name!r} has not exited yet")
        return self.t_end_ns - self.t_start_ns

    @property
    def duration_s(self) -> float:
        return self.duration_ns * 1e-9


@dataclasses.dataclass(frozen=True)
class Event:
    """One instantaneous record (request lifecycle transitions etc.)."""

    name: str
    track: str
    t_ns: int
    attrs: dict[str, Any]


class _NullSpan:
    """The shared no-op span: disabled tracing costs one attribute
    lookup and a context-manager protocol round trip, nothing else."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def fence(self, *values) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled recorder: every call is a no-op, ``span`` returns
    the shared :data:`NULL_SPAN` (no allocation, no clock read, no
    ``block_until_ready``)."""

    enabled = False

    def span(self, name: str, *, track: str = "main", **attrs) -> _NullSpan:
        return NULL_SPAN

    def event(self, name: str, *, track: str = "main", **attrs) -> None:
        return None


class _OpenSpan:
    """Context manager binding one :class:`Span` to its tracer stack."""

    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span

    def __enter__(self) -> Span:
        self.tracer._stack.append(self.span)
        return self.span

    def __exit__(self, *exc) -> bool:
        sp = self.span
        if sp._fences:
            import jax

            jax.block_until_ready(sp._fences)
            sp._fences.clear()
        sp.t_end_ns = self.tracer._now()
        self.tracer._stack.pop()
        self.tracer.records.append(sp)
        return False


class Tracer:
    """Records nestable spans and instant events on a relative clock.

    ``records`` holds finished spans and events in completion order —
    a child span lands before its parent, matching Chrome-trace
    expectations. Open spans live on a stack; ``depth`` is the nesting
    level at entry.
    """

    enabled = True

    def __init__(self, clock: Callable[[], int] | None = None):
        self._clock = clock or time.perf_counter_ns
        self._t0 = self._clock()
        self._stack: list[Span] = []
        self.records: list[Span | Event] = []

    def _now(self) -> int:
        return self._clock() - self._t0

    def span(self, name: str, *, track: str = "main", **attrs) -> _OpenSpan:
        """Open a span; use as ``with tracer.span("x", k=4) as sp``."""
        return _OpenSpan(
            self,
            Span(
                name=name,
                track=track,
                t_start_ns=self._now(),
                depth=len(self._stack),
                attrs=dict(attrs),
            ),
        )

    def event(self, name: str, *, track: str = "main", **attrs) -> None:
        """Record one instantaneous event."""
        self.records.append(
            Event(name=name, track=track, t_ns=self._now(), attrs=dict(attrs))
        )

    # -- queries -------------------------------------------------------------

    def spans(self, name: str | None = None) -> list[Span]:
        out = [r for r in self.records if isinstance(r, Span)]
        return out if name is None else [s for s in out if s.name == name]

    def events(self, name: str | None = None) -> list[Event]:
        out = [r for r in self.records if isinstance(r, Event)]
        return out if name is None else [e for e in out if e.name == name]

    # -- export --------------------------------------------------------------

    def to_records(self) -> list[dict]:
        """Plain-dict view of every record (the JSONL rows)."""
        rows = []
        for r in self.records:
            if isinstance(r, Span):
                rows.append({
                    "type": "span",
                    "name": r.name,
                    "track": r.track,
                    "ts_us": r.t_start_ns / 1e3,
                    "dur_us": r.duration_ns / 1e3,
                    "depth": r.depth,
                    "attrs": r.attrs,
                })
            else:
                rows.append({
                    "type": "event",
                    "name": r.name,
                    "track": r.track,
                    "ts_us": r.t_ns / 1e3,
                    "attrs": r.attrs,
                })
        return rows

    def export_jsonl(self, path: str) -> int:
        """One JSON object per line; returns the record count."""
        rows = self.to_records()
        with open(path, "w") as f:
            for row in rows:
                f.write(json.dumps(row, default=str) + "\n")
        return len(rows)

    def to_chrome_trace(self) -> dict:
        """The Chrome trace-event document (``chrome://tracing`` /
        Perfetto): complete ("X") events for spans, instant ("i") for
        events, one named thread per track."""
        tids: dict[str, int] = {}

        def tid(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids)
            return tids[track]

        trace_events: list[dict] = []
        for r in self.records:
            if isinstance(r, Span):
                trace_events.append({
                    "name": r.name, "ph": "X", "pid": 0, "tid": tid(r.track),
                    "ts": r.t_start_ns / 1e3, "dur": r.duration_ns / 1e3,
                    "args": dict(r.attrs),
                })
            else:
                trace_events.append({
                    "name": r.name, "ph": "i", "s": "t", "pid": 0,
                    "tid": tid(r.track), "ts": r.t_ns / 1e3,
                    "args": dict(r.attrs),
                })
        meta = [
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": n,
             "args": {"name": track}}
            for track, n in tids.items()
        ]
        return {"traceEvents": meta + trace_events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> int:
        """Write the Chrome-trace JSON; returns the span+event count."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1, default=str)
        return len(self.records)
