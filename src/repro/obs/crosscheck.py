"""Measured-vs-modeled pricing cross-check.

The cost model (:mod:`repro.core.costmodel`) prices every decode tick
analytically — the paper's ~154x TacitMap and ~3113x EinsteinBarrier
claims are exactly such step-count prices — but until PR 8 nothing ever
compared those predictions against what the host measures. This module
is that fidelity check: it pairs each **traced** decode tick (the
``decode_tick`` spans the serving engine records, wall-clock fenced
with ``block_until_ready``) with its ``scheduled_decode_tick`` /
``plan_decode_tick`` modeled price and reports the measured/modeled
ratio per engine x K.

The ratio is NOT expected to be ~1 on a host simulator: the model
prices the *photonic crossbar* (nanosecond readout) while the
measurement times a JAX emulation of it — what the ratio buys is a
*consistent* fidelity trajectory (finite, positive, comparable across
PRs) and a structural check that modeled cost actually scales the way
the measured tick does across engine x K.

    rows = crosscheck_serving(se)          # after a traced serve run
    print(format_report(rows))
"""

from __future__ import annotations

import dataclasses
import statistics


@dataclasses.dataclass(frozen=True)
class TickCheck:
    """Measured-vs-modeled decode-tick pricing for one (engine, K)."""

    engine: str
    k: int
    ticks: int                   # traced decode ticks aggregated
    n_active_mean: float         # mean admitted width across those ticks
    measured_ns: float           # median measured tick wall time
    measured_total_ns: float     # summed measured wall time
    modeled_ns: float            # modeled latency of the median tick
    modeled_total_ns: float      # summed modeled latency (per-tick widths)
    ratio: float                 # measured_total / modeled_total

    @property
    def finite(self) -> bool:
        import math

        return math.isfinite(self.ratio) and self.ratio > 0.0


def crosscheck_ticks(tracer, plan, pool: int) -> list[TickCheck]:
    """Pair a tracer's ``decode_tick`` spans with the cost model.

    Every span is priced at ITS admitted width through
    :func:`repro.core.costmodel.scheduled_decode_tick` (which wraps
    ``plan_decode_tick`` at that width), so partially-admitted ticks
    are compared against what they actually issued, not the full pool.
    Returns one row per (engine, K), sorted.
    """
    from repro.core import costmodel

    groups: dict[tuple[str, int], list] = {}
    for sp in tracer.spans("decode_tick"):
        key = (str(sp.attrs.get("engine", "?")), int(sp.attrs.get("k", 1)))
        groups.setdefault(key, []).append(sp)

    params = costmodel.params_for_spec(plan.spec)
    rows = []
    for (engine, k), spans in sorted(groups.items()):
        measured = [sp.duration_ns for sp in spans]
        widths = [min(int(sp.attrs.get("n_active", 1)), pool) for sp in spans]
        modeled = [
            costmodel.scheduled_decode_tick(plan, w, pool, params=params).latency_ns
            for w in widths
        ]
        modeled_total = sum(modeled)
        measured_total = float(sum(measured))
        rows.append(TickCheck(
            engine=engine,
            k=k,
            ticks=len(spans),
            n_active_mean=sum(widths) / len(widths),
            measured_ns=float(statistics.median(measured)),
            measured_total_ns=measured_total,
            modeled_ns=float(statistics.median(modeled)),
            modeled_total_ns=float(modeled_total),
            ratio=measured_total / modeled_total if modeled_total > 0 else float("inf"),
        ))
    return rows


def crosscheck_serving(se, tracer=None) -> list[TickCheck]:
    """Cross-check a serving engine's traced ticks against its compiled
    target's pricing plan (the bound mapping plan when the target has
    one, else the plan ``CompiledModel.price()`` compiles lazily on the
    target's spec/policy). ``tracer`` defaults to the active telemetry
    session's."""
    if tracer is None:
        from repro import obs

        tel = obs.active()
        if tel is None:
            raise ValueError(
                "no active telemetry session and no tracer passed — start "
                "one with repro.obs.start() before serving, or pass the "
                "Tracer that recorded the decode_tick spans"
            )
        tracer = tel.tracer
    plan = se.compiled.pricing_plan()
    return crosscheck_ticks(tracer, plan, pool=se.max_batch)


def format_report(rows: list[TickCheck]) -> str:
    """The printable measured-vs-modeled table."""
    lines = [
        f"{'engine':>10s} {'K':>3s} {'ticks':>6s} {'width':>6s} "
        f"{'measured_us':>12s} {'modeled_ns':>11s} {'ratio':>10s}"
    ]
    for r in rows:
        lines.append(
            f"{r.engine:>10s} {r.k:3d} {r.ticks:6d} {r.n_active_mean:6.1f} "
            f"{r.measured_ns * 1e-3:12.1f} {r.modeled_ns:11.1f} "
            f"{r.ratio:10.1f}"
        )
    lines.append(
        "(ratio = summed measured wall / summed modeled latency; the host "
        "emulates nanosecond photonics, so >>1 is expected — the value is "
        "the trajectory, not the level)"
    )
    return "\n".join(lines)
