"""Zero-dependency telemetry for the serving stack: tracing spans,
hardware/serving counters, and measured-vs-modeled pricing.

Three pieces, threaded through every layer (compiler pipeline, serving
engine, request scheduler, weight caches, launch drivers):

* :mod:`repro.obs.trace`      — nestable wall-clock spans with
  ``block_until_ready`` fencing, JSON-lines + Chrome-trace export.
* :mod:`repro.obs.metrics`    — counters / gauges / bounded histograms
  (TTFT, admission wait, tick latency, queue depth) rendered as a
  Prometheus-style text snapshot.
* :mod:`repro.obs.crosscheck` — pairs traced decode ticks with their
  ``costmodel`` prices: the measured/modeled ratio per engine x K.

**Off by default, near-zero when off.** Instrumentation sites call the
module-level helpers (:func:`span`, :func:`event`, :func:`observe`,
:func:`count`, :func:`cache_event`); with no active session each is one
``None`` check returning a shared no-op object — no clock reads, no
allocation and, critically, **no host synchronization** added to the
decode hot path (fences only drain on an enabled tracer). Telemetry
never changes generated tokens: tracing on vs off is bit-identical
(tests/test_obs.py gates it across the engine grid).

Usage::

    from repro import obs

    tel = obs.start()                      # enable for this process
    compiled = compile(cfg, params, target)   # compile-stage spans
    se = compiled.serve(max_batch=8)
    ...                                    # per-tick spans + metrics
    tel.tracer.export_chrome("trace.json")    # chrome://tracing
    print(tel.metrics.render())               # Prometheus snapshot
    print(obs.crosscheck.format_report(obs.crosscheck_serving(se)))
    obs.stop()

or scoped: ``with obs.session() as tel: ...``.
"""

from __future__ import annotations

import contextlib
from typing import Callable

from repro.obs import crosscheck  # noqa: F401
from repro.obs.crosscheck import (  # noqa: F401
    TickCheck,
    crosscheck_serving,
    crosscheck_ticks,
    format_report,
)
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_BUCKETS,
    TICK_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import (  # noqa: F401
    NULL_SPAN,
    Event,
    NullTracer,
    Span,
    Tracer,
)


class Telemetry:
    """One telemetry session: a tracer and a metrics registry that live
    and die together (started by :func:`start` / :func:`session`)."""

    def __init__(self, clock: Callable[[], int] | None = None):
        self.tracer = Tracer(clock=clock)
        self.metrics = MetricsRegistry()

    def write(self, *, trace_out: str | None = None,
              jsonl_out: str | None = None,
              metrics_out: str | None = None) -> None:
        """Export whichever artifacts were requested."""
        if trace_out:
            self.tracer.export_chrome(trace_out)
        if jsonl_out:
            self.tracer.export_jsonl(jsonl_out)
        if metrics_out:
            self.metrics.export(metrics_out)


_ACTIVE: Telemetry | None = None


def active() -> Telemetry | None:
    """The current session, or ``None`` when telemetry is off."""
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


def start(clock: Callable[[], int] | None = None) -> Telemetry:
    """Begin a telemetry session (replacing any previous one)."""
    global _ACTIVE
    _ACTIVE = Telemetry(clock=clock)
    return _ACTIVE


def stop() -> Telemetry | None:
    """End the session; returns it so callers can still export."""
    global _ACTIVE
    tel, _ACTIVE = _ACTIVE, None
    return tel


@contextlib.contextmanager
def session(clock: Callable[[], int] | None = None):
    """Scoped telemetry: ``with obs.session() as tel: ...``."""
    tel = start(clock=clock)
    try:
        yield tel
    finally:
        if _ACTIVE is tel:
            stop()


# ---------------------------------------------------------------------------
# Hot-path helpers — each is one None check when telemetry is off.
# ---------------------------------------------------------------------------


def span(name: str, *, track: str = "main", **attrs):
    """Open a span on the active tracer (shared no-op span when off)."""
    if _ACTIVE is None:
        return NULL_SPAN
    return _ACTIVE.tracer.span(name, track=track, **attrs)


def event(name: str, *, track: str = "main", **attrs) -> None:
    """Record an instantaneous event on the active tracer."""
    if _ACTIVE is not None:
        _ACTIVE.tracer.event(name, track=track, **attrs)


def count(name: str, n: float = 1.0, help: str = "", **labels) -> None:
    """Increment a counter on the active registry."""
    if _ACTIVE is not None:
        c = _ACTIVE.metrics.counter(name, help)
        (c.labels(**labels) if labels else c).inc(n)


def gauge_set(name: str, value: float, help: str = "", **labels) -> None:
    """Set a gauge on the active registry."""
    if _ACTIVE is not None:
        g = _ACTIVE.metrics.gauge(name, help)
        (g.labels(**labels) if labels else g).set(value)


def observe(name: str, value: float, help: str = "",
            buckets: tuple[float, ...] = DEFAULT_BUCKETS, **labels) -> None:
    """Observe into a histogram on the active registry."""
    if _ACTIVE is not None:
        h = _ACTIVE.metrics.histogram(name, help, buckets=buckets)
        (h.labels(**labels) if labels else h).observe(value)


def cache_event(cache: str, kind: str, n: int = 1) -> None:
    """Live cache counters (WeightCache / placement LRUs hook this on
    every hit/miss/eviction; one None check when telemetry is off)."""
    if _ACTIVE is not None:
        _ACTIVE.metrics.counter(
            "repro_cache_events_total",
            "prepared-weight and placement cache traffic",
        ).labels(cache=cache, kind=kind).inc(n)
