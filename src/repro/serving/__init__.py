"""Batched serving: a request scheduler (admission control, KV budget,
SLOs, preemption) in front of a slot-pool engine whose decode ticks are
grouped into WDM-style K-groups."""

from repro.serving.engine import (
    BatchPlanner,
    GroupPlan,
    LegacyServingSignatureError,
    ServingEngine,
    ServingStats,
)
from repro.serving.scheduler import (
    DegradedServiceError,
    Request,
    RequestRejectedError,
    RequestScheduler,
    RequestState,
    RequestStatus,
    SchedulerConfig,
    SchedulerConfigError,
    SchedulerExhaustedError,
    SchedulerStats,
    SlotSnapshot,
)

__all__ = [
    "BatchPlanner",
    "DegradedServiceError",
    "GroupPlan",
    "LegacyServingSignatureError",
    "Request",
    "RequestRejectedError",
    "RequestScheduler",
    "RequestState",
    "RequestStatus",
    "SchedulerConfig",
    "SchedulerConfigError",
    "SchedulerExhaustedError",
    "SchedulerStats",
    "ServingEngine",
    "ServingStats",
    "SlotSnapshot",
]
