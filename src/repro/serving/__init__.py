"""Batched serving engine (continuous batching over a slot cache)."""

from repro.serving.engine import Request, ServingEngine
