"""Batched serving: a request scheduler (admission control, KV budget,
SLOs, preemption) in front of a slot-pool engine whose decode ticks are
grouped into WDM-style K-groups.

Two entry points, one contract (bit-exact generations):

* **Single replica** — ``CompiledModel.serve()`` returns a
  :class:`ServingEngine` (slot pool + jitted prefill/decode dispatches)
  fronted by its :class:`RequestScheduler`; clients drive
  ``submit``/``step``/``drain``/``stream`` and read typed
  :class:`ServingStats`. Fault-injecting targets get a
  :class:`~repro.faults.monitor.HealthMonitor` automatically.
* **Fleet** — :class:`repro.fleet.FleetEngine` stands up N of those
  replicas behind a KV-prefix-affinity router and exposes the SAME
  client loop one level up, adding prefix-grafted admission
  (:class:`~repro.serving.scheduler.PrefixGraft` rows skip re-prefilling
  a shared prefix) and failover off degraded replicas. Single-replica
  serving never pays for the fleet layer — ``repro.fleet`` imports this
  package, not the other way around.

:class:`SlotSnapshot` is the portability primitive both share: KV rows
snapshotted on one engine restore bit-exactly into any engine compiled
from the same :class:`~repro.compiler.HardwareTarget` (prefill rows are
prompt-length-invariant and cache layouts are target-determined), which
is what lets the fleet salvage preempted work across replicas.
"""

from repro.serving.engine import (
    BatchPlanner,
    GroupPlan,
    LegacyServingSignatureError,
    ServingEngine,
    ServingStats,
)
from repro.serving.scheduler import (
    DegradedServiceError,
    PrefixGraft,
    Request,
    RequestRejectedError,
    RequestScheduler,
    RequestState,
    RequestStatus,
    SchedulerConfig,
    SchedulerConfigError,
    SchedulerExhaustedError,
    SchedulerStats,
    SlotSnapshot,
)

__all__ = [
    "BatchPlanner",
    "DegradedServiceError",
    "GroupPlan",
    "LegacyServingSignatureError",
    "PrefixGraft",
    "Request",
    "RequestRejectedError",
    "RequestScheduler",
    "RequestState",
    "RequestStatus",
    "SchedulerConfig",
    "SchedulerConfigError",
    "SchedulerExhaustedError",
    "SchedulerStats",
    "ServingEngine",
    "ServingStats",
    "SlotSnapshot",
]
