"""Batched serving engine (continuous batching over a slot cache,
decode ticks grouped into WDM-style K-groups)."""

from repro.serving.engine import BatchPlanner, GroupPlan, Request, ServingEngine
