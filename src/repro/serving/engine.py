"""Continuous-batching serving engine with WDM-style K-group decode.

The paper's accelerator streams independent inference requests through
resident weights (WDM multiplexes K of them onto one crossbar pass);
the LM serving analogue is continuous batching: a fixed pool of
KV-cache slots that requests join and leave independently, with the
active slots grouped into K-groups so ONE ``Engine.binary_mmm``
registry call serves a whole tick.

Design:

* **Slot cache**: caches allocated once at (max_batch, max_len);
  requests claim a free slot, prefill writes their prompt KV into it,
  decode advances the active slots with per-slot positions
  (``attention_decode_step`` takes a (B,) position vector), finished
  slots are freed and immediately reusable — no cache reallocation,
  fixed memory.
* **K-group batching** (:class:`BatchPlanner`): every tick the planner
  collects the active slots into groups of up to K and the engine runs
  one gathered decode over them. Inside the model, the binarized
  projections execute through a :class:`~repro.core.engine.GroupedEngine`
  — the whole tick's stacked activations go down as ONE
  ``binary_mmm(groups, w)`` call instead of one ``binary_vmm`` per
  slot. K is capability-aware: a compiled ``repro.mapping`` plan passed
  as ``mapping_plan=`` contributes its ``preferred_group_size()`` (the
  placed tile technology's WDM capacity) first; else ``native_mmm``
  backends (``wdm``) contribute their wavelength count via
  ``preferred_group_size()``; every other backend gets one vmap'd group
  spanning the pool. Ragged
  tails (active % K != 0) pad the last group by repeating a real slot
  (an idle comb line); pad lanes are computed and discarded.
* **Crossbar programming phase** (PR 4, moved into ``compile()`` PR 5):
  every binarized projection is compiled into the engine's resident
  form ONCE by the compiler pipeline (``lm.program_weights`` — mapped
  complement tiles, packed int32 words, gathered block stacks ...), so
  decode ticks trace zero weight-side transforms and stream only
  activations — the paper's Computation-In-Memory premise. The phase is
  counted in ``stats`` (``programmed`` instances, ``program_s`` wall
  time); a target with ``prepare_weights=False`` restores the per-tick
  re-programming path (the prepared-vs-raw benchmark baseline).
* **One-call construction** (PR 5): the engine/spec/plan/K/prepare
  knobs live in a :class:`repro.compiler.HardwareTarget`;
  ``compile(cfg, params, target).serve(max_batch=..., max_len=...)``
  replaces the old five-kwarg constructor (which survives as a
  deprecation shim routed through the same pipeline).
* **Per-slot KV-cache scatter**: gather, decode and the scatter of the
  group's cache rows back into the resident pool run as ONE fused
  compiled dispatch per tick. Pad lanes mirror a real slot (identical
  inputs, bit-identical updates), so the scatter is exact and free
  slots are never touched.
* **Greedy decoding** (argmax) — sampling is orthogonal to the engine.
* The invariant tested in tests/test_serving.py and
  tests/test_serving_groups.py: any interleaving of submissions, any
  group size and any execution backend produce byte-identical
  generations to running each request alone — continuous batching and
  K-grouping are semantically invisible.

This engine is CPU/TPU-agnostic pure JAX over the model zoo's
prefill/decode entry points (decoder-only archs incl. SSM/hybrid).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm as lm_lib

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    # filled by the engine:
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One tick's unit of work: active slots arranged into K-groups."""

    slots: tuple[int, ...]        # real active slots, in slot order
    k: int                        # group size (wavelengths per group)

    @property
    def n_active(self) -> int:
        return len(self.slots)

    @property
    def n_groups(self) -> int:
        """Crossbar MMM activations this tick costs per projection —
        the decode tick count in hardware-step terms (ceil(active/K))."""
        return math.ceil(self.n_active / self.k)

    @property
    def n_lanes(self) -> int:
        return self.n_groups * self.k

    @property
    def n_pad(self) -> int:
        """Idle wavelengths: lanes in the ragged tail carrying no slot."""
        return self.n_lanes - self.n_active

    def gather_indices(self) -> np.ndarray:
        """(n_lanes,) slot indices for the gathered decode batch; the
        ragged tail repeats the last real slot (outputs discarded)."""
        idx = np.empty((self.n_lanes,), np.int32)
        idx[: self.n_active] = self.slots
        idx[self.n_active:] = self.slots[-1]
        return idx


class BatchPlanner:
    """Collects active slots into WDM-style K-groups each tick.

    The contract (documented in ROADMAP.md §Serving batching): given
    the set of active slots, produce a :class:`GroupPlan` whose lanes
    are a static multiple of K — ceil(active/K) groups, ragged tail
    padded — or ``None`` when nothing is active. The serving engine
    issues one gathered decode per plan; a future multi-device serving
    path shards *groups* (not slots) across devices from the same plan.
    """

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"group size must be >= 1, got {k}")
        self.k = int(k)

    def plan(self, active_slots: list[int]) -> GroupPlan | None:
        if not active_slots:
            return None
        return GroupPlan(slots=tuple(sorted(active_slots)), k=self.k)


class ServingEngine:
    """Continuous batching over a :class:`repro.compiler.CompiledModel`.

    The one-call construction is ``compile(cfg, params, target).serve()``
    (or equivalently ``ServingEngine(compiled_model)``): the compiler
    pipeline has already mapped, validated and programmed the target, so
    serving just binds the slot pool. The legacy multi-knob signature
    ``ServingEngine(cfg, params, engine=..., group_size=...,
    mapping_plan=..., prepare_weights=...)`` survives as a deprecation
    shim that builds the equivalent :class:`~repro.compiler.HardwareTarget`
    — new code should construct the target itself.
    """

    def __init__(
        self,
        model,
        params: Any = None,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        engine: str | None = None,
        group_size: int | None = None,
        mapping_plan=None,
        prepare_weights: bool = True,
    ):
        from repro import compiler as compiler_lib

        if isinstance(model, compiler_lib.CompiledModel):
            if (
                params is not None
                or engine is not None
                or mapping_plan is not None
                or group_size is not None
                or prepare_weights is not True
            ):
                raise TypeError(
                    "pass EITHER a CompiledModel (the target already fixed "
                    "engine/plan/K/prepare_weights at compile time) OR "
                    "(cfg, params) with the legacy knobs"
                )
            compiled = model
        else:
            # deprecation shim: the pre-compiler wiring, re-expressed as
            # a HardwareTarget run through the one canonical pipeline
            if engine is not None or group_size or mapping_plan is not None:
                warnings.warn(
                    "ServingEngine(cfg, params, engine=/group_size=/"
                    "mapping_plan=) is deprecated; build a "
                    "repro.compiler.HardwareTarget and pass "
                    "compile(cfg, params, target) (or call its .serve())",
                    DeprecationWarning,
                    stacklevel=2,
                )
            compiled = compiler_lib.compile(
                model,
                params,
                compiler_lib.HardwareTarget(
                    engine=engine or "reference",
                    group_size=group_size or None,
                    prepare_weights=prepare_weights,
                ),
                plan=mapping_plan,
            )
        self.compiled = compiled
        cfg = compiled.cfg
        self.cfg = cfg
        self.params = compiled.params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mapping_plan = compiled.plan

        # K-group sizing: explicit target K > mapping plan's WDM
        # capacity > engine capability > one vmap'd group (one policy
        # for every consumer: engine_lib.resolve_group_size, applied by
        # the compiled model)
        self.group_k = compiled.group_size_for(max_batch)
        self.planner = BatchPlanner(self.group_k)
        self._exec = compiled.executor(max_batch)
        self.stats = {
            "ticks": 0,           # gathered decode launches
            "decoded": 0,         # real slot-tokens decoded (slot-at-a-time steps)
            "mmm_groups": 0,      # K-groups issued to a registry backend
                                  # (crossbar MMM steps/projection; 0 when
                                  # the plain-jnp path executes instead)
            "pad_lanes": 0,       # idle wavelengths from ragged tails
            "prefills": 0,
            # crossbar programming happened in compile(): every
            # binarized projection is resident in the backend's prepared
            # form, so decode ticks trace zero weight-side transforms
            "programmed": compiled.programmed,
            "program_s": compiled.program_s,
        }

        self.caches = lm_lib.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros((max_batch,), np.int32)        # next write position
        self.tok = np.zeros((max_batch,), np.int32)        # last emitted token
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []

        self._prefill = jax.jit(
            lambda p, t: lm_lib.prefill(p, t, cfg, engine=self._exec)
        )

        def gathered_decode(p, tok, pos, caches, idx):
            # gather -> decode -> per-slot scatter, fused into ONE
            # compiled dispatch per tick (specializes on the lane count:
            # at most ceil(max_batch/K) distinct shapes, reused
            # steady-state). Pad lanes mirror a real slot and therefore
            # compute bit-identical updates, so scattering every lane is
            # exact; slots outside `idx` are never touched.
            gathered = jax.tree.map(lambda c: jnp.take(c, idx, axis=1), caches)
            logits, new_c = lm_lib.decode_step(
                p, tok[idx], pos[idx], gathered, cfg, engine=self._exec
            )
            caches = jax.tree.map(
                lambda dst, src: dst.at[:, idx].set(src.astype(dst.dtype)),
                caches,
                new_c,
            )
            return logits, caches

        # the cache pytree (argnum 3 in both decode entry points) is
        # DONATED: tick N's caches update in place instead of being
        # copied. `step()` immediately rebinds `self.caches` to the
        # returned pytree, so the consumed input is never reused.
        self._decode = jax.jit(gathered_decode, donate_argnums=(3,))
        # identity-plan fast path: with the whole pool active and no pad
        # lanes the gather/scatter is the identity — skip the two
        # O(pool * max_len) cache copies and decode in place
        self._decode_full = jax.jit(
            lambda p, tok, pos, c: lm_lib.decode_step(
                p, tok, pos, c, cfg, engine=self._exec
            ),
            donate_argnums=(3,),
        )

    # -- client API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slot_req)

    def cache_stats(self) -> dict[str, dict[str, int]]:
        """Hit/miss counters from the bound engine's caches (weight
        cache, tiled placement caches); ``{}`` on the plain-jnp path."""
        if self._exec is None or not hasattr(self._exec, "cache_stats"):
            return {}
        return self._exec.cache_stats()

    # -- internals ------------------------------------------------------------
    def _graft(self, slot: int, pre_caches: Any, prompt_len: int) -> None:
        """Write one request's prompt KV/states into its slot."""

        def one(dst, src):
            if dst.ndim == 5 and src.ndim == 5 and dst.shape[2] >= src.shape[2]:
                # attn KV (L, B, T, KV, D): batch row `slot`, first T rows
                return dst.at[:, slot, : src.shape[2]].set(src[:, 0].astype(dst.dtype))
            # SSM conv/state (L, B, ...): replace the whole row
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

        self.caches = jax.tree.map(one, self.caches, pre_caches)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pre = self._prefill(self.params, prompt)
            self._graft(slot, pre, prompt.shape[1])
            first = int(jnp.argmax(logits[0]))
            req.generated.append(first)
            self.slot_req[slot] = req
            self.pos[slot] = len(req.prompt)
            self.tok[slot] = first
            self.stats["prefills"] += 1

    def step(self) -> list[Request]:
        """Admit queued requests, run one K-grouped decode tick over the
        active slots; returns requests that finished this tick."""
        self._admit()
        active = [s for s in range(self.max_batch) if self.slot_req[s] is not None]
        plan = self.planner.plan(active)
        if plan is None:
            return []

        # one fused dispatch: gather the plan's lanes (active slots +
        # ragged-tail repeats), decode, scatter the KV rows back; with
        # the whole pool active the plan is the identity and the decode
        # runs in place
        if plan.n_active == self.max_batch and plan.n_pad == 0:
            logits, self.caches = self._decode_full(
                self.params, jnp.asarray(self.tok), jnp.asarray(self.pos), self.caches
            )
        else:
            logits, self.caches = self._decode(
                self.params,
                jnp.asarray(self.tok),
                jnp.asarray(self.pos),
                self.caches,
                jnp.asarray(plan.gather_indices()),
            )
        n = plan.n_active
        self.stats["ticks"] += 1
        self.stats["decoded"] += plan.n_active
        # K-groups actually issued to a registry backend; the plain-jnp
        # path (no engine) executes no binary_mmm, so its reduction is
        # not reported as a measurement
        if self._exec is not None:
            self.stats["mmm_groups"] += plan.n_groups
        self.stats["pad_lanes"] += plan.n_pad

        nxt = np.asarray(jnp.argmax(logits[:n], axis=-1), np.int32)
        finished = []
        for lane, slot in enumerate(plan.slots):
            req = self.slot_req[slot]
            req.generated.append(int(nxt[lane]))
            self.pos[slot] += 1
            self.tok[slot] = nxt[lane]
            out_of_budget = len(req.generated) >= req.max_new_tokens
            out_of_cache = self.pos[slot] + 1 >= self.max_len
            if out_of_budget or out_of_cache:
                req.done = True
                finished.append(req)
                self.slot_req[slot] = None   # slot immediately reusable
                self.pos[slot] = 0
                self.tok[slot] = 0
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> list[Request]:
        """Drain queue + slots; raises on ``max_ticks`` exhaustion.

        The idle check runs *after* each tick (a tick both admits and
        decodes), so requests submitted after a previous drain — or
        mid-run between ticks — are picked up rather than spinning; and
        exhaustion raises with the stuck requests named instead of
        silently returning partial results.
        """
        out = []
        for _ in range(max_ticks):
            if self.idle():
                return out
            out += self.step()
            if self.idle():
                return out
        stuck = [r.rid for r in self.queue] + [
            r.rid for r in self.slot_req if r is not None
        ]
        raise RuntimeError(
            f"serving engine did not drain after {max_ticks} ticks; "
            f"undrained request ids: {stuck} "
            f"(queued={len(self.queue)}, active="
            f"{sum(r is not None for r in self.slot_req)})"
        )
