"""Continuous-batching serving engine.

The paper's accelerator streams independent inference requests through
resident weights (WDM multiplexes them onto one crossbar pass); the LM
serving analogue is continuous batching: a fixed pool of KV-cache slots
that requests join and leave independently, with ONE batched decode
step per tick regardless of how requests interleave.

Design:

* **Slot cache**: caches allocated once at (max_batch, max_len);
  requests claim a free slot, prefill writes their prompt KV into it,
  decode advances all active slots with per-slot positions
  (``attention_decode_step`` takes a (B,) position vector), finished
  slots are freed and immediately reusable — no recompilation, no
  cache reallocation, fixed memory.
* **Greedy decoding** (argmax) — sampling is orthogonal to the engine.
* **Inactive slots still compute** (SPMD-friendly: the batch shape is
  static); their outputs are masked. This is the standard accelerator
  trade: waste a little compute on empty slots, never reshape.
* The invariant tested in tests/test_serving.py: any interleaving of
  submissions produces byte-identical generations to running each
  request alone — continuous batching is semantically invisible.

This engine is CPU/TPU-agnostic pure JAX over the model zoo's
prefill/decode entry points (decoder-only archs incl. SSM/hybrid).

Binarized models (``cfg.quant == "bnn"``) can serve their hidden
projections through any execution backend registered in
``repro.core.engine`` (``engine="packed"`` routes prefill and every
decode tick through the bit-packed XNOR+popcount Pallas kernel) — all
backends are bit-exact, so continuous batching stays semantically
invisible regardless of the backend.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm as lm_lib
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    # filled by the engine:
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        engine: str | None = None,
    ):
        if engine is not None and engine != "reference":
            from repro.core import engine as engine_lib

            engine_lib.get_engine(engine)  # validate the name eagerly
            # a non-reference engine executes the binarized projections,
            # so it implies quant="bnn" (same contract as launch/serve.py
            # --engine); without this the flag would be a silent no-op
            cfg = dataclasses.replace(cfg, quant="bnn", bnn_engine=engine)
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.caches = lm_lib.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros((max_batch,), np.int32)        # next write position
        self.tok = np.zeros((max_batch,), np.int32)        # last emitted token
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []

        self._prefill = jax.jit(
            lambda p, t: lm_lib.prefill(p, t, cfg), static_argnums=()
        )
        self._decode = jax.jit(
            lambda p, t, pos, c: lm_lib.decode_step(p, t, pos, c, cfg)
        )

    # -- client API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slot_req)

    # -- internals ------------------------------------------------------------
    def _graft(self, slot: int, pre_caches: Any, prompt_len: int) -> None:
        """Write one request's prompt KV/states into its slot."""

        def one(dst, src):
            if dst.ndim == 5 and src.ndim == 5 and dst.shape[2] >= src.shape[2]:
                # attn KV (L, B, T, KV, D): batch row `slot`, first T rows
                return dst.at[:, slot, : src.shape[2]].set(src[:, 0].astype(dst.dtype))
            # SSM conv/state (L, B, ...): replace the whole row
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

        self.caches = jax.tree.map(one, self.caches, pre_caches)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pre = self._prefill(self.params, prompt)
            self._graft(slot, pre, prompt.shape[1])
            first = int(jnp.argmax(logits[0]))
            req.generated.append(first)
            self.slot_req[slot] = req
            self.pos[slot] = len(req.prompt)
            self.tok[slot] = first

    def step(self) -> list[Request]:
        """Admit queued requests, run one batched decode tick; returns
        requests that finished this tick."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return []
        logits, self.caches = self._decode(
            self.params,
            jnp.asarray(self.tok),
            jnp.asarray(self.pos),
            self.caches,
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        finished = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.generated.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.tok[slot] = nxt[slot]
            out_of_budget = len(req.generated) >= req.max_new_tokens
            out_of_cache = self.pos[slot] + 1 >= self.max_len
            if out_of_budget or out_of_cache:
                req.done = True
                finished.append(req)
                self.slot_req[slot] = None   # slot immediately reusable
                self.pos[slot] = 0
                self.tok[slot] = 0
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> list[Request]:
        out = []
        for _ in range(max_ticks):
            out += self.step()
            if self.idle():
                return out
        raise RuntimeError("serving engine did not drain")
