"""Scheduler-fronted continuous-batching serving engine.

The paper's accelerator streams independent inference requests through
resident weights (WDM multiplexes K of them onto one crossbar pass);
the LM serving analogue is continuous batching behind admission
control. The serving stack is two layers:

* :class:`ServingEngine` (this module) is the **slot pool executor**: a
  fixed pool of KV-cache slots over a
  :class:`repro.compiler.CompiledModel`, with K-group batched decode.
  It owns the caches, the jitted prefill/decode dispatches, and the
  per-slot state — and exposes the small pool surface the scheduler
  drives (``acquire_slot`` / ``prefill_into`` / ``decode_tick`` /
  ``evict_slot`` / ``restore_slot`` / ``release_slot``).
* :class:`repro.serving.scheduler.RequestScheduler` is the **request
  path** in front of it: waiting/running queues, FIFO + deadline
  policies, a KV-token budget with a reserve ratio, per-request SLOs
  (priority, ``deadline_ticks``) with graceful rejection, preemption
  back to waiting, and streaming token callbacks. Every client call on
  the engine — ``submit`` / ``step`` / ``drain`` / ``stream`` —
  delegates to its scheduler; ``run_to_completion`` survives as a thin
  wrapper over ``drain``.

The documented loop::

    compiled = repro.compiler.compile(cfg, params, target)
    se = compiled.serve(max_batch=8, max_len=256,
                        scheduler=SchedulerConfig(policy="deadline"))
    states = [se.submit(Request(rid=i, prompt=p, max_new_tokens=32))
              for i, p in enumerate(prompts)]
    se.drain()                      # or: se.step() per tick
    print(se.stats())               # one frozen ServingStats snapshot

Executor design (unchanged across the scheduler redesign):

* **Slot cache**: caches allocated once at (max_batch, max_len);
  requests claim a free slot, prefill writes their prompt KV into it,
  decode advances the active slots with per-slot positions
  (``attention_decode_step`` takes a (B,) position vector), finished
  slots are freed and immediately reusable — no cache reallocation,
  fixed memory.
* **K-group batching** (:class:`BatchPlanner`): every tick the planner
  collects the active slots into groups of up to K and the engine runs
  one gathered decode over them. Inside the model, the binarized
  projections execute through a :class:`~repro.core.engine.GroupedEngine`
  — the whole tick's stacked activations go down as ONE
  ``binary_mmm(groups, w)`` call instead of one ``binary_vmm`` per
  slot. K is capability-aware (mapping plan's WDM capacity > engine
  capability > one vmap'd group); ragged tails pad the last group by
  repeating a real slot (an idle comb line), pad lanes discarded.
* **Crossbar programming** happened in ``compile()`` (PR 4/5): every
  binarized projection is resident in the backend's prepared form, so
  decode ticks trace zero weight-side transforms — the paper's
  Computation-In-Memory premise. Counted in ``stats().programmed`` /
  ``.program_s``.
* **Per-slot KV-cache scatter**: gather, decode and the scatter of the
  group's cache rows back into the resident pool run as ONE fused
  compiled dispatch per tick; with the whole pool active the plan is
  the identity and decode runs in place (donated caches, zero copies).
* **Preemption snapshots**: evicting a slot copies its exact cache
  rows + position + last token out of the pool; restoring them into
  any free slot resumes greedy decode bit-identically — the mechanism
  behind the scheduler's budget/priority preemption.
* **Greedy decoding** (argmax) — sampling is orthogonal to the engine.

The invariant, tested in tests/test_serving.py /
tests/test_serving_groups.py / tests/test_scheduler.py: any
interleaving of submissions, any group size, any execution backend,
any scheduling policy and any preemption pattern produce byte-identical
generations to running each request alone — batching and scheduling
are semantically invisible.

The legacy multi-knob constructor
``ServingEngine(cfg, params, engine=..., group_size=...)`` (deprecated
in PR 5) is REMOVED: the only construction is from a
:class:`~repro.compiler.CompiledModel`, and old call sites get a
:class:`LegacyServingSignatureError` naming ``repro.compiler.compile``.

This engine is CPU/TPU-agnostic pure JAX over the model zoo's
prefill/decode entry points (decoder-only archs incl. SSM/hybrid).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm as lm_lib
from repro.serving.scheduler import (
    Request,
    RequestScheduler,
    RequestState,
    SchedulerConfig,
    SchedulerStats,
    SlotSnapshot,
)

Array = jax.Array


class LegacyServingSignatureError(TypeError):
    """The pre-compiler ``ServingEngine(cfg, params, engine=...)``
    signature was removed in PR 7; compile a target instead."""


@dataclasses.dataclass(frozen=True)
class ServingStats:
    """One frozen snapshot of the serving engine's counters.

    Replaces the PR 3-6 ad-hoc ``stats`` dict + ``cache_stats()`` pair:
    executor counters here, the request path nested as ``scheduler``,
    and the bound backend's cache hit/miss counters as ``caches``.
    """

    ticks: int                  # gathered decode launches
    decoded: int                # real slot-tokens decoded
    mmm_groups: int             # K-groups issued to a registry backend
    pad_lanes: int              # idle wavelengths from ragged tails
    prefills: int
    prefill_tokens: int         # prompt tokens actually prefilled
    grafted_tokens: int         # prompt tokens elided by prefix grafts
    evictions: int              # preemption snapshots taken
    restores: int               # snapshots grafted back into a slot
    programmed: int             # projections made resident in compile()
    program_s: float            # one-time programming wall time
    scheduler: SchedulerStats
    caches: dict[str, dict[str, int]]   # backend cache counters ({} on plain jnp)


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One tick's unit of work: active slots arranged into K-groups."""

    slots: tuple[int, ...]        # real active slots, in slot order
    k: int                        # group size (wavelengths per group)

    @property
    def n_active(self) -> int:
        return len(self.slots)

    @property
    def n_groups(self) -> int:
        """Crossbar MMM activations this tick costs per projection —
        the decode tick count in hardware-step terms (ceil(active/K))."""
        return math.ceil(self.n_active / self.k)

    @property
    def n_lanes(self) -> int:
        return self.n_groups * self.k

    @property
    def n_pad(self) -> int:
        """Idle wavelengths: lanes in the ragged tail carrying no slot."""
        return self.n_lanes - self.n_active

    def gather_indices(self) -> np.ndarray:
        """(n_lanes,) slot indices for the gathered decode batch; the
        ragged tail repeats the last real slot (outputs discarded)."""
        idx = np.empty((self.n_lanes,), np.int32)
        idx[: self.n_active] = self.slots
        idx[self.n_active:] = self.slots[-1]
        return idx


class BatchPlanner:
    """Collects active slots into WDM-style K-groups each tick.

    The contract (documented in ROADMAP.md §Serving batching): given
    the set of active slots, produce a :class:`GroupPlan` whose lanes
    are a static multiple of K — ceil(active/K) groups, ragged tail
    padded — or ``None`` when nothing is active. The serving engine
    issues one gathered decode per plan; a future multi-device serving
    path shards *groups* (not slots) across devices from the same plan.
    """

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"group size must be >= 1, got {k}")
        self.k = int(k)

    def plan(self, active_slots: list[int]) -> GroupPlan | None:
        if not active_slots:
            return None
        return GroupPlan(slots=tuple(sorted(active_slots)), k=self.k)


class ServingEngine:
    """Slot-pool executor + scheduler front over a
    :class:`repro.compiler.CompiledModel`.

    Construction is ``compile(cfg, params, target).serve()`` (or
    equivalently ``ServingEngine(compiled_model)``): the compiler
    pipeline has already mapped, validated and programmed the target,
    so serving just binds the slot pool and its request scheduler.
    """

    def __init__(
        self,
        compiled,
        *legacy_args,
        max_batch: int = 4,
        max_len: int = 256,
        scheduler: SchedulerConfig | None = None,
        **legacy_kwargs,
    ):
        from repro import compiler as compiler_lib

        if legacy_args or legacy_kwargs or not isinstance(
            compiled, compiler_lib.CompiledModel
        ):
            bad = sorted(legacy_kwargs) or ["positional params"]
            raise LegacyServingSignatureError(
                "the legacy ServingEngine(cfg, params, engine=/group_size=/"
                "mapping_plan=/prepare_weights=) signature was removed "
                f"(got: {', '.join(bad)}); build a repro.compiler."
                "HardwareTarget and pass repro.compiler.compile(cfg, "
                "params, target) — or call its .serve(max_batch=..., "
                "max_len=..., scheduler=SchedulerConfig(...))"
            )
        self.compiled = compiled
        cfg = compiled.cfg
        self.cfg = cfg
        self.params = compiled.params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mapping_plan = compiled.plan

        # K-group sizing: explicit target K > mapping plan's WDM
        # capacity > engine capability > one vmap'd group (one policy
        # for every consumer: engine_lib.resolve_group_size, applied by
        # the compiled model)
        self.group_k = compiled.group_size_for(max_batch)
        self.planner = BatchPlanner(self.group_k)
        self._exec = compiled.executor(max_batch)
        self.engine_name = compiled.target.engine
        self._counts = {
            "ticks": 0, "decoded": 0, "mmm_groups": 0, "pad_lanes": 0,
            "prefills": 0, "prefill_tokens": 0, "grafted_tokens": 0,
            "evictions": 0, "restores": 0,
        }

        # prefix grafting (PR 10): a continuation prefill slices cached
        # KV at a token boundary, which only attention mixers support
        # (SSM/hybrid state is recurrent) and only the token-prompt path
        # can hash (VLM prompts prepend frontend embeddings)
        self.supports_prefix_graft = (
            all(kind.mixer == "attn" for kind in cfg.pattern)
            and cfg.frontend != "vision"
        )
        # fleet hooks (PR 10): `prefill_observer(state, prompt_rows)` is
        # called after every prefill with the prompt's batch-squeezed
        # cache rows (the router's prefix-library feed); `on_degrade`
        # fires when the health monitor degrades this engine
        self.prefill_observer = None
        self.on_degrade = None

        self.caches = lm_lib.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros((max_batch,), np.int32)        # next write position
        self.tok = np.zeros((max_batch,), np.int32)        # last emitted token
        self._free = set(range(max_batch))

        self._build_dispatches()

        self.scheduler = RequestScheduler(self, scheduler)

        # fault tolerance (PR 9): a fault-injecting backend gets a
        # health monitor — sampled consistency sweeps, quarantine +
        # remap, K shrink on dead lanes, graceful degradation
        from repro.faults.engine import FaultyEngine
        from repro.faults.monitor import HealthMonitor

        self.health = (
            HealthMonitor(self)
            if isinstance(compiled.engine, FaultyEngine) else None
        )

    def _build_dispatches(self) -> None:
        """(Re)build the jitted prefill/decode dispatches around the
        CURRENT executor. Called at construction and by :meth:`_rebind`
        after a fault remap — the closures capture the executor by
        value, so stale jit caches can never serve a replaced engine."""
        cfg = self.cfg
        ex = self._exec

        self._prefill = jax.jit(
            lambda p, t: lm_lib.prefill(p, t, cfg, engine=ex)
        )
        # prefix-graft continuation: suffix tokens over donated prefix
        # rows, returning full-prompt-shaped caches (specializes on the
        # (prefix_len, suffix_len) pair — block-aligned grafts keep the
        # shape set small)
        self._prefill_cont = jax.jit(
            lambda p, t, pre: lm_lib.prefill_continue(p, t, pre, cfg, engine=ex)
        )

        def gathered_decode(p, tok, pos, caches, idx):
            # gather -> decode -> per-slot scatter, fused into ONE
            # compiled dispatch per tick (specializes on the lane count:
            # at most ceil(max_batch/K) distinct shapes, reused
            # steady-state). Pad lanes mirror a real slot and therefore
            # compute bit-identical updates, so scattering every lane is
            # exact; slots outside `idx` are never touched.
            gathered = jax.tree.map(lambda c: jnp.take(c, idx, axis=1), caches)
            logits, new_c = lm_lib.decode_step(
                p, tok[idx], pos[idx], gathered, cfg, engine=ex
            )
            caches = jax.tree.map(
                lambda dst, src: dst.at[:, idx].set(src.astype(dst.dtype)),
                caches,
                new_c,
            )
            return logits, caches

        # the cache pytree (argnum 3 in both decode entry points) is
        # DONATED: tick N's caches update in place instead of being
        # copied. `decode_tick()` immediately rebinds `self.caches` to
        # the returned pytree, so the consumed input is never reused.
        self._decode = jax.jit(gathered_decode, donate_argnums=(3,))
        # identity-plan fast path: with the whole pool active and no pad
        # lanes the gather/scatter is the identity — skip the two
        # O(pool * max_len) cache copies and decode in place
        self._decode_full = jax.jit(
            lambda p, tok, pos, c: lm_lib.decode_step(
                p, tok, pos, c, cfg, engine=ex
            ),
            donate_argnums=(3,),
        )

    def _rebind(self) -> None:
        """Resynchronize with the compiled model after it changed under
        us (fault remap re-placed the plan / dead lanes shrank K):
        refreshed params, a new K planner, and freshly traced
        dispatches over the new executor."""
        self.params = self.compiled.params
        self.group_k = self.compiled.group_size_for(self.max_batch)
        self.planner = BatchPlanner(self.group_k)
        self._exec = self.compiled.executor(self.max_batch)
        self._build_dispatches()

    # -- client API (delegates to the request scheduler) ---------------------

    def submit(self, request: Request) -> RequestState:
        """Enqueue a request; returns its (possibly REJECTED) state."""
        return self.scheduler.submit(request)

    def step(self) -> list[RequestState]:
        """One scheduling tick: expire/admit/preempt, then one K-grouped
        decode over the active slots; returns newly terminal states."""
        return self.scheduler.step()

    def drain(self, max_ticks: int = 10_000) -> list[RequestState]:
        """Step until idle; raises ``SchedulerExhaustedError`` (with
        queue-depth and budget context) on tick exhaustion."""
        return self.scheduler.drain(max_ticks)

    def run_to_completion(self, max_ticks: int = 10_000) -> list[RequestState]:
        """Thin wrapper over :meth:`drain` (the historical name)."""
        return self.drain(max_ticks)

    def stream(self, request: Request):
        """Submit and iterate the request's tokens as they decode."""
        return self.scheduler.stream(request)

    def idle(self) -> bool:
        return self.scheduler.idle()

    def stats(self) -> ServingStats:
        """One frozen snapshot: executor counters + nested scheduler
        stats + the bound backend's cache hit/miss counters."""
        backend = (
            self._exec.cache_stats()
            if self._exec is not None and hasattr(self._exec, "cache_stats")
            else {}
        )
        return ServingStats(
            **self._counts,
            programmed=self.compiled.programmed,
            program_s=self.compiled.program_s,
            scheduler=self.scheduler.stats(),
            caches=backend,
        )

    # -- slot-pool surface (driven by RequestScheduler) ----------------------

    @property
    def n_slots(self) -> int:
        return self.max_batch

    @property
    def slot_capacity(self) -> int:
        """KV rows one slot holds — the scheduler's budget unit."""
        return self.max_len

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire_slot(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot (scheduler admitted past the pool)")
        slot = min(self._free)
        self._free.remove(slot)
        return slot

    def release_slot(self, slot: int) -> None:
        self.pos[slot] = 0
        self.tok[slot] = 0
        self._free.add(slot)

    def prefill_into(self, slot: int, st: RequestState) -> None:
        """Run the request's prompt prefill and graft its KV into the
        slot; emits the first (argmax) token onto the state.

        A request carrying a :class:`~repro.serving.scheduler
        .PrefixGraft` (fleet prefix-affinity hit) skips recomputing the
        shared prefix: the donated rows stand in for positions
        ``[0, length)`` and only the suffix runs, through
        ``prefill_continue`` — bit-identical to the full prefill."""
        plen = st.request.prompt_len
        prompt = jnp.asarray(st.request.prompt, jnp.int32)[None, :]
        graft = st.request.prefix
        use_graft = (
            graft is not None and self.supports_prefix_graft
            and 0 < graft.length < plen
        )
        with obs.span(
            "prefill", track="serve", engine=self.engine_name,
            slot=slot, rid=st.request.rid, prompt_len=plen,
            grafted=graft.length if use_graft else 0,
        ) as sp:
            if use_graft:
                pre_rows = jax.tree.map(
                    lambda r: r[:, None, : graft.length], graft.rows
                )
                logits, pre = self._prefill_cont(
                    self.params, prompt[:, graft.length:], pre_rows
                )
                self._counts["grafted_tokens"] += graft.length
                self._counts["prefill_tokens"] += plen - graft.length
            else:
                logits, pre = self._prefill(self.params, prompt)
                self._counts["prefill_tokens"] += plen
            self._graft(slot, pre, plen)
            st.emit(int(jnp.argmax(logits[0])))
            sp.fence(self.caches)
        self.pos[slot] = plen
        self.tok[slot] = st.generated[-1]
        self._counts["prefills"] += 1
        if self.prefill_observer is not None:
            # full-prompt-shaped rows either way (continuation returns
            # prefix + suffix concatenated) — the fleet prefix library
            # extends its chains from grafted admissions too
            self.prefill_observer(st, jax.tree.map(lambda c: c[:, 0], pre))
        if obs.enabled():
            obs.observe(
                "repro_prefill_latency_seconds", sp.duration_s,
                "prompt prefill wall time (graft fenced)",
                engine=self.engine_name,
            )
            obs.count(
                "repro_prefills_total", 1, "prompt prefills run",
                engine=self.engine_name,
            )

    def slot_exhausted(self, slot: int) -> bool:
        """True when the next decode write would run off the slot."""
        return self.pos[slot] + 1 >= self.max_len

    def evict_slot(self, slot: int) -> SlotSnapshot:
        """Copy the slot's exact execution state out of the pool (the
        rows are materialized as NEW arrays, so later donated decode
        ticks cannot alias them) and free the slot."""
        rows = jax.tree.map(lambda c: jnp.array(c[:, slot]), self.caches)
        snap = SlotSnapshot(
            pos=int(self.pos[slot]), tok=int(self.tok[slot]), rows=rows,
            tick=self._counts["ticks"],
        )
        self.release_slot(slot)
        self._counts["evictions"] += 1
        return snap

    def restore_slot(self, slot: int, snap: SlotSnapshot) -> None:
        """Graft a preemption snapshot into a (possibly different) free
        slot. The full row is restored — including the stale region
        beyond ``pos``, which attention masks exactly as it does for a
        reused slot — so resumed decode is bit-identical."""
        self.caches = jax.tree.map(
            lambda dst, src: dst.at[:, slot].set(src.astype(dst.dtype)),
            self.caches,
            snap.rows,
        )
        self.pos[slot] = snap.pos
        self.tok[slot] = snap.tok
        self._counts["restores"] += 1

    def decode_tick(self, running: dict[int, RequestState]) -> None:
        """One K-grouped decode over the running slots: plan, one fused
        gather/decode/scatter dispatch, then emit each slot's token.

        With telemetry on (:mod:`repro.obs`), each tick records a fenced
        ``decode_tick`` span (engine, K, active/group/pad lanes, cache
        hit/miss deltas) plus tick-latency histogram and lane counters;
        with telemetry off the tick pays one ``None`` check and no extra
        host synchronization.
        """
        plan = self.planner.plan(list(running))
        if plan is None:
            return
        if not obs.enabled():
            self._run_tick(plan, running)
            if self.health is not None:
                self.health.after_tick()
            return
        before = self._cache_totals()
        with obs.span(
            "decode_tick", track="serve", engine=self.engine_name,
            k=plan.k, n_active=plan.n_active, n_groups=plan.n_groups,
            n_pad=plan.n_pad,
        ) as sp:
            self._run_tick(plan, running)
            sp.fence(self.caches)
            after = self._cache_totals()
            sp.set(
                cache_hits=after[0] - before[0],
                cache_misses=after[1] - before[1],
            )
        obs.observe(
            "repro_tick_latency_seconds", sp.duration_s,
            "K-grouped decode tick wall time (cache scatter fenced)",
            engine=self.engine_name, k=plan.k,
        )
        obs.count(
            "repro_decode_ticks_total", 1, "gathered decode launches",
            engine=self.engine_name,
        )
        obs.count(
            "repro_decoded_tokens_total", plan.n_active,
            "real slot-tokens decoded", engine=self.engine_name,
        )
        if self._exec is not None:
            obs.count(
                "repro_mmm_groups_total", plan.n_groups,
                "K-groups issued to a registry backend",
                engine=self.engine_name,
            )
        if plan.n_pad:
            obs.count(
                "repro_pad_lanes_total", plan.n_pad,
                "idle wavelengths from ragged tails",
                engine=self.engine_name,
            )
        if self.health is not None:
            self.health.after_tick()

    def _cache_totals(self) -> tuple[int, int]:
        """(hits, misses) summed over the backend's caches — the span's
        per-tick delta source (only read with telemetry on)."""
        if self._exec is None or not hasattr(self._exec, "cache_stats"):
            return (0, 0)
        stats = self._exec.cache_stats().values()
        return (
            sum(s.get("hits", 0) for s in stats),
            sum(s.get("misses", 0) for s in stats),
        )

    def _run_tick(self, plan: GroupPlan, running: dict[int, RequestState]) -> None:
        """The tick body: fused dispatch, counters, token emission."""
        if plan.n_active == self.max_batch and plan.n_pad == 0:
            logits, self.caches = self._decode_full(
                self.params, jnp.asarray(self.tok), jnp.asarray(self.pos), self.caches
            )
        else:
            logits, self.caches = self._decode(
                self.params,
                jnp.asarray(self.tok),
                jnp.asarray(self.pos),
                self.caches,
                jnp.asarray(plan.gather_indices()),
            )
        n = plan.n_active
        self._counts["ticks"] += 1
        self._counts["decoded"] += n
        # K-groups actually issued to a registry backend; the plain-jnp
        # path (no engine) executes no binary_mmm, so its reduction is
        # not reported as a measurement
        if self._exec is not None:
            self._counts["mmm_groups"] += plan.n_groups
        self._counts["pad_lanes"] += plan.n_pad

        nxt = np.asarray(jnp.argmax(logits[:n], axis=-1), np.int32)
        for lane, slot in enumerate(plan.slots):
            st = running[slot]
            st.emit(int(nxt[lane]))
            self.pos[slot] += 1
            self.tok[slot] = nxt[lane]

    # -- internals ------------------------------------------------------------

    def _graft(self, slot: int, pre_caches: Any, prompt_len: int) -> None:
        """Write one request's prompt KV/states into its slot."""

        def one(dst, src):
            if dst.ndim == 5 and src.ndim == 5 and dst.shape[2] >= src.shape[2]:
                # attn KV (L, B, T, KV, D): batch row `slot`, first T rows
                return dst.at[:, slot, : src.shape[2]].set(src[:, 0].astype(dst.dtype))
            # SSM conv/state (L, B, ...): replace the whole row
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

        self.caches = jax.tree.map(one, self.caches, pre_caches)
