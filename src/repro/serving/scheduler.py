"""Production request scheduler: admission control, KV-budget queues,
SLOs and preemption in front of the slot-pool serving engine.

The paper's resident-weight premise means independent requests stream
through the programmed crossbar with zero data-movement overhead — the
host's job is purely to keep the slot pool saturated under heavy,
bursty traffic. This module is that host-side request path, modeled on
rtp-llm's ``FIFOScheduler`` (waiting/running queues, a KV-block budget
with a reserve ratio, partial/whole fallback under cache pressure):

* **Typed requests**: :class:`Request` is the immutable submission
  (prompt, token budget, ``priority``, ``deadline_ticks``, streaming
  callback); all mutable progress — generated tokens, status,
  admission/first-token ticks, preemption snapshots — lives in the
  :class:`RequestState` the scheduler returns from ``submit``.
* **Admission control**: a request is admitted only when a slot is
  free AND its KV need fits the remaining cache-token budget
  (``pool slots x slot capacity``, minus a configurable
  ``kv_reserve_ratio`` held back for decode growth). ``whole``
  admission commits the full ``prompt_len + max_new_tokens`` need up
  front; ``partial`` admits on the prompt footprint alone and grows the
  commitment per tick — the optimistic fallback under pressure,
  reconciled by preemption when the pool overcommits.
* **Queue policies**: ``fifo`` (priority, then submission order — pure
  FIFO at equal priority, head-of-line blocking included) and
  ``deadline`` (earliest absolute deadline first). Requests that can
  never fit are REJECTED gracefully at submit; requests whose
  ``deadline_ticks`` elapse — waiting or mid-decode — are EXPIRED and
  returned with their partial output, never silently starved.
* **Preemption**: when the budget overcommits (partial admission) or a
  strictly-higher-priority request cannot be admitted, the
  lowest-priority most-recently-admitted victim is evicted back to
  waiting. Eviction snapshots the slot's exact KV rows; re-admission
  restores them byte-for-byte, so a preempted request's generation is
  bit-identical to an undisturbed run. The reconcile loop never evicts
  the last running request, so an over-subscribed load always makes
  forward progress — no deadlocks by construction.
* **Streaming**: every emitted token fires the request's ``on_token``
  callback in order; :meth:`RequestScheduler.stream` wraps
  submit-and-step into a per-request token iterator.
* **Typed stats**: :meth:`RequestScheduler.stats` returns one frozen
  :class:`SchedulerStats` snapshot (admission latency, queue depth,
  ticks-to-first-token, rejections, expirations, preemptions, KV
  commitment) — the serving engine nests it inside its
  :class:`~repro.serving.engine.ServingStats`.

The scheduler drives a *slot pool* — any object exposing the small
executor surface :class:`ServingEngine` implements (``n_slots`` /
``slot_capacity`` / ``acquire_slot`` / ``release_slot`` /
``prefill_into`` / ``decode_tick`` / ``slot_exhausted`` /
``evict_slot`` / ``restore_slot``). The invariant, tested in
tests/test_scheduler.py: for any policy, budget, admission mode and
preemption pattern, every request's generated tokens are byte-identical
to running it alone.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Callable

import numpy as np

from repro import obs

POLICIES = ("fifo", "deadline")
ADMISSION_MODES = ("whole", "partial")


class SchedulerConfigError(ValueError):
    """An inconsistent :class:`SchedulerConfig`."""


class RequestRejectedError(RuntimeError):
    """A streamed request was rejected at admission control."""


class SchedulerExhaustedError(RuntimeError):
    """``drain()`` hit its tick cap with requests still in flight."""


class DegradedServiceError(RuntimeError):
    """A streamed request FAILED because the service degraded (fault
    tolerance out of moves: spare tiles exhausted / remap budget spent).
    Individual requests fail with this named error — the engine object
    itself stays alive and keeps rejecting new work gracefully."""


class RequestStatus(enum.Enum):
    WAITING = "waiting"        # queued, not yet admitted
    RUNNING = "running"        # holds a slot, decoding
    PREEMPTED = "preempted"    # evicted back to waiting, KV snapshotted
    FINISHED = "finished"      # hit its token budget (or cache capacity)
    REJECTED = "rejected"      # graceful admission-control rejection
    EXPIRED = "expired"        # deadline_ticks elapsed before finishing
    FAILED = "failed"          # service degraded with the request in flight


TERMINAL = (
    RequestStatus.FINISHED,
    RequestStatus.REJECTED,
    RequestStatus.EXPIRED,
    RequestStatus.FAILED,
)


@dataclasses.dataclass(frozen=True)
class PrefixGraft:
    """Cached KV rows for a shared prompt prefix (PR 10 fleet routing).

    ``rows`` is a prefill-shaped cache pytree with the batch axis
    squeezed (attention KV leaves ``(R, L, KV, D)``) covering at least
    the first ``length`` prompt positions, taken from an earlier
    prefill of a prompt sharing those tokens. A pool that supports
    continuation (:meth:`ServingEngine.supports_prefix_graft`) admits
    the request by prefilling only the suffix — bit-identical to a full
    prefill, by the ``prefill_continue`` invariant. ``length`` must be
    strictly below the prompt length: the last prompt position always
    computes fresh logits for the first emitted token.
    """

    length: int
    rows: Any


@dataclasses.dataclass(frozen=True, eq=False)
class Request:
    """One immutable client submission.

    Progress (generated tokens, status, timing) is NOT here — it lives
    in the :class:`RequestState` that ``submit`` returns, so a request
    object can be re-submitted or compared without aliasing mutable
    state (the pre-scheduler ``Request`` mixed both).
    """

    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int
    priority: int = 0                   # higher = more important
    deadline_ticks: int | None = None   # SLO: ticks from submit to finish
    on_token: Callable[[int, int, int], None] | None = None
    # on_token(rid, token, index) — fired per emitted token, in order
    prefix: PrefixGraft | None = None   # shared-prefix KV to graft at
    #                                     admission (fleet router affinity
    #                                     hit); pools without continuation
    #                                     support ignore it

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    def kv_need(self, slot_capacity: int) -> int:
        """Cache rows a full run writes: the prompt plus one row per
        decode tick (``max_new_tokens - 1`` of them), clamped to the
        slot — beyond it the engine finishes the request early."""
        return min(self.prompt_len + self.max_new_tokens - 1, slot_capacity)


@dataclasses.dataclass(frozen=True)
class SlotSnapshot:
    """A preempted request's exact execution state: the slot's KV/state
    rows plus position and last token. Restoring it into any free slot
    resumes decode bit-identically."""

    pos: int
    tok: int
    rows: Any       # pytree of per-slot cache rows (device arrays)
    tick: int = -1  # pool tick the snapshot was taken at (fault-tolerance
    #                 watermark: snapshots older than the last probe-clean
    #                 tick are trusted across a remap; newer ones restart)


@dataclasses.dataclass
class RequestState:
    """The mutable half of a request: progress, status, timing."""

    request: Request
    seq: int                             # global submission order
    submit_tick: int
    status: RequestStatus = RequestStatus.WAITING
    generated: list[int] = dataclasses.field(default_factory=list)
    committed: int = 0                   # KV tokens held against the budget
    admitted_tick: int | None = None     # first admission
    first_token_tick: int | None = None
    finish_tick: int | None = None
    preemptions: int = 0
    reject_reason: str | None = None
    fail_reason: str | None = None
    snapshot: SlotSnapshot | None = None

    # -- convenience views ---------------------------------------------------
    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        return self.status is RequestStatus.FINISHED

    @property
    def latency_ticks(self) -> int | None:
        """End-to-end ticks from submit to the terminal status (None
        while in flight) — the fleet router's per-replica load signal."""
        if self.finish_tick is None:
            return None
        return self.finish_tick - self.submit_tick

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL

    def emit(self, token: int) -> None:
        """Record one generated token and stream it to the client."""
        self.generated.append(int(token))
        if self.request.on_token is not None:
            self.request.on_token(self.rid, int(token), len(self.generated) - 1)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Every scheduling knob, validated eagerly.

    * ``policy`` — waiting-queue order: ``fifo`` (priority then
      submission order) or ``deadline`` (earliest absolute deadline
      first, then priority).
    * ``admission`` — ``whole`` commits a request's full KV need at
      admission (never preempted for budget); ``partial`` admits on the
      prompt footprint and grows per tick, preempting the youngest
      lowest-priority request when the pool overcommits.
    * ``kv_reserve_ratio`` — fraction of the KV-token budget held back
      from admission (headroom for decode growth / prefix reuse).
    * ``max_waiting`` — queue-depth cap; submissions beyond it are
      REJECTED instead of growing the queue without bound.
    * ``preempt`` — allow priority/budget preemption at all. With
      ``False``, over-budget partial pools simply stop admitting.
    """

    policy: str = "fifo"
    admission: str = "whole"
    kv_reserve_ratio: float = 0.0
    max_waiting: int | None = None
    preempt: bool = True

    def validate(self) -> "SchedulerConfig":
        if self.policy not in POLICIES:
            raise SchedulerConfigError(
                f"unknown scheduling policy {self.policy!r}; "
                f"known: {', '.join(POLICIES)}"
            )
        if self.admission not in ADMISSION_MODES:
            raise SchedulerConfigError(
                f"unknown admission mode {self.admission!r}; "
                f"known: {', '.join(ADMISSION_MODES)}"
            )
        if not 0.0 <= self.kv_reserve_ratio <= 1.0:
            raise SchedulerConfigError(
                f"kv_reserve_ratio must be in [0, 1], got {self.kv_reserve_ratio}"
            )
        if self.max_waiting is not None and self.max_waiting < 1:
            raise SchedulerConfigError(
                f"max_waiting must be >= 1 (or None for unbounded), "
                f"got {self.max_waiting}"
            )
        return self


@dataclasses.dataclass(frozen=True)
class SchedulerStats:
    """One frozen snapshot of the scheduler's counters."""

    policy: str
    admission: str
    submitted: int
    admitted: int               # admissions incl. resumptions
    finished: int
    rejected: int
    expired: int
    preempted: int
    resumed: int
    failed: int                 # FAILED by service degradation
    restarted: int              # requeued-from-scratch after fault remaps
    degraded_reason: str | None  # non-None once the service degraded
    queue_depth: int            # waiting now
    running: int                # slots held now
    max_queue_depth: int
    kv_budget: int              # pool slots x slot capacity (tokens)
    kv_usable: int              # budget minus the reserve
    kv_committed: int           # tokens held by running requests now
    admission_wait_ticks: float  # mean ticks from submit to first admission
    ticks_to_first_token: float  # mean ticks from submit to first output
    request_latency_ticks: float  # mean submit->FINISHED ticks (end to end)


class RequestScheduler:
    """Waiting/running queues + admission control over a slot pool.

    ``submit(request) -> RequestState`` enqueues (or gracefully
    rejects); ``step()`` runs one tick — expire deadlines, admit per
    policy and budget, reconcile over-commitment, one grouped decode —
    and returns the states that reached a terminal status this tick;
    ``drain()`` steps until idle. ``stats()`` snapshots the counters.
    """

    def __init__(self, pool, config: SchedulerConfig | None = None):
        self.pool = pool
        self.config = (config or SchedulerConfig()).validate()
        self.waiting: list[RequestState] = []
        self.running: dict[int, RequestState] = {}   # slot -> state
        self.tick_count = 0
        self._seq = 0
        self._counts = {
            "submitted": 0, "admitted": 0, "finished": 0, "rejected": 0,
            "expired": 0, "preempted": 0, "resumed": 0, "failed": 0,
            "restarted": 0,
        }
        # fault tolerance: set by degrade() — new submissions are then
        # rejected with this reason; terminal states produced OUTSIDE
        # step() (degrade mid-tick) queue here until the next step()
        self.degraded_reason: str | None = None
        self._async_terminal: list[RequestState] = []
        self._max_queue_depth = 0
        self._wait_ticks = [0, 0.0]   # [n admitted, total submit->admit ticks]
        self._ttft = [0, 0.0]         # [n first tokens, total ticks]
        self._latency = [0, 0.0]      # [n finished, total submit->finish ticks]

    # -- budget -------------------------------------------------------------

    @property
    def kv_budget(self) -> int:
        """The pool's total KV capacity in cache tokens."""
        return self.pool.n_slots * self.pool.slot_capacity

    @property
    def kv_usable(self) -> int:
        """Budget minus the configured reserve."""
        return int(self.kv_budget * (1.0 - self.config.kv_reserve_ratio))

    def kv_committed(self) -> int:
        return sum(st.committed for st in self.running.values())

    def _need(self, st: RequestState) -> int:
        """KV tokens an admission of ``st`` commits right now."""
        req = st.request
        full = req.kv_need(self.pool.slot_capacity)
        if self.config.admission == "whole":
            return full
        # partial: rows already written (prompt + generated-1) + one
        # tick of growth headroom — grows as the request decodes
        return min(req.prompt_len + max(len(st.generated), 1), full)

    # -- client API ---------------------------------------------------------

    def submit(self, request: Request) -> RequestState:
        """Enqueue a request; returns its state (possibly REJECTED)."""
        st = RequestState(
            request=request, seq=self._seq, submit_tick=self.tick_count
        )
        self._seq += 1
        self._counts["submitted"] += 1
        obs.event(
            "request.submit", track="sched", rid=request.rid,
            prompt_len=request.prompt_len,
            max_new_tokens=request.max_new_tokens, tick=self.tick_count,
        )
        obs.count("repro_requests_submitted_total", 1, "client submissions")
        reason = self._rejection_reason(request)
        if reason is not None:
            st.status = RequestStatus.REJECTED
            st.reject_reason = reason
            st.finish_tick = self.tick_count
            self._counts["rejected"] += 1
            obs.event(
                "request.reject", track="sched", rid=request.rid,
                reason=reason, tick=self.tick_count,
            )
            obs.count(
                "repro_requests_terminal_total", 1,
                "requests reaching a terminal status", status="rejected",
            )
            return st
        self.waiting.append(st)
        self._max_queue_depth = max(self._max_queue_depth, len(self.waiting))
        return st

    def _rejection_reason(self, req: Request) -> str | None:
        if self.degraded_reason is not None:
            return f"service degraded: {self.degraded_reason}"
        if req.max_new_tokens < 1:
            return f"max_new_tokens must be >= 1, got {req.max_new_tokens}"
        if req.prompt_len + 1 > self.pool.slot_capacity:
            return (
                f"prompt of {req.prompt_len} tokens cannot decode in a "
                f"{self.pool.slot_capacity}-token slot"
            )
        min_need = (
            req.kv_need(self.pool.slot_capacity)
            if self.config.admission == "whole"
            else req.prompt_len + 1
        )
        if min_need > self.kv_usable:
            return (
                f"KV need of {min_need} tokens exceeds the usable budget "
                f"({self.kv_usable} of {self.kv_budget} after "
                f"reserve={self.config.kv_reserve_ratio})"
            )
        if (
            self.config.max_waiting is not None
            and len(self.waiting) >= self.config.max_waiting
        ):
            return f"waiting queue full (max_waiting={self.config.max_waiting})"
        return None

    def idle(self) -> bool:
        return not self.waiting and not self.running

    def step(self) -> list[RequestState]:
        """One scheduling tick. Returns states that became terminal."""
        out = list(self._async_terminal)
        self._async_terminal.clear()
        out += self._expire()
        self._admit()
        if self.config.admission == "partial":
            self._reconcile_budget()
        if obs.enabled():
            obs.gauge_set(
                "repro_queue_depth", len(self.waiting),
                "requests waiting after this tick's admissions",
            )
            obs.gauge_set(
                "repro_running_slots", len(self.running), "slots held now"
            )
            obs.gauge_set(
                "repro_kv_committed_tokens", self.kv_committed(),
                "KV tokens held by running requests",
            )
            obs.observe(
                "repro_queue_depth_ticks", len(self.waiting),
                "waiting-queue depth sampled per tick",
                buckets=obs.TICK_BUCKETS,
            )
        # a 1-token request is satisfied by its prefill alone — collect
        # it before the decode so it neither burns a lane nor overshoots
        out.extend(self._collect_finished())
        if self.running:
            self.pool.decode_tick(self.running)
            for st in self.running.values():
                if self.config.admission == "partial":
                    st.committed = self._need(st)
        # first-token bookkeeping BEFORE collecting finished: a request
        # that finishes in its admission tick still has a TTFT
        for st in self.running.values():
            if st.first_token_tick is None and st.generated:
                st.first_token_tick = self.tick_count
                self._ttft[0] += 1
                self._ttft[1] += self.tick_count - st.submit_tick
                obs.observe(
                    "repro_ttft_ticks", self.tick_count - st.submit_tick,
                    "ticks from submit to first output token",
                    buckets=obs.TICK_BUCKETS,
                )
        out.extend(self._collect_finished())
        self.tick_count += 1
        return out

    def drain(self, max_ticks: int = 10_000) -> list[RequestState]:
        """Step until idle; raises :class:`SchedulerExhaustedError`
        (with queue-depth and budget context) on tick exhaustion."""
        if max_ticks < 1:
            raise ValueError(
                f"max_ticks must be >= 1 (the drain safety bound), "
                f"got {max_ticks}"
            )
        out: list[RequestState] = []
        for _ in range(max_ticks):
            if self.idle():
                return out
            out += self.step()
        if self.idle():
            return out
        stuck = [st.rid for st in self.waiting] + [
            st.rid for st in self.running.values()
        ]
        raise SchedulerExhaustedError(
            f"scheduler did not drain after {max_ticks} ticks; undrained "
            f"request ids: {stuck} (queue_depth={len(self.waiting)}, "
            f"running={len(self.running)}, kv_committed={self.kv_committed()}"
            f"/{self.kv_usable} usable of {self.kv_budget} budget, "
            f"policy={self.config.policy}, admission={self.config.admission})"
        )

    def stream(self, request: Request):
        """Submit and iterate the request's tokens as they decode.

        Drives ``step()`` under the hood (other in-flight requests make
        progress too); raises :class:`RequestRejectedError` if admission
        control rejects, and stops when the request reaches a terminal
        state (EXPIRED streams end after the partial output).
        """
        st = self.submit(request)
        if st.status is RequestStatus.REJECTED:
            raise RequestRejectedError(
                f"request {request.rid} rejected: {st.reject_reason}"
            )
        sent = 0
        while not st.terminal:
            self.step()
            while sent < len(st.generated):
                yield st.generated[sent]
                sent += 1
        if st.status is RequestStatus.FAILED:
            raise DegradedServiceError(
                f"request {request.rid} failed: {st.fail_reason}"
            )
        while sent < len(st.generated):
            yield st.generated[sent]
            sent += 1

    def adopt(
        self,
        request: Request,
        *,
        generated: "list[int] | tuple[int, ...]" = (),
        snapshot: SlotSnapshot | None = None,
    ) -> RequestState:
        """Enqueue a request that already made progress elsewhere — the
        fleet failover path (PR 10): a healthy replica adopts a request
        off a degraded one, carrying the tokens it already streamed and
        (when the source's clean-tick watermark trusts it) the KV
        snapshot to resume from. With a snapshot, admission restores the
        rows instead of prefilling and decode continues bit-exactly;
        without one the request re-prefills and regenerates the same
        tokens from scratch. Carried tokens do NOT re-fire ``on_token``
        (the client already received them)."""
        st = self.submit(request)
        if st.status is RequestStatus.REJECTED:
            return st
        if generated:
            st.generated = list(generated)
        if snapshot is not None:
            st.snapshot = snapshot
        return st

    def pending_terminal(self) -> bool:
        """Terminal states produced outside ``step()`` (mid-tick
        degrade) waiting to be surfaced by the next ``step()``."""
        return bool(self._async_terminal)

    def stats(self) -> SchedulerStats:
        c = self._counts
        return SchedulerStats(
            policy=self.config.policy,
            admission=self.config.admission,
            submitted=c["submitted"],
            admitted=c["admitted"],
            finished=c["finished"],
            rejected=c["rejected"],
            expired=c["expired"],
            preempted=c["preempted"],
            resumed=c["resumed"],
            failed=c["failed"],
            restarted=c["restarted"],
            degraded_reason=self.degraded_reason,
            queue_depth=len(self.waiting),
            running=len(self.running),
            max_queue_depth=self._max_queue_depth,
            kv_budget=self.kv_budget,
            kv_usable=self.kv_usable,
            kv_committed=self.kv_committed(),
            admission_wait_ticks=(
                self._wait_ticks[1] / self._wait_ticks[0]
                if self._wait_ticks[0] else 0.0
            ),
            ticks_to_first_token=(
                self._ttft[1] / self._ttft[0] if self._ttft[0] else 0.0
            ),
            request_latency_ticks=(
                self._latency[1] / self._latency[0] if self._latency[0] else 0.0
            ),
        )

    # -- fault tolerance (PR 9) ----------------------------------------------

    def restart_in_flight(self, *, clean_before: int = -1, reason: str = "fault") -> int:
        """Requeue every in-flight request whose state may carry
        corrupted output after a fault + remap.

        Preemption snapshots taken at or before ``clean_before`` (the
        health monitor's last probe-clean pool tick) are trusted and
        resume bit-exactly; everything running now, and every snapshot
        newer than the watermark, restarts from scratch (cleared output,
        fresh prefill). ``first_token_tick`` is kept so TTFT is not
        double-counted. Returns the number of requests reset."""
        n = 0
        for slot, st in list(self.running.items()):
            del self.running[slot]
            self.pool.release_slot(slot)
            self._reset(st)
            self.waiting.append(st)
            n += 1
        for st in self.waiting:
            if st.snapshot is not None and st.snapshot.tick > clean_before:
                self._reset(st)
                n += 1
        if n:
            self._counts["restarted"] += n
            self._max_queue_depth = max(self._max_queue_depth, len(self.waiting))
            obs.event(
                "request.restart", track="sched", n=n, reason=reason,
                clean_before=clean_before, tick=self.tick_count,
            )
        return n

    def _reset(self, st: RequestState) -> None:
        """Back to square one: WAITING, no output, no snapshot (the
        request re-prefills on next admission)."""
        st.status = RequestStatus.WAITING
        st.generated.clear()
        st.committed = 0
        st.snapshot = None

    def degrade(self, reason: str) -> list[RequestState]:
        """Graceful degradation: FAIL every in-flight and queued request
        with a named reason and reject all future submissions. The pool
        and scheduler objects stay alive — callers observe
        :class:`DegradedServiceError` per request, never a dead engine."""
        self.degraded_reason = reason
        out: list[RequestState] = []
        for slot, st in list(self.running.items()):
            del self.running[slot]
            self.pool.release_slot(slot)
            out.append(self._terminate(st, RequestStatus.FAILED, reason))
        for st in list(self.waiting):
            self.waiting.remove(st)
            out.append(self._terminate(st, RequestStatus.FAILED, reason))
        self._async_terminal.extend(out)
        return out

    # -- scheduling internals ------------------------------------------------

    def _order_key(self, st: RequestState):
        req = st.request
        if self.config.policy == "deadline":
            deadline = (
                st.submit_tick + req.deadline_ticks
                if req.deadline_ticks is not None else math.inf
            )
            return (deadline, -req.priority, st.seq)
        return (-req.priority, st.seq)

    def _expire(self) -> list[RequestState]:
        """Time out waiting AND running requests past their SLO."""
        out = []
        for st in list(self.waiting):
            dl = st.request.deadline_ticks
            if dl is not None and self.tick_count - st.submit_tick >= dl:
                self.waiting.remove(st)
                out.append(self._terminate(st, RequestStatus.EXPIRED))
        for slot, st in list(self.running.items()):
            dl = st.request.deadline_ticks
            if dl is not None and self.tick_count - st.submit_tick >= dl:
                del self.running[slot]
                self.pool.release_slot(slot)
                out.append(self._terminate(st, RequestStatus.EXPIRED))
        return out

    def _terminate(
        self, st: RequestState, status: RequestStatus, reason: str | None = None
    ) -> RequestState:
        st.status = status
        st.finish_tick = self.tick_count
        st.committed = 0
        if status is not RequestStatus.FAILED:
            # FAILED keeps its preemption snapshot: a fleet pool salvages
            # clean-watermark snapshots off a degraded replica (PR 10)
            st.snapshot = None
        latency = self.tick_count - st.submit_tick
        if status is RequestStatus.FINISHED:
            self._latency[0] += 1
            self._latency[1] += latency
        obs.observe(
            "repro_request_latency_ticks", latency,
            "ticks from submit to a terminal status (end-to-end latency)",
            buckets=obs.TICK_BUCKETS, status=status.value,
        )
        key = {
            RequestStatus.FINISHED: "finished",
            RequestStatus.EXPIRED: "expired",
            RequestStatus.FAILED: "failed",
        }[status]
        if status is RequestStatus.FAILED:
            st.fail_reason = reason
        self._counts[key] += 1
        event = {
            "finished": "request.finish",
            "expired": "request.expire",
            "failed": "request.fail",
        }[key]
        obs.event(
            event, track="sched", rid=st.rid, tick=self.tick_count,
            n_generated=len(st.generated),
        )
        obs.count(
            "repro_requests_terminal_total", 1,
            "requests reaching a terminal status", status=status.value,
        )
        return st

    def _admit(self) -> None:
        """Move waiting requests into slots, strictly in policy order.

        Head-of-line blocking is intentional (FIFO semantics): when the
        head cannot be admitted — no slot, no budget, no preemptable
        victim — admission stops for the tick rather than admitting a
        later (smaller) request past it.
        """
        while self.waiting:
            # re-sorted every iteration: preemption inside _make_room
            # re-queues victims, which must take their policy position
            self.waiting.sort(key=self._order_key)
            st = self.waiting[0]
            need = self._need(st)
            if not self._make_room(st, need):
                return
            slot = self.pool.acquire_slot()
            self.waiting.pop(0)
            st.committed = need
            st.status = RequestStatus.RUNNING
            self._counts["admitted"] += 1
            if st.admitted_tick is None:
                st.admitted_tick = self.tick_count
                self._wait_ticks[0] += 1
                self._wait_ticks[1] += self.tick_count - st.submit_tick
                obs.observe(
                    "repro_admission_wait_ticks",
                    self.tick_count - st.submit_tick,
                    "ticks from submit to first admission",
                    buckets=obs.TICK_BUCKETS,
                )
            if st.snapshot is not None:
                self.pool.restore_slot(slot, st.snapshot)
                st.snapshot = None
                self._counts["resumed"] += 1
                obs.event(
                    "request.resume", track="sched", rid=st.rid,
                    slot=slot, tick=self.tick_count,
                )
            else:
                obs.event(
                    "request.admit", track="sched", rid=st.rid,
                    slot=slot, committed=need, tick=self.tick_count,
                )
                self.pool.prefill_into(slot, st)
            self.running[slot] = st

    def _make_room(self, st: RequestState, need: int) -> bool:
        """Free a slot and budget for ``st``, preempting strictly-lower
        priority victims when allowed. True when admission can proceed."""
        def fits() -> bool:
            return (
                self.pool.free_slots > 0
                and self.kv_committed() + need <= self.kv_usable
            )

        while not fits():
            if not self.config.preempt:
                return False
            victim = self._victim(max_priority=st.request.priority)
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _victim(self, max_priority: int | None = None) -> int | None:
        """The slot to evict: lowest priority, most recently admitted.
        ``max_priority`` restricts to strictly lower priorities (priority
        preemption must not evict a peer)."""
        candidates = [
            (st.request.priority, -(st.admitted_tick or 0), -st.seq, slot)
            for slot, st in self.running.items()
            if max_priority is None or st.request.priority < max_priority
        ]
        if not candidates:
            return None
        return min(candidates)[3]

    def _preempt(self, slot: int) -> None:
        """Evict one running request back to waiting, KV snapshotted."""
        st = self.running.pop(slot)
        st.snapshot = self.pool.evict_slot(slot)
        st.status = RequestStatus.PREEMPTED
        st.preemptions += 1
        st.committed = 0
        self._counts["preempted"] += 1
        obs.event(
            "request.preempt", track="sched", rid=st.rid,
            slot=slot, tick=self.tick_count,
        )
        obs.count("repro_preemptions_total", 1, "slots evicted back to waiting")
        self.waiting.append(st)
        self._max_queue_depth = max(self._max_queue_depth, len(self.waiting))

    def _reconcile_budget(self) -> None:
        """Partial admission grew past the budget: evict the youngest
        lowest-priority requests until within it. The LAST running
        request is never evicted, so the pool always makes forward
        progress (no deadlock, no preemption livelock)."""
        while self.kv_committed() > self.kv_usable and len(self.running) > 1:
            victim = self._victim()
            if victim is None:  # pragma: no cover - all priorities equal
                return
            self._preempt(victim)

    def _collect_finished(self) -> list[RequestState]:
        out = []
        for slot, st in list(self.running.items()):
            req = st.request
            out_of_budget = len(st.generated) >= req.max_new_tokens
            out_of_cache = self.pool.slot_exhausted(slot)
            if out_of_budget or out_of_cache:
                del self.running[slot]
                self.pool.release_slot(slot)
                out.append(self._terminate(st, RequestStatus.FINISHED))
        return out
