"""AdamW with optional factored second moment.

Pure functions over pytrees: ``state = adamw_init(params, cfg)``,
``params, state = adamw_update(grads, params, state, lr, cfg)``.
Everything jit/pjit-friendly; state shards exactly like params (the
partitioner maps m/v specs from the param specs), so ZeRO-style
optimizer sharding falls out of the param sharding for free.

Factored mode (``cfg.factored=True``): tensors with ndim >= 2 keep only
row/col second-moment statistics (Adafactor, Shazeer & Stern 2018) —
O(n+m) instead of O(nm) memory. First moment stays dense (momentum
matters for quality); this halves optimizer state vs Adam and is what
lets jamba-398b's state fit a single 256-chip pod (see EXPERIMENTS.md
§Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    factored: bool = False
    factored_min_size: int = 128 * 128  # only factor tensors at least this big
    eps_factored: float = 1e-30
    # Mixed precision: keep an fp32 master copy in the optimizer state
    # and emit params in their own (bf16) dtype. With ZeRO-3 batch
    # sharding this halves the per-layer weight all-gather (bf16 on the
    # wire instead of fp32) — see EXPERIMENTS.md §Perf. Enabled
    # automatically when any param is sub-fp32.
    master_weights: bool | None = None


def _factorable(x: Array, cfg: OptConfig) -> bool:
    return cfg.factored and x.ndim >= 2 and x.size >= cfg.factored_min_size


def _wants_master(params: Any, cfg: OptConfig) -> bool:
    if cfg.master_weights is not None:
        return cfg.master_weights
    return any(l.dtype != jnp.float32 for l in jax.tree.leaves(params))


def adamw_init(params: Any, cfg: OptConfig) -> dict:
    def init_v(p):
        if _factorable(p, cfg):
            # row/col mean-square stats over the trailing two dims
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return jnp.zeros_like(p, jnp.float32)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "v": jax.tree.map(init_v, params, is_leaf=lambda x: isinstance(x, jax.Array)),
    }
    if _wants_master(params, cfg):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _update_v(g2: Array, v, cfg: OptConfig):
    """Second-moment EMA; returns (new_v, dense 1/sqrt(v_hat) factor fn input)."""
    if isinstance(v, dict):  # factored
        vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
        vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
        # reconstruct: v̂ ≈ vr ⊗ vc / mean(vr)
        denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps_factored)
        vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
        return {"vr": vr, "vc": vc}, vhat
    vnew = cfg.b2 * v + (1 - cfg.b2) * g2
    return vnew, vnew


def adamw_update(
    grads: Any, params: Any, state: dict, lr: Array | float, cfg: OptConfig
) -> tuple[Any, dict]:
    step = state["step"] + 1
    # global-norm clip (fp32)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    has_master = "master" in state

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"]) if has_master else flat_p

    new_p, new_m, new_v, new_w = [], [], [], []
    for g, p, m, v, w in zip(flat_g, flat_p, flat_m, flat_v, flat_w):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2, vhat = _update_v(g * g, v, cfg)
        mhat = m2 / bc1
        vhat = vhat / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            upd = upd + cfg.weight_decay * w.astype(jnp.float32)
        w2 = w.astype(jnp.float32) - lr * upd
        new_p.append(w2.astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)

    new_state = {"step": step, "m": treedef.unflatten(new_m), "v": treedef.unflatten(new_v)}
    if has_master:
        new_state["master"] = treedef.unflatten(new_w)
    return treedef.unflatten(new_p), new_state
