"""LR schedules as pure functions of the step (jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(
    step, *, peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
):
    """Linear warmup to ``peak_lr`` then cosine decay to ``final_frac*peak``."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    floor = final_frac * peak_lr
    cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)
