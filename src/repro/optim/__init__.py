"""Optimizers and schedules (no external deps).

  adamw.py      AdamW with decoupled weight decay, global-norm clipping,
                and a memory-factored (Adafactor-style) second-moment
                mode for 300B+ models (row/col statistics instead of a
                full v tensor — the difference between fitting and not
                fitting optimizer state on a 256-chip pod).
  schedule.py   warmup + cosine decay.
"""

from repro.optim.adamw import OptConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
