"""qwen2-72b [dense] — GQA, QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    pattern=(LayerKind(mixer="attn"),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        pattern=(LayerKind(mixer="attn"),),
        attn_chunk=32,
        loss_chunk=32,
    )
