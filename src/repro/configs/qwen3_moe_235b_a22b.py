"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B
family scaling].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per-expert) vocab=151936,
MoE 128e top-8, every layer MoE. Fine-grained experts: d_ff is small
(1536) but 128 of them exist per layer.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    moe_experts=128,
    moe_top_k=8,
    pattern=(LayerKind(mixer="attn", moe=True),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        moe_experts=8,
        moe_top_k=4,
        pattern=(LayerKind(mixer="attn", moe=True),),
        attn_chunk=32,
        loss_chunk=32,
    )
