"""internvl2-1b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The ViT frontend
is a STUB per the brief: ``input_specs()`` provides precomputed patch
embeddings (``extra_embeds``) prepended to the token sequence.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    pattern=(LayerKind(mixer="attn"),),
    frontend="vision",
    frontend_len=256,  # 256 ViT patch embeddings per image
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        n_layers=2,
        d_model=56,   # keeps head_dim=4 divisible across 14 heads
        n_heads=14,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        pattern=(LayerKind(mixer="attn"),),
        frontend="vision",
        frontend_len=8,
        attn_chunk=32,
        loss_chunk=32,
    )
