"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    pattern=(LayerKind(mixer="attn"),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        pattern=(LayerKind(mixer="attn"),),
        attn_chunk=32,
        loss_chunk=32,
    )
