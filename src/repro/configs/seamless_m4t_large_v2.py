"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

24L (decoder) d_model=1024 16H (MHA kv=16) d_ff=8192 vocab=256206, with
a 24-layer bidirectional encoder over the audio frontend (STUB per the
brief: ``input_specs()`` provides precomputed frame embeddings).
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    n_encoder_layers=24,
    pattern=(LayerKind(mixer="attn"),),
    frontend="audio",
    frontend_len=512,  # speech frames per utterance
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        n_encoder_layers=2,
        pattern=(LayerKind(mixer="attn"),),
        frontend="audio",
        frontend_len=16,
        attn_chunk=32,
        loss_chunk=32,
    )
