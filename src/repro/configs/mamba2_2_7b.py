"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560 (attn-free) d_ff=0 (mixer-only blocks) vocab=50280,
ssm_state=128. d_inner = 2*d = 5120, head_dim 64 -> 80 SSD heads.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,          # unused (attn-free); SSD heads derive from d_inner
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_groups=1,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    pattern=(LayerKind(mixer="mamba"),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        tie_embeddings=True,
        pattern=(LayerKind(mixer="mamba"),),
        attn_chunk=32,
        loss_chunk=32,
    )
