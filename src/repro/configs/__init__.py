"""Assigned-architecture configs (``--arch <id>``).

Each module defines ``CONFIG`` (exact published numbers, see the source
annotations) and ``smoke_config()`` (a reduced same-family variant for
CPU tests). ``get_config``/``ARCH_IDS`` are the public lookup API used
by the launcher, dry-run, benchmarks, and tests.
"""

from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

ARCH_IDS: tuple[str, ...] = (
    "jamba-1.5-large-398b",
    "internvl2-1b",
    "grok-1-314b",
    "qwen3-moe-235b-a22b",
    "qwen1.5-0.5b",
    "tinyllama-1.1b",
    "qwen2-72b",
    "llama3.2-3b",
    "seamless-m4t-large-v2",
    "mamba2-2.7b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, *, quant: str = "none") -> ModelConfig:
    cfg = _module(arch).CONFIG
    if quant != cfg.quant:
        import dataclasses

        cfg = dataclasses.replace(cfg, quant=quant)
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def all_cells() -> list[tuple[str, ShapeConfig, bool, str]]:
    """All 40 (arch x shape) cells with the skip rule applied.

    Returns (arch, shape, runs?, skip_reason)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            runs, why = shape_applicable(cfg, shape)
            cells.append((arch, shape, runs, why))
    return cells
