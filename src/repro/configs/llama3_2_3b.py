"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2 family].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256; llama3 RoPE
base 500k.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    pattern=(LayerKind(mixer="attn"),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        rope_theta=500_000.0,
        pattern=(LayerKind(mixer="attn"),),
        attn_chunk=32,
        loss_chunk=32,
    )
