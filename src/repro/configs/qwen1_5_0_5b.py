"""qwen1.5-0.5b [dense] — QKV bias, tied embeddings [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (MHA: kv=16) d_ff=2816 vocab=151936.
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    pattern=(LayerKind(mixer="attn"),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        tie_embeddings=True,
        pattern=(LayerKind(mixer="attn"),),
        attn_chunk=32,
        loss_chunk=32,
    )
