"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave with
MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Jamba period: 8 layers with 1 attention layer (index 4, as published)
and MoE on every other layer (odd indices) -> 9 repeats of the pattern.
"""

from repro.models.config import LayerKind, ModelConfig

# attn at slot 4 of 8 (1:7), MoE every second layer
_PATTERN = tuple(
    LayerKind(mixer="attn" if i == 4 else "mamba", moe=(i % 2 == 1)) for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe_experts=16,
    moe_top_k=2,
    ssm_state=128,
    ssm_groups=1,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    pattern=_PATTERN,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        moe_experts=4,
        moe_top_k=2,
        ssm_state=16,
        ssm_head_dim=16,
        pattern=tuple(
            LayerKind(mixer="attn" if i == 1 else "mamba", moe=(i % 2 == 1))
            for i in range(4)
        ),
        attn_chunk=32,
        loss_chunk=32,
    )
