"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
Every layer is MoE (as released).
"""

from repro.models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    moe_experts=8,
    moe_top_k=2,
    pattern=(LayerKind(mixer="attn", moe=True),),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        moe_experts=4,
        moe_top_k=2,
        pattern=(LayerKind(mixer="attn", moe=True),),
        attn_chunk=32,
        loss_chunk=32,
    )
