"""``FaultyEngine`` — fault injection as an engine decorator.

Wraps ANY registry backend (``repro.core.engine``) behind the same
``prepare`` / ``binary_vmm`` / ``binary_mmm`` contract and corrupts its
outputs exactly the way stuck PCM cells would:

* ``prepare`` composes the inner engine's artifact with a *fault delta*
  ``D = stuck_SET * (1 - cells) - stuck_RESET * cells`` over the
  complement-stacked {0,1} cell matrix (2m, n): the per-cell difference
  between what the crossbar *reads* and what was *programmed*.
* Execution is algebraically exact corruption: a complement-drive
  readout of cells ``C' = C + D`` returns
  ``out + 2 * (drive @ D)`` where ``out`` is the inner engine's exact
  result — so injection composes with every backend without touching
  its kernel, and ``D = 0`` (fault-free) is bit-identical by
  construction, not merely numerically close.
* The delta rides inside the wrapper's :class:`PreparedWeights`
  (``data = (inner_data, delta)``), i.e. it is a *jit argument*, never
  a trace constant — refreshing artifacts after drift / tile failure /
  remap changes results without retracing hazards.

Fault-to-placement resolution is PER PHYSICAL TILE: each placed block's
tile id selects that tile's deterministic stuck-cell masks
(:meth:`FaultModel.tile_cell_masks`), so remapping a block onto a spare
tile genuinely escapes the old tile's faults. A plan-bound ``tiled``
inner engine resolves blocks through its ``MappingPlan``; any other
inner engine uses the layer-local row-major tile grid (tile ids are
then per-layer-shape, a documented modeling simplification — and scan
repeats of one shape share a placement, since engines see shapes, not
instances).

Detection: :meth:`consistency_probe` evaluates the TacitMap
complement-row invariant — for pristine cells the drives ``+1^m`` and
``-1^m`` sum to all-ones over the complement-stacked rows, so
``vmm(+1) + vmm(-1) == 0`` per column; stuck cells break it by
``2 * D.sum(rows)``. (A stuck-SET and stuck-RESET cell in the same
column can alias to zero — the probe is the hardware-plausible BIST;
:meth:`locate` reads the delta directly and is the simulator's exact
oracle the remap path uses.)

NOTE the wrapped engine lives on ``self.inner`` — NOT ``self.base``:
``lm.program_weights`` unwraps one ``.base`` level (GroupedEngine), and
a ``.base`` here would silently bypass injection during programming.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import bnn
from repro.core import engine as engine_lib
from repro.core.crossbar import CrossbarSpec, TileGrid
from repro.faults.model import FaultModel

_FAULT_TAG = "__faulty__"

# engines whose PreparedWeights.data recovers the programmed cell
# matrix, so artifacts can be *refreshed* (delta recomputed) after the
# fault state changes post-programming. ``packed`` holds bit-packed
# words — injection works at prepare time (cells derive from the raw
# signs) but a packed artifact cannot be refreshed in place.
CELL_DATA_ENGINES = ("reference", "tacitmap", "wdm", "tiled", "custbinarymap")
_SIGN_DATA_ENGINES = ("reference", "custbinarymap")
_CELLS_DATA_ENGINES = ("tacitmap", "wdm", "tiled")


class FaultInjectionError(RuntimeError):
    """Fault state cannot be applied to this engine/artifact."""


def _cells_from_signs(w_signs):
    """Complement-stacked {0,1} cells from ±1 signs, along axis -2 —
    works for stacked (L, m, n) artifacts too."""
    bits = bnn.signs_to_bits(w_signs)
    return jnp.concatenate([bits, 1.0 - bits], axis=-2).astype(jnp.float32)


class FaultyEngine:
    """Fault-injecting decorator over a registry engine.

    Runtime state (mutable, survives :meth:`rebind`):

    * ``epoch`` — drift epochs elapsed (:meth:`advance_drift`).
    * runtime-failed tiles (:meth:`fail_tile`) and runtime-dead lanes
      (:meth:`fail_lane`) — faults that *developed* after construction,
      on top of the :class:`FaultModel`'s.

    Changing runtime state does NOT rewrite already-prepared artifacts
    (their delta is baked into the artifact data); callers refresh them
    (``CompiledModel`` does, via :meth:`refresh`) to observe new state.
    """

    def __init__(self, inner, model: FaultModel, *, epoch: int = 0):
        if isinstance(inner, engine_lib.GroupedEngine):
            raise FaultInjectionError(
                "wrap the base engine, then group: "
                "GroupedEngine(FaultyEngine(base, model), k)"
            )
        if isinstance(inner, FaultyEngine):
            raise FaultInjectionError("refusing to double-wrap a FaultyEngine")
        self.inner = inner
        self.model = model.validate()
        self.epoch = int(epoch)
        self._runtime_failed: set[int] = set()
        self._runtime_dead_lanes: set[int] = set()
        self._mask_cache: dict[tuple, tuple[np.ndarray, np.ndarray]] = {}

    # -- delegated surface --------------------------------------------------

    @property
    def name(self) -> str:
        # artifacts stay tagged with the inner backend's name, so the
        # inner engine's _check_prepared accepts the unwrapped half
        return self.inner.name

    @property
    def info(self):
        return self.inner.info

    @property
    def spec(self) -> CrossbarSpec:
        return self.inner.spec

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return self.inner.steps_for(m, n, n_inputs)

    def cache_stats(self) -> dict:
        if hasattr(self.inner, "cache_stats"):
            return self.inner.cache_stats()
        return {}

    def with_spec(self, spec: CrossbarSpec) -> "FaultyEngine":
        return self.rebind(engine_lib.resolve(self.inner, spec))

    def rebind(self, new_inner) -> "FaultyEngine":
        """Same fault state over a different inner engine (the remap
        path: a re-placed plan means a new tiled inner instance)."""
        out = FaultyEngine(new_inner, self.model, epoch=self.epoch)
        out._runtime_failed = set(self._runtime_failed)
        out._runtime_dead_lanes = set(self._runtime_dead_lanes)
        return out

    # -- fault state --------------------------------------------------------

    @property
    def pristine(self) -> bool:
        """No cell-value corruption under the CURRENT state (dead lanes
        don't count — capacity, not correctness)."""
        return self.model.cell_pristine and not self._runtime_failed

    def failed_tiles(self) -> frozenset[int]:
        return self.model.failed_tiles | frozenset(self._runtime_failed)

    def dead_lanes(self) -> frozenset[int]:
        return self.model.dead_lanes | frozenset(self._runtime_dead_lanes)

    def fail_tile(self, tile: int) -> None:
        """Whole-tile failure at runtime: every cell now reads RESET."""
        self._runtime_failed.add(int(tile))

    def fail_lane(self, lane: int) -> None:
        """Kill one WDM comb line at runtime (capacity loss only)."""
        self._runtime_dead_lanes.add(int(lane))

    def advance_drift(self, epochs: int = 1) -> None:
        """Advance conductance drift; stuck-RESET cells only ever grow."""
        if epochs < 0:
            raise ValueError(f"drift only moves forward, got {epochs}")
        self.epoch += int(epochs)

    def effective_group_cap(self) -> int | None:
        """Alive wavelengths among the inner engine's preferred K, or
        ``None`` when the inner engine doesn't multiplex (K <= 1)."""
        k = self.inner.preferred_group_size()
        if k <= 1:
            return None
        dead = self.dead_lanes()
        return max(1, sum(1 for lane in range(k) if lane not in dead))

    def preferred_group_size(self) -> int:
        cap = self.effective_group_cap()
        return self.inner.preferred_group_size() if cap is None else cap

    def tile_is_clean(self, tile: int) -> bool:
        """BIST one physical tile under the current epoch: usable as a
        remap destination iff it is not failed and draws no stuck
        cells. Spare tiles are real hardware — they fault too."""
        if tile in self.failed_tiles():
            return False
        s, r = self.model.tile_cell_masks(
            tile, self.spec.rows, self.spec.cols, self.epoch, failed=False
        )
        return not (bool(s.any()) or bool(r.any()))

    # -- programming (wrapper artifacts) ------------------------------------

    @staticmethod
    def _is_wrapped(pw: engine_lib.PreparedWeights) -> bool:
        return (
            isinstance(pw.aux, tuple)
            and len(pw.aux) == 2
            and pw.aux[0] == _FAULT_TAG
        )

    def _split(self, pw: engine_lib.PreparedWeights):
        """Wrapper artifact -> (inner artifact, delta-or-None)."""
        inner_data, delta = pw.data
        inner_pw = engine_lib.PreparedWeights(
            engine=pw.engine, m=pw.m, n=pw.n, data=inner_data, aux=pw.aux[1]
        )
        return inner_pw, delta

    def _compose(self, inner_pw, cells) -> engine_lib.PreparedWeights:
        if self.pristine:
            delta = None
        else:
            if cells is None:
                raise FaultInjectionError(
                    f"engine {self.inner.name!r} artifacts do not expose the "
                    "programmed cell matrix (bit-packed data) — fault state "
                    "can only be injected at prepare time from raw signs, "
                    "not refreshed on an existing artifact"
                )
            delta = self._delta(cells, inner_pw.m, inner_pw.n)
        return engine_lib.PreparedWeights(
            engine=inner_pw.engine,
            m=inner_pw.m,
            n=inner_pw.n,
            data=(inner_pw.data, delta),
            aux=(_FAULT_TAG, inner_pw.aux),
        )

    def _cells_of(self, inner_pw):
        """Recover the programmed (…, 2m, n) cell matrix from an inner
        artifact, or ``None`` when the data doesn't carry it."""
        if inner_pw.engine in _CELLS_DATA_ENGINES:
            return inner_pw.data
        if inner_pw.engine in _SIGN_DATA_ENGINES:
            return _cells_from_signs(inner_pw.data)
        return None

    def prepare(self, w_signs) -> engine_lib.PreparedWeights:
        if isinstance(w_signs, engine_lib.PreparedWeights):
            if self._is_wrapped(w_signs):
                return w_signs
            inner_pw = self.inner.prepare(w_signs)  # validates engine name
            return self._compose(inner_pw, self._cells_of(inner_pw))
        inner_pw = self.inner.prepare(w_signs)
        # cells derive from the raw signs, so prepare-time injection
        # works for EVERY inner engine (packed included)
        return self._compose(inner_pw, _cells_from_signs(w_signs))

    def prepare_cached(self, w_signs, key=None) -> engine_lib.PreparedWeights:
        """No memoization: the fault state is mutable (drift, runtime
        tile failures), and an identity-keyed cache would serve stale
        deltas. The programmed path (``lm.program_weights``) is the
        production route; this raw-weights path just stays correct."""
        del key
        if isinstance(w_signs, engine_lib.PreparedWeights):
            return self.prepare(w_signs)
        return self.prepare(w_signs() if callable(w_signs) else w_signs)

    def refresh(self, pw: engine_lib.PreparedWeights) -> engine_lib.PreparedWeights:
        """Recompute an artifact's delta (and placement aux) under the
        CURRENT fault state / inner engine — the post-remap, post-drift
        reprogramming step. Works for stacked (L, …) artifacts."""
        inner_pw, _ = self._split(pw) if self._is_wrapped(pw) else (pw, None)
        if hasattr(self.inner, "_program_aux"):
            inner_pw = dataclasses.replace(
                inner_pw, aux=self.inner._program_aux(inner_pw.m, inner_pw.n)
            )
        return self._compose(inner_pw, self._cells_of(inner_pw))

    # -- the fault delta ----------------------------------------------------

    def _placement_blocks(self, m: int, n: int):
        """(row_block, col_block, rows_used, cols_used, tile) for every
        placed block of a (m, n) matrix — through the inner engine's
        MappingPlan when it has one, else the layer-local grid."""
        if hasattr(self.inner, "_placement"):
            lp = self.inner._placement(m, n)
            return [
                (b.row_block, b.col_block, b.rows_used, b.cols_used, b.tile)
                for b in lp.blocks
            ]
        grid = TileGrid(rows=2 * m, cols=n, spec=self.spec)
        R, C = self.spec.rows, self.spec.cols
        out = []
        for rb in range(grid.row_tiles):
            for cb in range(grid.col_tiles):
                out.append((
                    rb, cb,
                    min(R, 2 * m - rb * R),
                    min(C, n - cb * C),
                    rb * grid.col_tiles + cb,
                ))
        return out

    def _layer_masks(self, m: int, n: int) -> tuple[np.ndarray, np.ndarray]:
        """(stuck_SET, stuck_RESET) over the layer's (2m, n) cell matrix,
        assembled from the per-physical-tile masks through the placement
        (cached per (shape, epoch, failed-tile set))."""
        failed = self.failed_tiles()
        key = (m, n, self.epoch, tuple(sorted(failed)))
        cached = self._mask_cache.get(key)
        if cached is not None:
            return cached
        R, C = self.spec.rows, self.spec.cols
        set_m = np.zeros((2 * m, n), bool)
        reset_m = np.zeros((2 * m, n), bool)
        for rb, cb, ru, cu, tile in self._placement_blocks(m, n):
            s, r = self.model.tile_cell_masks(
                tile, R, C, self.epoch, failed=tile in failed
            )
            r0, c0 = rb * R, cb * C
            set_m[r0:r0 + ru, c0:c0 + cu] |= s[:ru, :cu]
            reset_m[r0:r0 + ru, c0:c0 + cu] |= r[:ru, :cu]
        self._mask_cache[key] = (set_m, reset_m)
        return set_m, reset_m

    def _delta(self, cells, m: int, n: int):
        """What the crossbar reads minus what was programmed:
        ``D = SET * (1 - C) - RESET * C`` (broadcasts over a stacked
        leading axis; dense even when all-zero so the artifact treedef
        is stable across refreshes)."""
        set_m, reset_m = self._layer_masks(m, n)
        s = jnp.asarray(set_m, jnp.float32)
        r = jnp.asarray(reset_m, jnp.float32)
        return s * (1.0 - cells) - r * cells

    # -- execution ----------------------------------------------------------

    def _corruption(self, a_signs, delta):
        """The exact output error of reading ``C + D``: per Eq. 1 the
        complement drive hits the delta as ``2 * (drive @ D)``."""
        drive = bnn.concat_complement_input(bnn.signs_to_bits(a_signs))
        return 2.0 * jnp.einsum(
            "...r,rc->...c", drive.astype(jnp.float32), delta
        )

    def binary_vmm(self, a_signs, w):
        pw = self.prepare(w)
        inner_pw, delta = self._split(pw)
        out = self.inner.binary_vmm(a_signs, inner_pw)
        if delta is None:
            return out
        return out + self._corruption(a_signs, delta).astype(out.dtype)

    def binary_mmm(self, groups, w):
        pw = self.prepare(w)
        inner_pw, delta = self._split(pw)
        out = self.inner.binary_mmm(groups, inner_pw)
        if delta is None:
            return out
        return out + self._corruption(groups, delta).astype(out.dtype)

    @property
    def supports_fused_dense(self) -> bool:
        """The fused decode-tick kernel has no seam to add the fault
        delta, so the capability is only advertised while pristine —
        non-pristine models fall back to the unfused chain where the
        corruption applies."""
        return self.pristine and getattr(
            self.inner, "supports_fused_dense", False
        )

    def fused_dense(self, x, pw, alpha):
        inner_pw, delta = (
            self._split(pw) if self._is_wrapped(pw) else (pw, None)
        )
        if delta is not None:
            raise FaultInjectionError(
                "fused_dense has no injection seam; the dense() layer must "
                "route non-pristine models through the unfused path "
                "(supports_fused_dense is False while faults are active)"
            )
        return self.inner.fused_dense(x, inner_pw, alpha)

    # -- detection ----------------------------------------------------------

    def consistency_probe(self, w, *, execute: bool = False) -> np.ndarray:
        """Per-column violation magnitude of the TacitMap complement-row
        invariant (0 everywhere iff no *visible* corruption).

        ``execute=True`` runs the honest two-drive readout
        ``|vmm(+1^m) + vmm(-1^m)|`` through the full execution path
        (single-layer artifacts only); the default reads the identical
        quantity ``|2 * D.sum(rows)]|`` off the delta — the inner
        engines satisfy the invariant exactly, so the two agree
        bit-for-bit. Stacked (L, …) artifacts reduce to the worst
        violation across repeats.
        """
        pw = self.prepare(w)
        if execute:
            ones = jnp.ones((pw.m,), jnp.float32)
            v = self.binary_vmm(ones, pw) + self.binary_vmm(-ones, pw)
            return np.abs(np.asarray(v, np.float64))
        _, delta = self._split(pw)
        if delta is None:
            return np.zeros((pw.n,), np.float64)
        d = np.asarray(delta, np.float64).reshape(-1, 2 * pw.m, pw.n)
        return np.abs(2.0 * d.sum(axis=1)).max(axis=0)

    def locate(self, w) -> frozenset[int]:
        """Physical tiles holding at least one corrupted cell of this
        artifact — the exact oracle the remap path consumes (unlike the
        probe, immune to same-column SET/RESET aliasing)."""
        pw = self.prepare(w)
        _, delta = self._split(pw)
        if delta is None:
            return frozenset()
        d = np.asarray(delta).reshape(-1, 2 * pw.m, pw.n)
        bad = np.argwhere(np.any(d != 0.0, axis=0))
        if not len(bad):
            return frozenset()
        R, C = self.spec.rows, self.spec.cols
        tile_of = {
            (rb, cb): tile
            for rb, cb, _, _, tile in self._placement_blocks(pw.m, pw.n)
        }
        return frozenset(
            tile_of[(r // R, c // C)] for r, c in bad
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"<FaultyEngine over {self.inner!r} epoch={self.epoch} "
            f"failed={sorted(self.failed_tiles())} "
            f"dead_lanes={sorted(self.dead_lanes())}>"
        )
