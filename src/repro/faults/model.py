"""Deterministic, seedable device-fault models for the PCM crossbar.

The paper's premise is weights resident *in* PCM cells — but real
(o)PCM devices suffer stuck-at faults (a cell frozen in the SET or
RESET conductance state regardless of what was programmed), conductance
drift (amorphous-phase resistance creeping up over time, which in a
binary read window manifests as cells decaying toward RESET), dead WDM
comb lines (a wavelength lane that no longer carries an input vector)
and whole-tile failures (a broken word-line driver / ADC takes every
cell in the tile to the RESET read). BCIM (arXiv:2211.06261) and the
optical XNOR-bitcount accelerator (arXiv:2302.06405) both flag this
cell non-ideality as the limiting factor for CIM BNN accuracy.

:class:`FaultModel` describes a fault *distribution*; the draw is fully
deterministic: every physical tile gets its own
``np.random.default_rng([seed, tile_id])`` stream, so

* the same (seed, tile) always produces the same stuck-cell masks —
  runs are reproducible and remapping a weight block to a DIFFERENT
  physical tile genuinely escapes the faults of the old one;
* drift is *epoch-monotone*: a cell stuck at epoch e stays stuck at
  every epoch > e (the per-cell uniform draw is fixed; only the
  threshold grows), matching physical drift's one-way direction.

:class:`FaultMap` is the detection result the tolerance half consumes:
the set of physical tiles (and WDM lanes) found faulty, handed to
``CompiledModel.remap`` / the serving health monitor.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


class FaultModelError(ValueError):
    """An inconsistent :class:`FaultModel`."""


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One deterministic description of device faults.

    * ``seed`` — root of the per-tile RNG streams (``[seed, tile]``).
    * ``stuck_set_rate`` / ``stuck_reset_rate`` — per-cell probability
      of being stuck at the SET (reads 1) / RESET (reads 0) conductance
      state. A cell drawn for both is stuck-SET (SET wins ties).
    * ``drift_rate`` — per-epoch conductance-drift rate: the effective
      stuck-RESET fraction grows monotonically as
      ``reset + (1 - reset) * (1 - exp(-drift_rate * epoch))`` — at
      epoch 0 drift has not acted; as epochs advance more cells decay
      into the RESET read window and never come back.
    * ``dead_lanes`` — WDM comb-line indices that carry no input vector
      (capacity loss: effective K shrinks; never a correctness loss —
      the serving planner just stops scheduling slots onto them).
    * ``failed_tiles`` — physical tile ids that are wholly broken:
      every cell reads RESET regardless of programming.
    """

    seed: int = 0
    stuck_set_rate: float = 0.0
    stuck_reset_rate: float = 0.0
    drift_rate: float = 0.0
    dead_lanes: frozenset[int] = frozenset()
    failed_tiles: frozenset[int] = frozenset()

    def __post_init__(self):
        # accept any iterable of ints for the set-valued fields
        object.__setattr__(self, "dead_lanes",
                           frozenset(int(x) for x in self.dead_lanes))
        object.__setattr__(self, "failed_tiles",
                           frozenset(int(x) for x in self.failed_tiles))

    # -- validation ---------------------------------------------------------

    def validate(self) -> "FaultModel":
        if self.seed < 0:
            raise FaultModelError(f"seed must be >= 0, got {self.seed}")
        for name in ("stuck_set_rate", "stuck_reset_rate"):
            rate = getattr(self, name)
            if not 0.0 <= rate <= 1.0:
                raise FaultModelError(f"{name} must be in [0, 1], got {rate}")
        if self.stuck_set_rate + self.stuck_reset_rate > 1.0:
            raise FaultModelError(
                "stuck_set_rate + stuck_reset_rate must be <= 1, got "
                f"{self.stuck_set_rate} + {self.stuck_reset_rate}"
            )
        if self.drift_rate < 0.0:
            raise FaultModelError(
                f"drift_rate must be >= 0, got {self.drift_rate}"
            )
        if any(x < 0 for x in self.dead_lanes):
            raise FaultModelError(f"dead_lanes must be >= 0: {sorted(self.dead_lanes)}")
        if any(x < 0 for x in self.failed_tiles):
            raise FaultModelError(
                f"failed_tiles must be >= 0: {sorted(self.failed_tiles)}"
            )
        return self

    # -- properties ---------------------------------------------------------

    @property
    def cell_pristine(self) -> bool:
        """No mechanism that corrupts cell *values* (dead lanes are a
        capacity loss, not a correctness loss, so they don't count)."""
        return (
            self.stuck_set_rate == 0.0
            and self.stuck_reset_rate == 0.0
            and self.drift_rate == 0.0
            and not self.failed_tiles
        )

    @property
    def is_null(self) -> bool:
        """Completely fault-free: injection is a guaranteed no-op."""
        return self.cell_pristine and not self.dead_lanes

    # -- the draw -----------------------------------------------------------

    def reset_fraction(self, epoch: int) -> float:
        """Effective stuck-RESET cell fraction after ``epoch`` drift
        epochs (monotone in epoch; equals ``stuck_reset_rate`` at 0)."""
        if self.drift_rate == 0.0 or epoch <= 0:
            return self.stuck_reset_rate
        drifted = 1.0 - math.exp(-self.drift_rate * epoch)
        return self.stuck_reset_rate + (1.0 - self.stuck_reset_rate) * drifted

    def tile_cell_masks(
        self, tile: int, rows: int, cols: int, epoch: int = 0,
        failed: bool | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(stuck_SET, stuck_RESET) boolean masks for one physical tile.

        The masks cover the tile's full (rows, cols) cell array; the
        per-cell uniforms are drawn once from ``rng([seed, tile])`` so
        the same tile always faults the same cells, and raising
        ``epoch`` only ever *adds* stuck-RESET cells (drift is one-way).
        ``failed`` overrides the whole-tile state (default: whether
        ``tile`` is in :attr:`failed_tiles`) — a failed tile reads
        RESET everywhere.
        """
        if failed is None:
            failed = tile in self.failed_tiles
        if failed:
            return (
                np.zeros((rows, cols), bool),
                np.ones((rows, cols), bool),
            )
        reset_frac = self.reset_fraction(epoch)
        if self.stuck_set_rate == 0.0 and reset_frac == 0.0:
            z = np.zeros((rows, cols), bool)
            return z, z.copy()
        rng = np.random.default_rng([int(self.seed), int(tile)])
        u = rng.random((2, rows, cols))
        set_mask = u[0] < self.stuck_set_rate
        reset_mask = (u[1] < reset_frac) & ~set_mask  # SET wins ties
        return set_mask, reset_mask

    def describe(self) -> str:
        parts = [f"seed={self.seed}"]
        if self.stuck_set_rate:
            parts.append(f"set={self.stuck_set_rate:g}")
        if self.stuck_reset_rate:
            parts.append(f"reset={self.stuck_reset_rate:g}")
        if self.drift_rate:
            parts.append(f"drift={self.drift_rate:g}/epoch")
        if self.dead_lanes:
            parts.append(f"dead_lanes={sorted(self.dead_lanes)}")
        if self.failed_tiles:
            parts.append(f"failed_tiles={sorted(self.failed_tiles)}")
        if self.is_null:
            parts.append("null")
        return "[faults] " + " ".join(parts)


@dataclasses.dataclass(frozen=True)
class FaultMap:
    """A detection sweep's result: which physical resources are bad.

    ``tiles`` feeds ``CompiledModel.remap`` (move the resident blocks
    off them); ``lanes`` feeds the serving planner's effective-K shrink.
    Truthiness means "something to act on".
    """

    tiles: frozenset[int] = frozenset()
    lanes: frozenset[int] = frozenset()

    def __post_init__(self):
        object.__setattr__(self, "tiles", frozenset(int(x) for x in self.tiles))
        object.__setattr__(self, "lanes", frozenset(int(x) for x in self.lanes))

    def __bool__(self) -> bool:
        return bool(self.tiles) or bool(self.lanes)

    def union(self, other: "FaultMap") -> "FaultMap":
        return FaultMap(tiles=self.tiles | other.tiles,
                        lanes=self.lanes | other.lanes)

    def describe(self) -> str:
        return (
            f"[faultmap] tiles={sorted(self.tiles)} lanes={sorted(self.lanes)}"
        )
