"""Device fault injection + fault tolerance (PR 9).

Two halves, one package:

* **Injection** — :class:`FaultModel` (deterministic, seedable
  stuck-at-SET/RESET masks per physical tile, epoch-monotone
  conductance drift, dead WDM lanes, whole-tile failures) applied by
  :class:`FaultyEngine`, a decorator over any registry backend that
  honors the same ``prepare`` / ``binary_vmm`` / ``binary_mmm``
  contract and corrupts outputs with the algebraically exact delta of
  reading faulted cells. A null model is bit-identical to the plain
  engine by construction.
* **Tolerance** — detection via the TacitMap complement-row
  consistency invariant (``FaultyEngine.consistency_probe`` /
  ``locate``), fault-aware remapping onto a spare-tile pool
  (``repro.mapping.remap_plan`` + ``CompiledModel.remap``), and
  graceful serving degradation (:class:`HealthMonitor`, created
  automatically by the serving engine; only spare exhaustion fails
  requests — as ``serving.DegradedServiceError`` — never the engine).

Wiring: ``HardwareTarget(engine="tiled", mapping_policy=...,
spare_tiles=2, fault_model=FaultModel(...))`` threads everything
through the one-call compiler pipeline; the shared CLI exposes
``--fault-rate`` / ``--fault-seed`` / ``--spare-tiles``.
"""

from repro.faults.engine import (  # noqa: F401
    CELL_DATA_ENGINES,
    FaultInjectionError,
    FaultyEngine,
)
from repro.faults.model import (  # noqa: F401
    FaultMap,
    FaultModel,
    FaultModelError,
)
from repro.faults.monitor import HealthMonitor  # noqa: F401

__all__ = [
    "CELL_DATA_ENGINES",
    "FaultInjectionError",
    "FaultMap",
    "FaultModel",
    "FaultModelError",
    "FaultyEngine",
    "HealthMonitor",
]
