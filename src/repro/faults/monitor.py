"""Serving-side health monitor: detect -> quarantine -> remap -> degrade.

Sits inside :class:`~repro.serving.engine.ServingEngine` (created
automatically when the compiled model's backend is a
:class:`~repro.faults.engine.FaultyEngine`) and closes the fault
tolerance loop at a sampled per-tick rate:

1. **Detect** — every ``check_interval`` ticks, run the compiled
   model's consistency sweep (:meth:`CompiledModel.scan_faults`) over
   all resident artifacts. A clean sweep advances ``last_clean_tick``
   — the watermark the restart logic trusts: a probe-clean tick means
   no *persistent* cell corruption existed at or before it, so
   preemption snapshots taken then are bit-exact.
2. **Quarantine + remap** — faulty tiles go to
   :meth:`CompiledModel.remap`: only the affected blocks move to clean
   spare tiles (BIST-selected via ``FaultyEngine.tile_is_clean``) and
   only those tiles reprogram (priced through the costmodel seam).
   The serving engine rebinds its jitted dispatches, and every
   in-flight request whose state postdates ``last_clean_tick`` restarts
   from scratch (its output may carry corrupted tokens); clean
   snapshots are kept and resume bit-exactly.
3. **Shrink K** — dead WDM lanes are a capacity loss, not a
   correctness loss: the monitor rebinds the serving engine's K-group
   width to the surviving wavelengths (bit-exact by the grouping
   invariant), no restart needed.
4. **Degrade** — only when tolerance is out of moves (spares
   exhausted, no remap path, or the bounded ``max_remaps`` retry
   budget spent) does the scheduler *degrade*: in-flight and queued
   requests FAIL with a named reason (surfaced as
   :class:`~repro.serving.scheduler.DegradedServiceError` on the
   streaming path) and new submissions are rejected — the engine
   object itself never dies.

Retry/backoff: each successful remap pushes the next sweep out by
``backoff_ticks * remaps`` extra ticks, so a fault storm cannot make
the loop thrash remap/reprogram every tick.
"""

from __future__ import annotations

from repro import obs


class HealthMonitor:
    """Sampled fault sweep + bounded remap-and-restart over one
    :class:`~repro.serving.engine.ServingEngine`."""

    def __init__(
        self,
        serving,
        *,
        check_interval: int = 4,
        max_remaps: int = 4,
        backoff_ticks: int = 2,
    ):
        if check_interval < 1:
            raise ValueError(f"check_interval must be >= 1, got {check_interval}")
        if max_remaps < 0:
            raise ValueError(f"max_remaps must be >= 0, got {max_remaps}")
        self.serving = serving
        self.compiled = serving.compiled
        self.check_interval = int(check_interval)
        self.max_remaps = int(max_remaps)
        self.backoff_ticks = int(backoff_ticks)
        self.last_clean_tick = -1     # newest tick a sweep came back clean
        self.remaps = 0
        self.degraded = False
        self.quarantined: set[int] = set()
        self._known_dead_lanes: set[int] = set()
        self._next_check = self.check_interval

    # -- the per-tick hook --------------------------------------------------

    def after_tick(self) -> None:
        """Called by the serving engine at the end of every decode tick
        (one integer compare when no sweep is due)."""
        if self.degraded:
            return
        tick = self.serving._counts["ticks"]
        if tick < self._next_check:
            return
        self._next_check = tick + self.check_interval
        sweep = self.compiled.scan_faults()
        new_lanes = set(sweep.lanes) - self._known_dead_lanes
        if new_lanes:
            self._known_dead_lanes |= new_lanes
            self._shrink_k(new_lanes)
        if not sweep.tiles:
            self.last_clean_tick = tick
            return
        self._handle_tiles(sweep, tick)

    # -- responses ----------------------------------------------------------

    def _shrink_k(self, new_lanes: set[int]) -> None:
        """Dead wavelengths: rebind the serving K to the survivors —
        bit-exact (the grouping invariant), so nothing restarts."""
        old_k = self.serving.group_k
        self.serving._rebind()
        obs.event(
            "fault.k_shrink", track="serve", lanes=sorted(new_lanes),
            k_before=old_k, k_after=self.serving.group_k,
        )

    def _handle_tiles(self, sweep, tick: int) -> None:
        from repro.compiler.target import TargetError
        from repro.faults.engine import FaultInjectionError
        from repro.mapping import SpareTilesExhaustedError

        with obs.span(
            "degraded_tick", track="serve", tick=tick,
            tiles=len(sweep.tiles),
        ):
            if self.remaps >= self.max_remaps:
                self._degrade(
                    f"remap retry budget exhausted ({self.max_remaps}) with "
                    f"tiles {sorted(sweep.tiles)} still faulty"
                )
                return
            try:
                report = self.compiled.remap(sweep)
            except (SpareTilesExhaustedError, TargetError,
                    FaultInjectionError) as e:
                self._degrade(str(e))
                return
            self.remaps += 1
            self.quarantined |= set(sweep.tiles)
            self.serving._rebind()
            restarted = self.serving.scheduler.restart_in_flight(
                clean_before=self.last_clean_tick,
                reason=f"remap off faulty tiles {sorted(sweep.tiles)}",
            )
            # backoff: each remap pushes the next sweep further out so a
            # fault storm can't thrash reprogramming every tick
            self._next_check = (
                tick + self.check_interval + self.backoff_ticks * self.remaps
            )
            obs.event(
                "fault.remap", track="serve", tick=tick,
                tiles=sorted(sweep.tiles), moves=len(report.moves),
                restarted=restarted, spares_left=report.spares_left,
            )

    def _degrade(self, reason: str) -> None:
        self.degraded = True
        obs.event("fault.degrade", track="serve", reason=reason)
        obs.count(
            "repro_degraded_total", 1,
            "serving engines entering degraded service",
        )
        self.serving.scheduler.degrade(reason)
        # replica-level signal (PR 10): a fleet pool subscribes here to
        # mark the replica unhealthy and fail its requests over; FAILED
        # states keep their snapshots, so the pool can salvage the ones
        # at or below last_clean_tick
        if self.serving.on_degrade is not None:
            self.serving.on_degrade(reason)
