"""GPipe pipeline parallelism via shard_map + ppermute.

An optional schedule for the ``pod`` axis: each pod holds a contiguous
block of layers ("stage"); microbatches stream through stages with the
classic GPipe fill/drain bubble (bubble fraction = (P-1)/(P-1+M)).

Implementation notes (and why it looks the way it does):

* The stage function is *uniform* across ranks (SPMD): every rank holds
  its own stage's stacked params; non-resident microbatch slots carry
  zeros and are masked. The rotating buffer moves activations between
  neighbouring stages with ``ppermute`` — one neighbour hop per tick,
  which is exactly the physical DCN/ICI topology cost model.
* ``ppermute`` is pairwise-neighbour-only: tick t sends stage s's
  output to stage s+1. After P-1+M ticks all microbatches have exited.
* Backward pass comes from jax.grad through the whole scan (the scan is
  remat-wrapped) — gradients flow back through the reversed permutes
  automatically; no hand-written backward schedule is needed for GPipe
  semantics (XLA sees the full fwd+bwd graph and schedules both).
* First/last stage embed/unembed: handled by the caller (the pipeline
  moves *hidden states*; embedding and loss run data-parallel on the
  edge stages' ranks via the usual pjit path).

This module is exercised by tests on an 8-device CPU sub-mesh and is
selectable in the launcher with ``--pipeline_stages N`` (maps the `pod`
axis to stages, DESIGN.md §6).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Array = jax.Array


def stage_split(n_layers: int, n_stages: int) -> list[tuple[int, int]]:
    """Contiguous [start, end) layer ranges per stage (balanced)."""
    base, rem = divmod(n_layers, n_stages)
    out, s = [], 0
    for i in range(n_stages):
        e = s + base + (1 if i < rem else 0)
        out.append((s, e))
        s = e
    return out


def gpipe(
    stage_fn: Callable[[Any, Array], Array],
    *,
    mesh: Mesh,
    axis: str = "pod",
    n_microbatches: int,
) -> Callable[[Any, Array], Array]:
    """Build a pipelined apply: (stage_params_stacked, x (M*b, ...)) -> y.

    ``stage_fn(stage_params, x_mb)`` applies ONE stage to ONE microbatch.
    ``stage_params_stacked`` has a leading stage axis sharded over
    ``axis``; x is split into ``n_microbatches`` along dim 0.
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params: Any, x: Array) -> Array:
        m = n_microbatches
        mb = x.shape[0] // m
        xs = x.reshape(m, mb, *x.shape[1:])

        @functools.partial(
            shard_map,
            mesh=mesh,
            in_specs=(P(axis), P()),          # params: stage-sharded; x: replicated
            out_specs=P(),
            check_rep=False,
        )
        def run(sp: Any, xs_rep: Array) -> Array:
            stage = jax.lax.axis_index(axis)
            sp_local = jax.tree.map(lambda t: t[0], sp)  # this rank's stage
            n_ticks = n_stages - 1 + m
            buf = jnp.zeros((mb, *xs_rep.shape[2:]), xs_rep.dtype)
            outs = jnp.zeros_like(xs_rep)

            def tick(carry, t):
                buf, outs = carry
                # stage 0 ingests microbatch t (if any remain)
                mb_idx = jnp.clip(t, 0, m - 1)
                feed = jax.lax.dynamic_index_in_dim(xs_rep, mb_idx, keepdims=False)
                buf = jnp.where((stage == 0) & (t < m), feed, buf)
                # apply this stage
                y = stage_fn(sp_local, buf)
                # last stage emits microbatch t-(P-1)
                out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
                emit = (stage == n_stages - 1) & (t >= n_stages - 1)
                outs = jax.lax.cond(
                    emit,
                    lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                    lambda o: o,
                    outs,
                )
                # rotate: stage s -> s+1 (ring; stage P-1 -> 0 carries junk,
                # overwritten by the stage-0 ingest next tick)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                buf = jax.lax.ppermute(y, axis, perm)
                return (buf, outs), None

            (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
            # only the last stage holds real outputs; share them back
            outs = jax.lax.psum(
                jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis
            )
            return outs

        ys = run(stage_params, xs)
        return ys.reshape(m * mb, *ys.shape[2:])

    return pipelined


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (P-1) / (P-1+M)."""
    return (n_stages - 1) / (n_stages - 1 + n_microbatches)
