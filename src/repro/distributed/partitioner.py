"""PartitionSpec inference: candidate lists + divisibility fallback.

Every parameter/input/cache leaf gets an ordered list of candidate
PartitionSpecs (most-sharded first) selected by its tree path; the
first candidate whose every sharded dim divides evenly wins. This one
mechanism yields, across the 10 assigned architectures:

* TP       — attention heads / FFN hidden / vocab over ``model``
* FSDP     — the complementary weight dim over ``data`` (ZeRO-3-style;
             optimizer state inherits the same specs, so Adam moments
             shard identically for free)
* EP       — MoE expert dim over ``model`` when E % tp == 0 (qwen3's
             128, jamba's 16), falling back to FFN-dim TP when not
             (grok's 8 on a 16-way axis)
* SP       — decode KV caches sequence-sharded over ``model`` (and over
             ``data`` too for long_500k, where batch=1 gives data
             nothing else to do)
* DP       — batch over (``pod``, ``data``): pure DP across the pod
             axis (DCN-friendly: only gradient all-reduce crosses pods)

Divisibility fallback examples: tinyllama's 4 KV heads can't shard over
a 16-way model axis -> its KV projections replicate while Q stays TP;
internvl2's 151655 vocab is odd -> the embedding shards d_model
instead.

The inference is *static* (operates on shapes, no device state), so the
dry-run can build specs for 512-device meshes before any allocation.
"""

from __future__ import annotations

import re
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

MODEL = "model"


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Pure-DP axes: ('pod', 'data') on multi-pod meshes, ('data',) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _fits(spec: P, shape: tuple[int, ...], mesh: Mesh) -> bool:
    if len(spec) > len(shape):
        return False
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        size = 1
        for a in axes:
            if a not in mesh.shape:
                return False
            size *= mesh.shape[a]
        if dim % size != 0:
            return False
    return True


def first_fitting(
    candidates: Sequence[P], shape: tuple[int, ...], mesh: Mesh
) -> P:
    for c in candidates:
        if _fits(c, shape, mesh):
            return c
    return P()


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (path regex, candidate builder). ``d`` = data axis for FSDP. Param
# tensors under ``blocks`` carry a leading repeat/stack dim (scan), and
# enc/dec blocks a leading layer dim — handled by the ``lead`` prefix.
def _param_rules(d: str, fsdp: bool = False):
    R = None  # leading repeat dim: never sharded
    # ZeRO-3 training: no vocab parallelism — a V-sharded table forces a
    # full (V, d) fp32 materialization in the embedding-grad scatter
    # when the batch owns the model axis (measured: 4.6 GiB/dev x
    # several copies on qwen2-72b). d-sharded tables scatter shard-local.
    embed_cands = (
        [P(None, MODEL), P(d, MODEL), P(None, d), P()]
        if fsdp
        else [P(MODEL, d), P(None, MODEL), P(None, d), P()]
    )
    # head stays 2D (d over data, V over model) in BOTH modes: the loss
    # region pins its batch to the data axes only (hint "dp_strict"), so
    # the vocab-parallel logits/lse/grad all stay sharded — the
    # alternative (V replicated) materializes a full (d, V) fp32 head
    # gradient per device and all-reduces it once per loss chunk.
    head_cands = [P(d, MODEL), P(MODEL, None), P(d, None), P()]
    return [
        # embeddings / lm head
        (r"(^|/)embed$", embed_cands),
        (r"(^|/)head$", head_cands),
        # attention projections (leading repeat dim under blocks)
        (r"attn/[qkv]/w$", [P(R, d, MODEL), P(R, d, None), P(R, None, None)]),
        (r"attn/[qkv]/b$", [P(R, MODEL), P(R, None)]),
        (r"attn/o/w$", [P(R, MODEL, d), P(R, None, d), P(R, None, None)]),
        (r"attn/o/b$", [P(R, None)]),
        # dense FFN
        (r"ffn/w[13]/w$", [P(R, d, MODEL), P(R, d, None), P(R, None, None)]),
        (r"ffn/w2/w$", [P(R, MODEL, d), P(R, None, d), P(R, None, None)]),
        # MoE: experts over model (EP) else ffn-dim over model (TP)
        (r"moe/router$", [P(R, d, None), P(R, None, None)]),
        (r"moe/w[13]$", [P(R, MODEL, d, None), P(R, None, d, MODEL), P(R, None, d, None), P(R, None, None, None)]),
        (r"moe/w2$", [P(R, MODEL, None, d), P(R, None, MODEL, d), P(R, None, None, d), P(R, None, None, None)]),
        # mamba (unfused projections; see models/ssm.py)
        (r"mamba/[zx]_proj/w$", [P(R, d, MODEL), P(R, d, None), P(R, None, None)]),
        (r"mamba/[bc]_proj/w$", [P(R, d, None), P(R, None, None)]),
        (r"mamba/dt_proj/w$", [P(R, d, MODEL), P(R, d, None), P(R, None, None)]),
        (r"mamba/conv_x$", [P(R, None, MODEL), P(R, None, None)]),
        (r"mamba/conv_[bc]$", [P(R, None, None)]),
        (r"mamba/conv_bias_x$", [P(R, MODEL), P(R, None)]),
        (r"mamba/conv_bias_[bc]$", [P(R, None)]),
        (r"mamba/(A_log|D|dt_bias)$", [P(R, MODEL), P(R, None)]),
        (r"mamba/norm$", [P(R, MODEL), P(R, None)]),
        (r"mamba/out_proj/w$", [P(R, MODEL, d), P(R, None, d), P(R, None, None)]),
        # norms and everything else: replicated (beyond the repeat dim)
        (r"norm", [P()]),
    ]


def _path_of(key_path) -> str:
    parts = []
    for p in key_path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _strip_lead(spec_dims: tuple, shape: tuple[int, ...]) -> P:
    """Right-align a spec against a shape (leading stack dims -> None)."""
    pad = len(shape) - len(spec_dims)
    if pad < 0:
        return P(*spec_dims[-len(shape):]) if len(shape) else P()
    return P(*([None] * pad), *spec_dims)


def infer_specs(tree: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """Param pytree (arrays or ShapeDtypeStructs) -> PartitionSpec pytree."""
    d = "data" if "data" in mesh.shape else None
    rules = [(re.compile(rx), cands) for rx, cands in _param_rules(d, fsdp)]

    def leaf_spec(key_path, leaf) -> P:
        path = _path_of(key_path)
        shape = tuple(leaf.shape)
        for rx, cands in rules:
            if rx.search(path):
                aligned = [_strip_lead(tuple(c), shape) for c in cands]
                return first_fitting(aligned, shape, mesh)
        # default: FSDP the biggest dim over data if it divides
        if shape and d is not None:
            big = max(range(len(shape)), key=lambda i: shape[i])
            cand = P(*[d if i == big else None for i in range(len(shape))])
            return first_fitting([cand], shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


def opt_state_specs(param_specs: Any, opt_state: Any) -> Any:
    """Adam m/v (and the fp32 master copy, when present) inherit the
    param specs (factored v stats drop the last/-2nd dim respectively);
    step is replicated."""

    def v_spec(ps: P, v):
        if isinstance(v, dict):  # factored {vr, vc}
            dims = tuple(ps)
            return {
                "vr": P(*dims[:-1]) if dims else P(),
                "vc": P(*dims[:-2], *dims[-1:]) if len(dims) >= 2 else P(),
            }
        return ps

    v_specs = jax.tree.map(
        v_spec, param_specs, opt_state["v"], is_leaf=lambda x: isinstance(x, P)
    )
    specs = {"step": P(), "m": param_specs, "v": v_specs}
    if "master" in opt_state:
        specs["master"] = param_specs
    return specs


# ---------------------------------------------------------------------------
# Inputs / caches
# ---------------------------------------------------------------------------


def batch_specs(specs_tree: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """Inputs: batch dim over the DP axes; ``fsdp=True`` tries the model
    axis too (ZeRO-3 training: activations own every mesh axis, weights
    are gathered per layer), falling back down a divisibility ladder."""
    dp = data_axes(mesh)

    ladder: list[tuple] = []
    if fsdp:
        ladder.append((*dp, MODEL))
        if len(dp) > 1:  # multi-pod: ("data", "model") before plain DP
            ladder.append((dp[-1], MODEL))
    ladder.append(dp)
    if dp:
        ladder.append((dp[-1],))

    def leaf(key_path, leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        aligned = [P(c, *([None] * (len(shape) - 1))) for c in ladder]
        return first_fitting(aligned, shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, specs_tree)


def fsdp_batch_axes(batch_size: int, mesh: Mesh) -> tuple[str, ...]:
    """The axis tuple the FSDP ladder would give this batch size."""
    dp = data_axes(mesh)
    for cand in ((*dp, MODEL), (dp[-1], MODEL) if dp else (MODEL,), dp, (dp[-1],) if dp else ()):
        n = 1
        for a in cand:
            n *= mesh.shape.get(a, 10**9)
        if cand and batch_size % n == 0:
            return tuple(cand)
    return ()


def cache_specs(cache_tree: Any, mesh: Mesh, *, seq_axis_hint: int = 2) -> Any:
    """Decode caches. KV caches are (L, B, T, KV, D): batch over data,
    T (sequence) over model — SP for the softmax reductions. When batch
    can't use the data axis (long_500k's B=1), T takes (data, model).
    SSM states (L, B, H, N, P) shard H over model; conv states shard
    their channel dim."""
    dp = data_axes(mesh)
    dlast = dp[-1] if dp else None

    def leaf(key_path, leaf) -> P:
        path = _path_of(key_path)
        shape = tuple(leaf.shape)
        if "ssm" in path and len(shape) == 5:  # (L,B,H,N,P)
            cands = [
                P(None, dlast, MODEL, None, None),
                P(None, None, (*dp, MODEL), None, None),
                P(None, None, MODEL, None, None),
                P(),
            ]
        elif "conv" in path and len(shape) == 4:  # (L,B,K-1,C)
            cands = [
                P(None, dlast, None, MODEL),
                P(None, None, None, (*dp, MODEL)),
                P(None, None, None, MODEL),
                P(),
            ]
        elif len(shape) == 5:  # attn KV (L,B,T,KV,D)
            cands = [
                P(None, dlast, MODEL, None, None),
                P(None, None, (*dp, MODEL), None, None),
                P(None, None, MODEL, None, None),
                P(),
            ]
        elif len(shape) == 4:  # enc-dec KV without layer stack? (B,T,KV,D)
            cands = [P(dlast, MODEL, None, None), P(None, MODEL, None, None), P()]
        elif len(shape) >= 1:
            cands = [P(dlast), P()]
        else:
            return P()
        return first_fitting(cands, shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------


def named_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def validate_specs(tree: Any, specs: Any, mesh: Mesh) -> list[str]:
    """Return a list of violations (empty == all specs divide evenly)."""
    problems: list[str] = []

    def check(key_path, leaf, spec):
        if not _fits(spec, tuple(leaf.shape), mesh):
            problems.append(f"{_path_of(key_path)}: {spec} !~ {tuple(leaf.shape)}")

    jax.tree_util.tree_map_with_path(
        check, tree, specs, is_leaf=lambda x: isinstance(x, P)
    )
    return problems
