"""int8 gradient compression with error feedback.

Cross-pod (DCN) gradient all-reduce is the bandwidth-critical collective
in multi-pod DP: bf16 gradients at 398B params are ~0.8 TB per step per
direction. Quantizing to int8 (per-tensor absmax scale) halves DCN bytes
vs bf16; the quantization residual is carried in an error-feedback
buffer (Seide et al. 2014; Karimireddy et al. 2019) so the *accumulated*
gradient is unbiased and SGD converges at the uncompressed rate.

API is pure-functional: state pytree mirrors the grad pytree.

    state = ef_init(grads_shape)
    grads_c, state = compress_grads(grads, state)      # before all-reduce
    grads   = decompress_grads(grads_c)                # after all-reduce

``compressed_all_reduce_mean`` fuses the three for shard_map regions.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

_QMAX = 127.0


def ef_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x: Array) -> tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def _dequantize(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, ef_state: Any) -> tuple[Any, Any]:
    """Returns ({q, scale} pytree, new error-feedback state)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        new_e = x - _dequantize(q, scale)  # residual stays local
        return {"q": q, "scale": scale}, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = treedef.unflatten([o[0] for o in out])
    new_ef = treedef.unflatten([o[1] for o in out])
    return comp, new_ef


def decompress_grads(comp: Any) -> Any:
    return jax.tree.map(
        lambda c: _dequantize(c["q"], c["scale"]),
        comp,
        is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "scale"},
    )


def compressed_all_reduce_mean(grads: Any, ef_state: Any, axis_name: str) -> tuple[Any, Any]:
    """int8-on-the-wire mean all-reduce for shard_map regions.

    int8 tensors all-to-all'd as int32 partial sums (psum of int8 would
    overflow at >127 ranks): we dequantize-then-psum the int8 payload —
    the WIRE tensor is the int8 q (what the DCN moves when XLA fuses the
    convert into the collective); scales psum alongside.
    """
    comp, new_ef = compress_grads(grads, ef_state)

    def reduce_one(c):
        # mean of per-rank dequantized grads
        s = jax.lax.psum(c["q"].astype(jnp.float32) * c["scale"], axis_name)
        return s / jax.lax.psum(1, axis_name)

    reduced = jax.tree.map(
        reduce_one, comp, is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    )
    return reduced, new_ef
