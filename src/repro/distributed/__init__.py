"""Distribution layer: sharding rules, pipeline parallelism, gradient
compression, and collective helpers — the 1000-node posture.

  partitioner.py  candidate-list PartitionSpec inference with
                  divisibility fallback (DP/TP/EP/SP from one rule set)
  pipeline.py     GPipe microbatch schedule via shard_map + ppermute
  compression.py  int8 error-feedback gradient all-reduce
  collectives.py  overlap-friendly reduce-scatter / all-gather helpers
"""

from repro.distributed.partitioner import (
    batch_specs,
    cache_specs,
    data_axes,
    infer_specs,
    named_shardings,
    opt_state_specs,
    validate_specs,
)
