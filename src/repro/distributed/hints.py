"""Activation sharding hints (logical-axis constraints).

XLA's SPMD propagation is greedy: without mid-graph anchors it happily
replicates attention heads / SSD heads / MoE buffers over the model
axis inside scanned layers (while-loop carries force one sharding per
buffer, and the propagation pass often picks the replicated fixpoint).
A handful of ``with_sharding_constraint`` anchors at the block
boundaries pins the intended layout — measured on qwen1.5-0.5b
train_4k, anchoring q/k/v heads cut per-device attention FLOPs 16x
(see EXPERIMENTS.md §Perf).

The hints are *contextual* so model code stays mesh-agnostic:

    with activation_hints(mesh):
        lowered = jit(step).lower(...)

``hint(x, *axes)`` is a no-op outside the context (CPU unit tests) and
silently drops any axis that does not divide the corresponding dim
(tinyllama's 4 KV heads on a 16-way model axis -> that dim replicates,
everything else still shards).

Axis vocabulary: "dp" (all pure-DP axes: pod+data), "data", "model",
None. Dims beyond the given axes replicate.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()

MODEL_AXIS = "model"


@contextmanager
def activation_hints(mesh: Mesh | None, *, batch_axes: tuple | None = None, tp: bool = True):
    """``batch_axes`` overrides what "dp" resolves to (FSDP mode shards
    the batch over the model axis too); ``tp=False`` drops all "model"
    hints (no tensor parallelism — ZeRO-3-style training where weights
    are gathered per layer and activations own every mesh axis)."""
    prev = (getattr(_CTX, "mesh", None), getattr(_CTX, "batch_axes", None),
            getattr(_CTX, "tp", True))
    _CTX.mesh, _CTX.batch_axes, _CTX.tp = mesh, batch_axes, tp
    try:
        yield
    finally:
        _CTX.mesh, _CTX.batch_axes, _CTX.tp = prev


def current_mesh() -> Mesh | None:
    return getattr(_CTX, "mesh", None)


def _resolve(axis, mesh: Mesh):
    """'dp' -> the context batch axes (default: pod+data); 'model' ->
    itself unless TP is disabled in this context."""
    if axis is None:
        return None
    if axis == "dp":
        override = getattr(_CTX, "batch_axes", None)
        if override is not None:
            axes = tuple(a for a in override if a in mesh.shape)
        else:
            axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        return axes if axes else None
    if axis == "dp_strict":
        # always the pure-DP axes, ignoring any FSDP batch override —
        # used where another dim owns the model axis (vocab-parallel loss)
        axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        return axes if axes else None
    if axis == "model" and not getattr(_CTX, "tp", True):
        return None
    if axis == "model_strict":  # model axis even when TP is off (vocab-parallel loss)
        axis = MODEL_AXIS
    return axis if axis in mesh.shape else None


def _axis_size(axis, mesh: Mesh) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def hint(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x``'s leading dims to ``axes`` (see module docstring)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, axis in zip(x.shape, axes):
        r = _resolve(axis, mesh)
        spec.append(r if r is not None and dim % _axis_size(r, mesh) == 0 else None)
    if not any(s is not None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
