"""Collective helpers for overlap-friendly gradient paths.

Used inside ``shard_map`` regions (the pipeline, the compressed
all-reduce). For the pjit path, XLA's SPMD partitioner emits the
collectives; overlap there is enabled by the latency-hiding-scheduler
flags set in ``launch/train.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ring_all_reduce_mean(x: Array, axis_name: str) -> Array:
    """psum / axis_size — the canonical DP gradient reduction."""
    return jax.lax.psum(x, axis_name) / jax.lax.psum(1, axis_name)


def reduce_scatter_mean(x: Array, axis_name: str, *, scatter_dim: int = 0) -> Array:
    """ZeRO-2 gradient path: each rank keeps 1/N of the reduced tensor.

    Returns the local shard (dim ``scatter_dim`` divided by axis size).
    """
    n = jax.lax.psum(1, axis_name)
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=scatter_dim, tiled=True) / n


def all_gather_dim(x: Array, axis_name: str, *, dim: int = 0) -> Array:
    """Inverse of ``reduce_scatter_mean`` (parameter re-materialization)."""
    return jax.lax.all_gather(x, axis_name, axis=dim, tiled=True)


def ppermute_shift(x: Array, axis_name: str, shift: int = 1) -> Array:
    """Neighbour exchange on a ring — the pipeline's stage hand-off."""
    n = jax.lax.psum(1, axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name, perm)
