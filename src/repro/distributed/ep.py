"""Hand-written expert-parallel MoE dispatch (shard_map + all_to_all).

Why this exists: under pjit/SPMD, expert parallelism must be *inferred*
by XLA from sharding annotations — and when the batch also owns the
model axis (ZeRO-3 training), the partitioner replicates the dispatch
instead of emitting an all-to-all (measured 47 -> 542 GiB/dev on qwen3
when hints tried to force it; EXPERIMENTS.md §Perf cell 1 #6). The
SPMD-expressible fallback (ZeRO weight-gather of ALL experts per layer)
costs 19 GiB/layer on jamba. This module writes the collective program
by hand instead:

* the ``model`` axis is MANUAL (shard_map): rank r holds E/R experts
  and B·S/R token rows (in ZeRO-3 training the batch is already spread
  over the model axis — exactly what EP wants);
* each rank routes its local tokens, buckets them by destination rank
  (owner(e) = e // E_local) with capacity C per (src, dst) pair, and
  ``jax.lax.all_to_all`` moves one (R, C, d) buffer each way —
  expert weights NEVER move;
* expert ids travel with the payload (packed as an extra channel), so
  the receiving rank computes its local experts' FFN on exactly the
  tokens it owns;
* the return all_to_all routes outputs back; gates combine locally.
  Dropped (over-capacity) tokens contribute zero, same policy as the
  pjit path.

Differentiable end-to-end (shard_map + all_to_all transpose = the
reverse all_to_all). Verified against the pjit ``moe_ffn`` reference at
drop-free capacity on an 8-device mesh (tests/test_multidevice.py).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig

Array = jax.Array


def _local_dispatch(x2d: Array, logits: Array, n_ranks: int, e_local: int,
                    k: int, cap: int):
    """Bucket local tokens by destination rank.

    x2d (T, d); logits (T, E). Returns (send (R, C, d), send_eid (R, C)
    in [0, e_local) or -1, send_tok (R, C) source token index or -1,
    gates (T, k), top_idx (T, k)).
    """
    t, d = x2d.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, top_idx = jax.lax.top_k(probs, k)                    # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = top_idx.reshape(-1)                                # (T*k,)
    dest = flat_e // e_local                                    # (T*k,)
    # slot within (dest rank, capacity): running count per destination
    onehot = jax.nn.one_hot(dest, n_ranks, dtype=jnp.float32)   # (T*k, R)
    slot = (jnp.cumsum(onehot, axis=0) - onehot) * onehot       # pos within dest
    slot = jnp.sum(slot, axis=-1).astype(jnp.int32)             # (T*k,)
    keep = slot < cap

    x_rep = jnp.repeat(x2d, k, axis=0)                          # (T*k, d)
    send = jnp.zeros((n_ranks, cap, d), x2d.dtype)
    send_eid = jnp.full((n_ranks, cap), -1, jnp.int32)
    send_tok = jnp.full((n_ranks, cap), -1, jnp.int32)
    upd = jnp.where(keep[:, None], x_rep, 0).astype(x2d.dtype)
    send = send.at[dest, slot].add(jnp.where(keep[:, None], upd, 0), mode="drop")
    send_eid = send_eid.at[dest, slot].set(
        jnp.where(keep, flat_e % e_local, -1), mode="drop"
    )
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    send_tok = send_tok.at[dest, slot].set(jnp.where(keep, tok_ids, -1), mode="drop")
    return send, send_eid, send_tok, gates, top_idx, dest, slot, keep


def _expert_ffn(recv: Array, recv_eid: Array, w1, w3, w2) -> Array:
    """(R*C, d) tokens with local-expert ids -> outputs (R*C, d)."""
    e_local = w1.shape[0]
    sel = jnp.clip(recv_eid, 0, e_local - 1)
    valid = (recv_eid >= 0)[:, None]
    w1g = w1[sel]                                  # (N, d, f)
    w3g = w3[sel]
    w2g = w2[sel]                                  # (N, f, d)
    h = jnp.einsum("nd,ndf->nf", recv, w1g.astype(recv.dtype))
    g = jnp.einsum("nd,ndf->nf", recv, w3g.astype(recv.dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(recv.dtype) * g
    out = jnp.einsum("nf,nfd->nd", h, w2g.astype(h.dtype))
    return jnp.where(valid, out, 0)


def ep_moe_ffn(
    p: dict[str, Array],
    x: Array,
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    axis: str = "model",
    batch_spec: P | None = None,
) -> tuple[Array, Array]:
    """Expert-parallel MoE FFN. x (B, S, d) with its batch sharded over
    (at least) ``axis``; expert weights (E, d, f) sharded on E over
    ``axis``. Router weights replicated over ``axis``.

    Returns (out (B, S, d), aux load-balance loss).
    """
    n_ranks = mesh.shape[axis]
    e, k = cfg.moe_experts, cfg.moe_top_k
    assert e % n_ranks == 0, f"{e} experts on a {n_ranks}-way axis"
    e_local = e // n_ranks
    b, s, d = x.shape
    t_local = (b * s) // n_ranks  # token rows per rank (batch spread over axis)
    # per-(src,dst) capacity: average tokens*k per expert * factor, split by rank
    cap = max(1, math.ceil(t_local * k / n_ranks * cfg.moe_capacity_factor))

    other = tuple(a for a in mesh.axis_names if a != axis)

    def body(xl, router, w1, w3, w2):
        # xl: this rank's (b_l, s, d) token rows; weights: (e_local, ...)
        bl = xl.shape[0] * xl.shape[1]
        x2d = xl.reshape(bl, d)
        logits = x2d.astype(jnp.float32) @ router.astype(jnp.float32)
        send, send_eid, send_tok, gates, top_idx, dest, slot, keep = _local_dispatch(
            x2d, logits, n_ranks, e_local, k, cap
        )
        # move buckets: (R, C, *) -> received-from-each-rank (R, C, *)
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid, axis, 0, 0, tiled=False)
        out_e = _expert_ffn(
            recv.reshape(n_ranks * cap, d), recv_eid.reshape(n_ranks * cap), w1, w3, w2
        ).reshape(n_ranks, cap, d)
        back = jax.lax.all_to_all(out_e, axis, 0, 0, tiled=False)  # (R, C, d)
        # combine: token i's k results live at (dest[i*k+j], slot[i*k+j])
        got = back[dest, slot] * keep[:, None]                     # (T*k, d)
        y = (got.reshape(bl, k, d) * gates[..., None].astype(got.dtype)).sum(axis=1)
        # aux loss from local stats (averaged over ranks by the outer psum)
        onehot_e = jax.nn.one_hot(top_idx.reshape(-1), e, dtype=jnp.float32)
        frac = onehot_e.mean(axis=0) * e / k
        mean_prob = jax.nn.softmax(logits, axis=-1).mean(axis=0)
        aux = jnp.sum(frac * mean_prob) * e / e  # E * sum(f_e * P_e) shape
        aux = jax.lax.pmean(aux, axis)
        return y.reshape(xl.shape).astype(x.dtype), aux

    in_specs = (
        batch_spec if batch_spec is not None else P(axis, None, None),  # x rows over axis
        P(),                      # router replicated over axis
        P(axis, None, None),      # w1 (E, d, f) experts over axis
        P(axis, None, None),      # w3
        P(axis, None, None),      # w2
    )
    del other
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(in_specs[0], P()),
            axis_names={axis},   # MANUAL over the model axis only
            check_vma=False,
        )
    else:  # older jax: experimental shard_map, manual-over-one-axis via `auto`
        from jax.experimental.shard_map import shard_map as _shard_map

        fn = _shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(in_specs[0], P()),
            check_rep=False,
            auto=frozenset(mesh.axis_names) - {axis},
        )
    return fn(x, p["router"], p["w1"], p["w3"], p["w2"])
