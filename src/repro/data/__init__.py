"""Deterministic, step-indexed synthetic data pipelines.

Restart safety is structural: batch(step) is a pure function of
(seed, step, shape), so a resumed/elastically-rescaled job regenerates
the exact stream with no data-loader state in checkpoints.
"""

from repro.data.synthetic import (
    bnn_image_batch,
    frontend_embeds,
    lm_batch,
    make_input_specs,
    token_count,
)
