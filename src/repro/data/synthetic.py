"""Synthetic data generation + ShapeDtypeStruct stand-ins.

Two call families:

* ``lm_batch`` / ``bnn_image_batch`` / ``frontend_embeds`` — REAL
  arrays, deterministic in (seed, step). Used by examples, smoke tests,
  and the training loop. LM tokens follow a skewed (Zipf-ish) marginal
  so losses have realistic structure rather than uniform noise.
* ``make_input_specs`` — ShapeDtypeStruct pytrees mirroring the real
  batches, used by the dry-run (never allocates; shard-able).

Shape conventions per cell kind (see ``ModelConfig``/``ShapeConfig``):

  train    {tokens (B, S) i32} (+ extra_embeds / src_embeds for
           vlm / encdec frontends — stub embeddings per the brief)
  prefill  same tokens pytree, lowered against ``prefill``
  decode   {token (B,) i32, pos scalar i32, caches pytree(S_cache)}
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig, ShapeConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Real batches (deterministic in (seed, step))
# ---------------------------------------------------------------------------


def _fold(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.key(seed), step)


def lm_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0, step: int = 0) -> dict:
    """Skewed synthetic token batch; pure function of (seed, step)."""
    key = _fold(seed, step)
    k1, k2 = jax.random.split(key)
    # Zipf-ish marginal: exp-distributed logits over a vocab-sized support
    u = jax.random.uniform(k1, (batch, seq), minval=1e-6, maxval=1.0)
    r = jnp.floor(-jnp.log(u) * cfg.vocab_size / 8.0).astype(jnp.int32)
    tokens = jnp.clip(r, 0, cfg.vocab_size - 1)
    out: dict[str, Any] = {"tokens": tokens}
    if cfg.frontend == "vision":
        out["extra_embeds"] = frontend_embeds(cfg, batch, key=k2)
    elif cfg.is_encdec:
        out["src_embeds"] = frontend_embeds(cfg, batch, key=k2)
    return out


def frontend_embeds(cfg: ModelConfig, batch: int, *, key: jax.Array | None = None) -> Array:
    """Stub modality frontend: unit-variance patch/frame embeddings."""
    if key is None:
        key = jax.random.key(0)
    return jax.random.normal(key, (batch, cfg.frontend_len, cfg.d_model), jnp.float32)


def bnn_image_batch(
    n: int, shape: tuple[int, ...] = (28, 28, 1), classes: int = 10, *, seed: int = 0, step: int = 0
) -> tuple[Array, Array]:
    """Class-conditional synthetic images (MNIST/CIFAR stand-ins): each
    class is a fixed random template + noise, so BNNs actually learn."""
    key = _fold(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (n,), 0, classes)
    templates = jax.random.normal(jax.random.key(seed + 999), (classes, *shape))
    x = templates[labels] + 0.5 * jax.random.normal(k2, (n, *shape))
    del k3
    return x.astype(jnp.float32), labels


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _spec_like(tree):
    return jax.tree.map(lambda x: _sds(x.shape, x.dtype), tree)


def make_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct pytree for one (arch x shape) cell.

    Weak-type-correct and shardable; mirrors exactly what the train /
    prefill / decode entry points take (see launch/dryrun.py).
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs: dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.frontend == "vision":
            specs["extra_embeds"] = _sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
        elif cfg.is_encdec:
            specs["src_embeds"] = _sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
        return specs
    # decode: one new token against a seq_len-deep cache
    if cfg.is_encdec:
        cache = jax.eval_shape(
            lambda: encdec.init_cache(cfg, b, s, src_len=cfg.frontend_len)
        )
    else:
        cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
    return {
        "token": _sds((b,), jnp.int32),
        "pos": _sds((), jnp.int32),
        "caches": _spec_like(cache),
    }


def token_count(shape: ShapeConfig) -> int:
    """Tokens processed by one lowered step (decode steps process B)."""
    if shape.kind == "decode":
        return shape.global_batch
    return shape.global_batch * shape.seq_len
