"""Tile allocator: layer IR -> explicit block-to-tile placement.

This is the artifact the repo previously lacked: a static, inspectable
answer to "which crossbar tile holds which weight block". Every policy
shares the TacitMap functional layout — a binarized (m, n) matrix is
stored complement-stacked as (2m, n) (Fig. 2-(b)) and cut into
``spec.rows x spec.cols`` blocks — and differs in how those blocks are
*assigned to physical tiles*:

* ``tacitmap``      — the paper's layout order: blocks walk the stacked
  matrix row-major (a weight block and its complement land on vertically
  adjacent tiles) and claim fresh tiles sequentially.
* ``column-major``  — blocks walk column-major (all row blocks of one
  output column group stay adjacent — partial-sum adders see a
  contiguous tile run); BCIM-style column-serial layouts order this way.
* ``greedy``        — longest-processing-time load balancing: blocks
  (weighted by active cells x instance count) go to the least-loaded
  physical tile. Only meaningful under a ``tile_budget``; without one it
  degenerates to one tile per block like the others.

``tile_budget`` models a fixed accelerator: fewer physical tiles than
weight blocks forces co-residency (a tile stores several blocks side by
side in its spare columns / is time-multiplexed between them), and a
layer whose blocks share a tile pays serialized activations per input
vector — ``LayerPlan.steps_per_vector``. The *functional* engines are
unaffected (placement never changes the math, tests assert bit-exactness
for every policy); the scheduler and cost model charge the serialization.

WDM: every placement records the wavelength set its layer streams over
(``range(spec.wdm_k)``); ``MappingPlan.preferred_group_size()`` is the K
the serving BatchPlanner consults.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable

from repro.core.crossbar import CrossbarSpec, EPCM_TILE, TileGrid
from repro.mapping.ir import LayerIR, ModelIR, to_ir

POLICIES: tuple[str, ...] = ("tacitmap", "column-major", "greedy")


class SpareTilesExhaustedError(RuntimeError):
    """A remap needed more clean spare tiles than the plan has left."""


@dataclasses.dataclass(frozen=True)
class BlockPlacement:
    """One ``spec.rows x spec.cols`` weight block pinned to a tile."""

    layer: str          # owning layer instance (LayerPlan.name)
    row_block: int      # index over the complement-stacked (2m) row axis
    col_block: int      # index over the stored-column axis
    tile: int           # physical tile id (plan-global)
    rows_used: int      # active rows in this block (<= spec.rows)
    cols_used: int      # active cols in this block (<= spec.cols)

    @property
    def cells(self) -> int:
        return self.rows_used * self.cols_used


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Placement of ONE layer instance's complement-stacked matrix."""

    name: str                       # instance name, e.g. "slot0.ffn.w1[3]"
    ir: LayerIR                     # the IR entry this instance came from
    grid: TileGrid                  # complement-stacked (2m, n) tiling
    blocks: tuple[BlockPlacement, ...]
    wavelengths: tuple[int, ...]    # WDM comb lines this layer streams over

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def cells_used(self) -> int:
        return sum(b.cells for b in self.blocks)

    @property
    def tiles(self) -> tuple[int, ...]:
        """Distinct physical tiles this instance occupies."""
        return tuple(sorted({b.tile for b in self.blocks}))

    @property
    def steps_per_vector(self) -> int:
        """Serialized tile passes per input vector: co-resident blocks of
        the SAME layer share their tile's ADC chain and fire in turn."""
        per_tile: dict[int, int] = {}
        for b in self.blocks:
            per_tile[b.tile] = per_tile.get(b.tile, 0) + 1
        return max(per_tile.values())

    def block_order(self) -> tuple[tuple[int, int], ...]:
        """(row_block, col_block) in placement order — the slice order
        the `tiled` engine executes."""
        return tuple((b.row_block, b.col_block) for b in self.blocks)


@dataclasses.dataclass(frozen=True)
class MappingPlan:
    """The static compilation artifact: every weight block, placed.

    ``layers`` holds one :class:`LayerPlan` per layer *instance* (IR
    ``count`` is expanded, so an LM's scanned repeats are all visible).
    ``n_tiles`` is the physical tile pool the plan provisions; with a
    ``tile_budget`` smaller than the block count, utilization may exceed
    1.0 — that is over-subscription, paid for in
    ``LayerPlan.steps_per_vector`` serialization.
    """

    model: ModelIR
    spec: CrossbarSpec
    policy: str
    tile_budget: int | None
    layers: tuple[LayerPlan, ...]
    # fault tolerance (PR 9): physical tiles provisioned as remap
    # destinations but holding no data yet, and tiles the allocator was
    # told to avoid (known-bad hardware / quarantined after a remap)
    spares: tuple[int, ...] = ()
    avoid_tiles: tuple[int, ...] = ()

    @property
    def n_tiles(self) -> int:
        used = max(b.tile for lp in self.layers for b in lp.blocks)
        if self.spares:
            used = max(used, max(self.spares))
        return 1 + used

    @property
    def n_blocks(self) -> int:
        return sum(lp.n_blocks for lp in self.layers)

    @property
    def cells_used(self) -> int:
        return sum(lp.cells_used for lp in self.layers)

    def utilization(self) -> float:
        """Active cells / provisioned cells (> 1.0 = over-subscribed)."""
        cap = self.n_tiles * self.spec.rows * self.spec.cols
        return self.cells_used / cap

    def tile_loads(self) -> dict[int, int]:
        """Physical tile id -> active cells resident on it."""
        loads: dict[int, int] = {}
        for lp in self.layers:
            for b in lp.blocks:
                loads[b.tile] = loads.get(b.tile, 0) + b.cells
        return loads

    def preferred_group_size(self) -> int:
        """The WDM K the serving BatchPlanner should group decode by."""
        return self.spec.wdm_k

    def layer(self, name: str) -> LayerPlan:
        for lp in self.layers:
            if lp.name == name:
                return lp
        raise KeyError(f"no layer instance {name!r} in plan for {self.model.name}")

    def layer_for(self, m: int, n: int) -> LayerPlan | None:
        """First placed instance matching a (m, n) weight matrix — the
        `tiled` engine's lookup when handed raw operands."""
        for lp in self.layers:
            if lp.ir.binary and lp.ir.m == m and lp.ir.n == n:
                return lp
        return None

    def instances(self, ir_name: str) -> tuple[LayerPlan, ...]:
        return tuple(lp for lp in self.layers if lp.ir.name == ir_name)


# ---------------------------------------------------------------------------
# Block enumeration + tile assignment
# ---------------------------------------------------------------------------


def _blocks_of(ir: LayerIR, spec: CrossbarSpec, policy: str) -> list[tuple[int, int, int, int]]:
    """(row_block, col_block, rows_used, cols_used) in policy order."""
    grid = TileGrid(rows=2 * ir.m, cols=ir.n, spec=spec)
    R, C = spec.rows, spec.cols

    def geom(rb: int, cb: int) -> tuple[int, int, int, int]:
        return (
            rb, cb,
            min(R, 2 * ir.m - rb * R),
            min(C, ir.n - cb * C),
        )

    if policy == "column-major":
        return [geom(rb, cb) for cb in range(grid.col_tiles) for rb in range(grid.row_tiles)]
    # tacitmap order (also the enumeration greedy starts from): row-major
    return [geom(rb, cb) for rb in range(grid.row_tiles) for cb in range(grid.col_tiles)]


def _instance_irs(model: ModelIR) -> Iterable[tuple[str, LayerIR]]:
    for ir in model.layers:
        if not ir.binary:
            continue
        for i in range(ir.count):
            yield (f"{ir.name}[{i}]" if ir.count > 1 else ir.name), ir


def allocate(
    source,
    spec: CrossbarSpec = EPCM_TILE,
    policy: str = "tacitmap",
    tile_budget: int | None = None,
    spare_tiles: int = 0,
    avoid_tiles=(),
) -> MappingPlan:
    """Compile a model (ModelConfig / NetworkDesc / ModelIR) into a
    :class:`MappingPlan` under one placement policy.

    ``tile_budget`` caps the physical tile pool; ``None`` provisions one
    tile per block (the spatial-architecture ideal every policy then
    trivially satisfies with steps_per_vector == 1).

    Fault tolerance (PR 9): ``spare_tiles`` provisions that many extra
    physical tiles holding no data — the remap destinations
    :func:`remap_plan` draws from when tiles fail in the field.
    ``avoid_tiles`` names physical tile ids the allocator must skip
    entirely (a known fault map): data and spares are assigned to the
    lowest usable ids around the holes, so a plan compiled against a
    fault map never touches a bad tile.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown mapping policy {policy!r}; known: {', '.join(POLICIES)}")
    if tile_budget is not None and tile_budget < 1:
        raise ValueError(f"tile_budget must be >= 1, got {tile_budget}")
    if spare_tiles < 0:
        raise ValueError(f"spare_tiles must be >= 0, got {spare_tiles}")
    avoid = frozenset(int(t) for t in avoid_tiles)
    if any(t < 0 for t in avoid):
        raise ValueError(f"avoid_tiles must be >= 0: {sorted(avoid)}")
    model = to_ir(source)
    wavelengths = tuple(range(spec.wdm_k))

    # enumerate every (instance, block) in policy order
    pending: list[tuple[str, LayerIR, tuple[int, int, int, int]]] = []
    for inst_name, ir in _instance_irs(model):
        for blk in _blocks_of(ir, spec, policy):
            pending.append((inst_name, ir, blk))
    if not pending:
        raise ValueError(f"{model.name}: IR has no binary layers to place")

    n_tiles = len(pending) if tile_budget is None else min(tile_budget, len(pending))

    # the physical pool: lowest tile ids that are not avoided — first
    # ``n_tiles`` hold data, the next ``spare_tiles`` are the spares
    pool: list[int] = []
    t = 0
    while len(pool) < n_tiles + spare_tiles:
        if t not in avoid:
            pool.append(t)
        t += 1
    data_pool, spare_pool = pool[:n_tiles], pool[n_tiles:]

    # tile assignment
    assigned: list[tuple[str, LayerIR, tuple[int, int, int, int], int]] = []
    if policy == "greedy":
        # LPT: heaviest block first onto the least-loaded physical tile
        # (a (load, tile) heap keeps this O(B log T) — qwen-class plans
        # place ~10k blocks)
        heap = [(0, t) for t in data_pool]
        heapq.heapify(heap)
        order = sorted(
            range(len(pending)), key=lambda i: -(pending[i][2][2] * pending[i][2][3])
        )
        tiles_by_index: dict[int, int] = {}
        for i in order:
            load, t = heapq.heappop(heap)
            tiles_by_index[i] = t
            heapq.heappush(heap, (load + pending[i][2][2] * pending[i][2][3], t))
        for i, (inst, ir, blk) in enumerate(pending):
            assigned.append((inst, ir, blk, tiles_by_index[i]))
    else:
        # sequential striping in enumeration order (round-robin under a
        # budget — the deterministic layouts the paper figures draw)
        for i, (inst, ir, blk) in enumerate(pending):
            assigned.append((inst, ir, blk, data_pool[i % n_tiles]))

    # group back into per-instance LayerPlans, preserving block order
    by_instance: dict[str, list[BlockPlacement]] = {}
    ir_of: dict[str, LayerIR] = {}
    for inst, ir, (rb, cb, ru, cu), tile in assigned:
        by_instance.setdefault(inst, []).append(
            BlockPlacement(layer=inst, row_block=rb, col_block=cb, tile=tile,
                           rows_used=ru, cols_used=cu)
        )
        ir_of[inst] = ir

    layer_plans = tuple(
        LayerPlan(
            name=inst,
            ir=ir_of[inst],
            grid=TileGrid(rows=2 * ir_of[inst].m, cols=ir_of[inst].n, spec=spec),
            blocks=tuple(blocks),
            wavelengths=wavelengths,
        )
        for inst, blocks in by_instance.items()
    )
    return MappingPlan(
        model=model, spec=spec, policy=policy,
        tile_budget=tile_budget, layers=layer_plans,
        spares=tuple(spare_pool), avoid_tiles=tuple(sorted(avoid)),
    )


# ---------------------------------------------------------------------------
# Fault-aware remapping (PR 9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockMove:
    """One weight block relocated from a failed tile to a spare."""

    layer: str
    row_block: int
    col_block: int
    src: int
    dst: int
    cells: int


@dataclasses.dataclass(frozen=True)
class RemapDelta:
    """What a remap did and what it costs to reprogram.

    ``cost`` prices ONLY the moved blocks (tiles reprogram in parallel,
    rows within a destination tile serially — the same physics as
    ``costmodel.layer_programming_cost``), which is the whole point of
    incremental remapping: untouched tiles keep their cells.
    """

    moves: tuple[BlockMove, ...]
    cost: "object"  # costmodel.ProgrammingCost (lazy import below)


def remap_plan(
    plan: MappingPlan,
    failed_tiles,
    *,
    tile_ok=None,
) -> tuple[MappingPlan, RemapDelta]:
    """Re-place only the blocks resident on ``failed_tiles`` onto the
    plan's spare pool.

    ``tile_ok`` (optional predicate ``tile_id -> bool``) lets the caller
    BIST candidate spares before committing — the serving path passes
    ``FaultyEngine.tile_is_clean`` so a remap never lands on a spare
    that is itself faulty. Spares consumed (or found failed/unclean)
    leave the pool; failed tiles join ``avoid_tiles`` so a later
    recompile also skips them. Raises :class:`SpareTilesExhaustedError`
    when the usable spare pool can't cover the displaced blocks.
    """
    from repro.core import costmodel

    failed = frozenset(int(t) for t in failed_tiles)
    params = costmodel.params_for_spec(plan.spec)
    if not failed:
        return plan, RemapDelta(
            moves=(), cost=costmodel.ProgrammingCost(cells=0, energy_pj=0.0, time_ns=0.0)
        )

    candidates = [
        t for t in plan.spares
        if t not in failed and (tile_ok is None or tile_ok(t))
    ]
    displaced = sum(
        1 for lp in plan.layers for b in lp.blocks if b.tile in failed
    )
    if displaced > len(candidates):
        raise SpareTilesExhaustedError(
            f"{plan.model.name}: {displaced} block(s) displaced from failed "
            f"tiles {sorted(failed)} but only {len(candidates)} clean spare "
            f"tile(s) usable (of {len(plan.spares)} provisioned)"
        )

    moves: list[BlockMove] = []
    next_spare = iter(candidates)
    used: set[int] = set()
    rows_per_dst: dict[int, int] = {}
    new_layers = []
    for lp in plan.layers:
        blocks = []
        for b in lp.blocks:
            if b.tile in failed:
                dst = next(next_spare)
                used.add(dst)
                moves.append(BlockMove(
                    layer=lp.name, row_block=b.row_block, col_block=b.col_block,
                    src=b.tile, dst=dst, cells=b.cells,
                ))
                rows_per_dst[dst] = rows_per_dst.get(dst, 0) + b.rows_used
                b = dataclasses.replace(b, tile=dst)
            blocks.append(b)
        new_layers.append(dataclasses.replace(lp, blocks=tuple(blocks)))

    new_plan = dataclasses.replace(
        plan,
        layers=tuple(new_layers),
        spares=tuple(t for t in plan.spares if t not in used and t not in failed),
        avoid_tiles=tuple(sorted(set(plan.avoid_tiles) | failed)),
    )

    # price the reprogramming: destination tiles write in parallel, rows
    # within one destination serially (mirrors layer_programming_cost)
    cells = sum(mv.cells for mv in moves)
    time_ns = (max(rows_per_dst.values()) * params.t_row_write_ns) if rows_per_dst else 0.0
    cost = costmodel.ProgrammingCost(
        cells=cells,
        energy_pj=cells * params.e_cell_write_pj,
        time_ns=time_ns,
    )
    return new_plan, RemapDelta(moves=tuple(moves), cost=cost)


def balance_ratio(plan: MappingPlan) -> float:
    """max tile load / mean tile load (1.0 = perfectly balanced) over the
    provisioned pool — the quantity the greedy policy minimizes."""
    loads = plan.tile_loads()
    pool = [loads.get(t, 0) for t in range(plan.n_tiles)]
    mean = sum(pool) / len(pool)
    return max(pool) / mean if mean else 1.0


def required_tiles(source, spec: CrossbarSpec = EPCM_TILE) -> int:
    """Blocks (= dedicated tiles) a model needs with no budget — handy
    for sizing ``tile_budget`` sweeps."""
    model = to_ir(source)
    total = 0
    for ir in model.layers:
        if not ir.binary:
            continue
        g = TileGrid(rows=2 * ir.m, cols=ir.n, spec=spec)
        total += g.n_tiles * ir.count
    return total
