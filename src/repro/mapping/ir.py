"""Mapping IR — the layer view the placement planner compiles from.

The allocator does not care whether a binarized matmul came from a
paper BNN (``core/networks.py::NetworkDesc``) or from an LM's projection
stack (``models/config.py::ModelConfig``); it only needs, per layer, the
quantities a crossbar placement is made of: fan-in ``m`` (rows driven),
fan-out ``n`` (stored weight vectors), how many input vectors stream
through per inference (``positions``), how many identical instances the
model repeats (``count`` — LM layer stacks scan over repeats, so one IR
entry describes all of them), and whether the layer is binary at all
(hi-res edge layers stay off the binary tile fabric, §II-B).

:func:`from_model_config` extracts exactly the projections that
``models/layers.py::dense`` binarizes under ``quant="bnn"``: attention
q/k/v/o and the dense-FFN w1/w3/w2 of each pattern slot. Mixers without
binarized projections (mamba, MoE dispatch) contribute nothing — the
IR mirrors what the execution engines will actually be asked to run.
"""

from __future__ import annotations

import dataclasses

from repro.core.networks import LayerDesc, NetworkDesc
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class LayerIR:
    """One (class of) binarized matmul(s) the planner must place."""

    name: str
    m: int              # fan-in: logical input-vector length
    n: int              # fan-out: stored weight vectors (columns)
    count: int = 1      # identical instances (LM scan repeats)
    positions: int = 1  # input vectors per inference (im2col positions)
    binary: bool = True

    def __post_init__(self):
        if self.m < 1 or self.n < 1:
            raise ValueError(f"{self.name}: degenerate layer {self.m}x{self.n}")
        if self.count < 1:
            raise ValueError(f"{self.name}: count must be >= 1, got {self.count}")

    @property
    def macs(self) -> int:
        return self.m * self.n * self.positions * self.count

    def to_layer_desc(self) -> LayerDesc:
        """Bridge to the cost model's layer vocabulary (one instance)."""
        return LayerDesc(
            name=self.name, m=self.m, n=self.n,
            positions=self.positions, binary=self.binary,
        )


@dataclasses.dataclass(frozen=True)
class ModelIR:
    """The ordered layer list one MappingPlan is compiled from."""

    name: str
    source: str                     # "model_config" | "network_desc" | "adhoc"
    layers: tuple[LayerIR, ...]

    @property
    def binary_layers(self) -> tuple[LayerIR, ...]:
        return tuple(l for l in self.layers if l.binary)

    @property
    def macs(self) -> int:
        return sum(l.macs for l in self.layers)

    def layer(self, name: str) -> LayerIR:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(f"no layer {name!r} in IR {self.name}; "
                       f"have: {[l.name for l in self.layers]}")

    def to_network_desc(self) -> NetworkDesc:
        """Expand counts into the cost model's flat layer list."""
        flat = []
        for l in self.layers:
            for i in range(l.count):
                d = l.to_layer_desc()
                if l.count > 1:
                    d = dataclasses.replace(d, name=f"{l.name}[{i}]")
                flat.append(d)
        return NetworkDesc(name=self.name, dataset="-", layers=tuple(flat))


def from_network_desc(net: NetworkDesc) -> ModelIR:
    """Paper BNN workloads (MLP-S ... CNN-L) map one LayerDesc -> LayerIR."""
    return ModelIR(
        name=net.name,
        source="network_desc",
        layers=tuple(
            LayerIR(name=l.name, m=l.m, n=l.n, positions=l.positions, binary=l.binary)
            for l in net.layers
        ),
    )


def from_model_config(cfg: ModelConfig) -> ModelIR:
    """The LM's binarizable projections, one IR entry per pattern slot.

    Matches ``models/layers.py``: under ``quant="bnn"`` the attention
    q/k/v/o denses and the dense-FFN w1/w3/w2 run through the engine
    registry; each pattern slot repeats ``cfg.n_repeats`` times
    (``count``), so a 24-layer qwen1.5-0.5b compiles to 7 IR entries
    covering 168 physical weight matrices.
    """
    d, hd = cfg.d_model, cfg.hd
    layers: list[LayerIR] = []
    for i, kind in enumerate(cfg.pattern):
        slot = f"slot{i}"
        if kind.mixer == "attn":
            layers += [
                LayerIR(f"{slot}.attn.q", m=d, n=cfg.n_heads * hd, count=cfg.n_repeats),
                LayerIR(f"{slot}.attn.k", m=d, n=cfg.n_kv_heads * hd, count=cfg.n_repeats),
                LayerIR(f"{slot}.attn.v", m=d, n=cfg.n_kv_heads * hd, count=cfg.n_repeats),
                LayerIR(f"{slot}.attn.o", m=cfg.n_heads * hd, n=d, count=cfg.n_repeats),
            ]
        if not kind.moe and cfg.d_ff > 0:
            layers += [
                LayerIR(f"{slot}.ffn.w1", m=d, n=cfg.d_ff, count=cfg.n_repeats),
                LayerIR(f"{slot}.ffn.w3", m=d, n=cfg.d_ff, count=cfg.n_repeats),
                LayerIR(f"{slot}.ffn.w2", m=cfg.d_ff, n=d, count=cfg.n_repeats),
            ]
    if not layers:
        raise ValueError(
            f"{cfg.name}: no binarizable projections (pattern has neither "
            "attention nor dense FFN slots) — nothing to place"
        )
    return ModelIR(name=cfg.name, source="model_config", layers=tuple(layers))


def adhoc_layer(m: int, n: int, name: str | None = None) -> ModelIR:
    """A single-matmul IR — what the `tiled` engine compiles on the fly
    when it is handed a weight matrix with no plan covering it."""
    return ModelIR(
        name=name or f"adhoc_{m}x{n}",
        source="adhoc",
        layers=(LayerIR(name=name or f"mm_{m}x{n}", m=m, n=n),),
    )


def to_ir(source) -> ModelIR:
    """Accept a ModelIR, ModelConfig or NetworkDesc."""
    if isinstance(source, ModelIR):
        return source
    if isinstance(source, ModelConfig):
        return from_model_config(source)
    if isinstance(source, NetworkDesc):
        return from_network_desc(source)
    raise TypeError(f"cannot build a mapping IR from {type(source).__name__}")
