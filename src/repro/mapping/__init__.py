"""Mapping compiler: model -> explicit layer-to-tile placement plan.

The paper's core contribution is a *data mapping* — TacitMap decides how
binarized layers land on crossbar tiles and (with WDM) wavelengths.
This package makes that decision an explicit, static compilation
artifact instead of an implicit convention baked into each engine:

* :mod:`repro.mapping.ir`        — the layer IR (``LayerIR``/``ModelIR``)
  extracted from a ``ModelConfig`` (LM projection stacks, scan repeats
  expanded) or a paper ``NetworkDesc`` (MLP/CNN workloads).
* :mod:`repro.mapping.allocator` — the placement planner: complement-row
  TacitMap layout cut into ``CrossbarSpec`` tiles and assigned to a
  physical tile pool under a policy (``tacitmap`` | ``column-major`` |
  ``greedy`` load balancing), with WDM wavelength sets recorded per
  layer. Produces a :class:`~repro.mapping.allocator.MappingPlan`.
* :mod:`repro.mapping.schedule`  — orders per-tick tile activations into
  parallel phases and prices each layer via ``repro.core.costmodel``.
* :mod:`repro.mapping.report`    — human-readable plan/pricing reports.

Consumers: the ``tiled`` execution engine (``repro.core.engine``) slices
operands per the plan's block order; the serving engine's BatchPlanner
consults ``plan.preferred_group_size()``; ``launch/serve.py
--mapping-policy`` compiles a plan at startup; ``costmodel.price_plan``
prices one directly; ``benchmarks/run.py --sections mapping`` sweeps
policy x engine.

Worked example
--------------

Compile qwen1.5-0.5b onto oPCM tiles, schedule it, price it, and run the
binarized matmuls through the plan-driven ``tiled`` engine::

    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.crossbar import OPCM_TILE
    from repro.core.engine import get_engine
    from repro.mapping import allocate, report, schedule

    plan = allocate(get_config("qwen1.5-0.5b"), spec=OPCM_TILE,
                    policy="greedy", tile_budget=4096)
    sch = schedule.schedule(plan)          # tile phases + step counts
    # (or: from repro.mapping import schedule_plan; sch = schedule_plan(plan))
    cost = costmodel.price_plan(plan)      # latency/energy per inference
    print(report.summarize(plan))          # tiles/util/K/balance one-liner
    print(report.format_priced(cost))

    eng = get_engine("tiled", plan=plan)   # executes per the placement
    out = eng.binary_vmm(a_signs, w_signs) # bit-exact vs "reference"

    # the one-call replacement (compiles the plan, binds the engine,
    # programs the weights, consults the plan's WDM capacity for K):
    cm = repro.compiler.compile(cfg, params,
                                HardwareTarget(engine="tiled",
                                               mapping_policy="greedy",
                                               tile_budget=4096))
    se = cm.serve(max_batch=8, max_len=256)
"""

from repro.mapping.allocator import (  # noqa: F401
    POLICIES,
    BlockMove,
    BlockPlacement,
    LayerPlan,
    MappingPlan,
    RemapDelta,
    SpareTilesExhaustedError,
    allocate,
    balance_ratio,
    remap_plan,
    required_tiles,
)
from repro.mapping.ir import (  # noqa: F401
    LayerIR,
    ModelIR,
    adhoc_layer,
    from_model_config,
    from_network_desc,
    to_ir,
)
from repro.mapping import report  # noqa: F401
from repro.mapping import schedule as _schedule_mod
from repro.mapping.schedule import LayerSchedule, Schedule  # noqa: F401

# compile_plan is the one-call public entry point consumers use;
# schedule_plan orders+prices a compiled plan (the submodule stays
# reachable as repro.mapping.schedule — the function is NOT re-exported
# under the same name to avoid shadowing it)
compile_plan = allocate
schedule_plan = _schedule_mod.schedule
