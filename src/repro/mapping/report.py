"""Human-readable mapping-plan reports.

``format_plan`` renders the compilation artifact the way a hardware
mapping document would: the provisioned tile pool, per-layer placement
geometry (grid, blocks, tiles, serialization), and — when priced — the
schedule's step/latency/energy columns. ``launch/serve.py
--mapping-policy`` prints the summary; tests assert the full report
names every placed layer.
"""

from __future__ import annotations

from repro.core import costmodel
from repro.mapping import schedule as schedule_lib
from repro.mapping.allocator import MappingPlan, balance_ratio


def summarize(plan: MappingPlan) -> str:
    """One line: what this plan provisions and how it groups."""
    spec = plan.spec
    budget = "dedicated" if plan.tile_budget is None else f"budget={plan.tile_budget}"
    return (
        f"[mapping] {plan.model.name}: policy={plan.policy} "
        f"tiles={plan.n_tiles} ({spec.technology} {spec.rows}x{spec.cols}, {budget}) "
        f"blocks={plan.n_blocks} util={plan.utilization():.2f} "
        f"K={plan.preferred_group_size()} balance={balance_ratio(plan):.2f}"
    )


def format_plan(
    plan: MappingPlan,
    sch: schedule_lib.Schedule | None = None,
    max_rows: int = 40,
) -> str:
    """Multi-line placement report; pass a schedule to add cost columns.

    Layer instances beyond ``max_rows`` are elided with a summary line
    (LM plans expand scan repeats into hundreds of instances).
    """
    lines = [summarize(plan)]
    priced = {ls.layer: ls for ls in sch.layers} if sch is not None else {}
    header = (
        f"{'layer':<24s} {'mxn':>12s} {'grid':>7s} {'blocks':>6s} "
        f"{'tiles':>6s} {'s/vec':>5s}"
    )
    if priced:
        header += f" {'steps':>7s} {'lat_us':>8s} {'en_nJ':>8s}"
    lines.append(header)
    for lp in plan.layers[:max_rows]:
        row = (
            f"{lp.name:<24s} {f'{lp.ir.m}x{lp.ir.n}':>12s} "
            f"{f'{lp.grid.row_tiles}x{lp.grid.col_tiles}':>7s} "
            f"{lp.n_blocks:6d} {len(lp.tiles):6d} {lp.steps_per_vector:5d}"
        )
        ls = priced.get(lp.name)
        if ls is not None:
            row += f" {ls.steps:7d} {ls.latency_ns * 1e-3:8.2f} {ls.energy_pj * 1e-3:8.2f}"
        lines.append(row)
    hidden = len(plan.layers) - max_rows
    if hidden > 0:
        lines.append(f"... {hidden} more layer instances (same pattern slots, scan repeats)")
    if sch is not None:
        lines.append(
            f"total: {sch.total_steps} steps, "
            f"{sch.total_latency_ns * 1e-6:.3f} ms/batch, "
            f"{sch.total_energy_pj * 1e-6:.3f} uJ/batch "
            f"(design={sch.params.name}, batch={sch.params.batch})"
        )
    return "\n".join(lines)


def format_priced(cost: costmodel.PlanCost) -> str:
    """Render a costmodel.price_plan result (IR-entry aggregates)."""
    lines = [
        f"[mapping] {cost.model} priced on {cost.design} "
        f"(policy={cost.policy}, batch={cost.batch}): "
        f"{cost.latency_s * 1e6:.2f} us/inf, {cost.energy_j * 1e6:.3f} uJ/inf, "
        f"{cost.n_tiles} tiles @ util {cost.utilization:.2f}",
        f"{'layer':<20s} {'mxn':>12s} {'inst':>5s} {'blocks':>6s} "
        f"{'s/vec':>5s} {'steps':>8s} {'lat_us':>8s} {'en_uJ':>8s}",
    ]
    for r in cost.layers:
        mxn = "{m}x{n}".format(m=r["m"], n=r["n"])
        lines.append(
            f"{r['layer']:<20s} {mxn:>12s} "
            f"{r['instances']:5d} {r['blocks']:6d} {r['steps_per_vector']:5d} "
            f"{r['steps']:8d} {r['latency_ns'] * 1e-3:8.2f} "
            f"{r['energy_pj'] * 1e-6:8.3f}"
        )
    return "\n".join(lines)
