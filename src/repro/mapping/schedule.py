"""Tick scheduler: a placed plan -> ordered tile activations + cost.

A :class:`~repro.mapping.allocator.MappingPlan` says *where* every weight
block lives; this module says *when* each tile fires and what that
costs:

* **Phases** — per input vector (or WDM K-group), a layer's tiles fire
  in parallel waves: all tiles holding exactly one of the layer's blocks
  fire in phase 0; a tile co-hosting j blocks of the same layer (tile
  budget over-subscription) fires again in phases 1..j-1. A layer's
  serialized step count per vector is therefore
  ``LayerPlan.steps_per_vector == len(phases)``.
* **Steps** — the stream of ``batch x positions`` input vectors is
  WDM-grouped by the design's K (``Engine.steps_for`` through the
  registry, the same seam the cost model uses), then multiplied by the
  phase serialization.
* **Latency / energy** — per-layer estimates via ``repro.core.costmodel``
  (``layer_energy_pj`` dispatches the registered binary-energy counter;
  latency charges the tile's VMM step time per sequential step), so a
  plan's numbers and the paper-figure numbers come from one place.
"""

from __future__ import annotations

import dataclasses

from repro.core import costmodel
from repro.mapping.allocator import LayerPlan, MappingPlan


@dataclasses.dataclass(frozen=True)
class LayerSchedule:
    """Activation order + cost for ONE placed layer instance."""

    layer: str                          # instance name
    n_blocks: int
    phases: tuple[tuple[int, ...], ...] # tiles firing per serialized pass
    groups: int                         # WDM K-group activations per stream
    steps: int                          # total sequential steps (groups x phases)
    latency_ns: float                   # for params.batch inferences
    energy_pj: float

    @property
    def steps_per_vector(self) -> int:
        return len(self.phases)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Per-tick activation schedule + per-layer costs for a whole plan."""

    plan: MappingPlan
    params: costmodel.CIMParams
    layers: tuple[LayerSchedule, ...]

    @property
    def total_steps(self) -> int:
        return sum(l.steps for l in self.layers)

    @property
    def total_latency_ns(self) -> float:
        """Batch latency: the spatial pipeline streams one batch through
        all layers, so layer times add (costmodel convention)."""
        return sum(l.latency_ns for l in self.layers)

    @property
    def total_energy_pj(self) -> float:
        return sum(l.energy_pj for l in self.layers)

    def layer(self, name: str) -> LayerSchedule:
        for l in self.layers:
            if l.layer == name:
                return l
        raise KeyError(f"no layer instance {name!r} in schedule")


def phases_of(lp: LayerPlan) -> tuple[tuple[int, ...], ...]:
    """Order one layer's tile activations into parallel waves.

    Tiles holding a single block of this layer all fire together; a tile
    with j co-resident blocks (placement order preserved) contributes to
    the first j waves.
    """
    passes: dict[int, int] = {}     # tile -> blocks seen so far
    waves: list[list[int]] = []
    for b in lp.blocks:
        p = passes.get(b.tile, 0)
        passes[b.tile] = p + 1
        if p == len(waves):
            waves.append([])
        waves[p].append(b.tile)
    return tuple(tuple(sorted(w)) for w in waves)


def schedule(
    plan: MappingPlan,
    params: costmodel.CIMParams | None = None,
    batch: int | None = None,
) -> Schedule:
    """Order every layer's tile activations and price them.

    ``params`` defaults to the CIM design matching the plan's tile spec
    (ePCM -> TacitMap-ePCM, oPCM+WDM -> EinsteinBarrier); ``batch``
    overrides the design's streaming batch.
    """
    params = params or costmodel.params_for_spec(plan.spec)
    if params.tile is not plan.spec:
        params = dataclasses.replace(params, tile=plan.spec)
    if batch is not None:
        params = dataclasses.replace(params, batch=batch)

    eng = params.engine()
    rows = []
    for lp in plan.layers:
        ir = lp.ir
        desc = ir.to_layer_desc()
        phases = phases_of(lp)
        # the costmodel's stream convention: conv layers replicate
        # weights across spare tiles (position parallelism), so plan
        # numbers and the paper-figure numbers stay comparable
        stream = costmodel.position_stream(params, desc)
        groups = eng.steps_for(ir.m, ir.n, stream)
        steps = groups * len(phases)
        # latency: every sequential step is one tile-array VMM pass
        latency_ns = steps * plan.spec.t_vmm_ns
        # energy through the cost model's registered per-backend counter
        # (serialization reorders activations, it does not add any)
        energy_pj = costmodel.layer_energy_pj(params, desc)
        rows.append(
            LayerSchedule(
                layer=lp.name, n_blocks=lp.n_blocks, phases=phases,
                groups=groups, steps=steps,
                latency_ns=latency_ns, energy_pj=energy_pj,
            )
        )
    return Schedule(plan=plan, params=params, layers=tuple(rows))
