"""Multi-level PCM study — the paper's §VI-C future-work item, built on
the crossbar device models.

The paper uses PCM cells in BINARY mode, citing Cardoso et al. [16]:
at realistic photonic noise levels, multi-level cells corrupt the MAC.
This module quantifies that trade-off with the same machinery the
mappings use, closing the loop the paper leaves open:

* ``quantize_weights(w, bits)`` — a multi-level cell stores ``bits``
  bits of a fixed-point weight; TacitMap's complement trick generalizes
  (store w and (2^bits-1)-w below it) so the same crossbar computes the
  multi-level MAC in one VMM.
* ``noisy_vmm(...)`` — the analog MAC with the oPCM readout-noise model
  (relative Gaussian on the photocurrent, sigma per §II-C's "high
  frequencies = high noise"), followed by ADC quantization.
* ``level_error_rate(...)`` — Monte-Carlo probability that noise flips
  the recovered dot product by at least one output LSB, per cell depth.

The headline result (benchmarks/multilevel.py): at the noise level
where the 1-bit (binary) mapping is still exact, 2-bit cells already
misread a measurable fraction of MACs and 4-bit cells are unusable —
the quantitative version of the paper's §II-C argument for why
EinsteinBarrier stays binary.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.crossbar import CrossbarSpec, OPCM_TILE

Array = jax.Array


def quantize_weights(w: Array, bits: int) -> Array:
    """Real-valued w in [-1, 1] -> integer conductance levels 0..2^b-1."""
    levels = 2**bits - 1
    return jnp.round((jnp.clip(w, -1.0, 1.0) + 1.0) * 0.5 * levels).astype(jnp.int32)


def dequantize(q: Array, bits: int) -> Array:
    levels = 2**bits - 1
    return q.astype(jnp.float32) / levels * 2.0 - 1.0


def multilevel_vmm_exact(a_levels: Array, w_levels: Array) -> Array:
    """Noise-free analog MAC on integer levels (the crossbar ideal)."""
    return jnp.matmul(a_levels.astype(jnp.float32), w_levels.astype(jnp.float32))


def noisy_vmm(
    a_levels: Array,
    w_levels: Array,
    bits: int,
    sigma: float,
    key: jax.Array,
    spec: CrossbarSpec = OPCM_TILE,
) -> Array:
    """Analog MAC with multiplicative photocurrent noise + ADC.

    sigma is the RELATIVE noise on each cell's contribution (per [16]:
    noise grows with modulation frequency). The ADC quantizes the summed
    current to ``spec.adc_bits`` over the full-scale range
    rows * levels^2 (input levels x weight levels).
    """
    levels = 2**bits - 1
    af = a_levels.astype(jnp.float32)
    wf = w_levels.astype(jnp.float32)
    contrib = af[..., :, None] * wf[None, ...]  # (batch, m, n) cell currents
    noise = 1.0 + sigma * jax.random.normal(key, contrib.shape)
    summed = jnp.sum(contrib * noise, axis=-2)
    # the ADC cannot resolve finer than one level-product unit (outputs
    # are integers in level units); its range covers full scale
    full_scale = a_levels.shape[-1] * levels * levels
    lsb = max(max(full_scale, 1) / spec.adc_levels, 1.0)
    return jnp.round(summed / lsb) * lsb


def level_error_rate(
    bits: int,
    sigma: float,
    *,
    m: int = 64,
    n: int = 32,
    batch: int = 64,
    seed: int = 0,
    spec: CrossbarSpec = OPCM_TILE,
) -> float:
    """Fraction of MAC outputs whose ADC reading differs from exact."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    levels = 2**bits - 1
    a = jax.random.randint(k1, (batch, m), 0, levels + 1)
    w = jax.random.randint(k2, (m, n), 0, levels + 1)
    exact = multilevel_vmm_exact(a, w)
    noisy = noisy_vmm(a, w, bits, sigma, k3, spec)
    # error = recovered reading off the TRUE integer MAC by >= 1 output
    # unit: captures BOTH analog noise and the ADC-resolution loss that
    # deeper cells force (full scale grows as levels^2 while the ADC
    # stays 9-bit — the paper's argument for binary cells, quantified)
    return float(jnp.mean(jnp.abs(noisy - exact) > 0.5))


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    bits: int
    sigma: float
    error_rate: float
    density_x: float      # storage density vs binary
    latency_x: float      # steps saved vs bit-serial binary (= bits)


def sweep(bit_depths=(1, 2, 4), sigmas=(0.0, 0.01, 0.02, 0.05, 0.1), **kw):
    out = []
    for bits in bit_depths:
        for sigma in sigmas:
            out.append(
                SweepPoint(
                    bits=bits,
                    sigma=sigma,
                    error_rate=level_error_rate(bits, sigma, **kw),
                    density_x=float(bits),
                    latency_x=float(bits),
                )
            )
    return out
