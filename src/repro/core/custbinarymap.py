"""CustBinaryMap: the SotA baseline mapping (Hirtzlin et al. [15]).

2T2R rows: each weight vector is stored *horizontally* in a memory row,
bit-interleaved with its complement (x, x̄ in the two devices of each
2T2R cell). A precharge sense amplifier (PCSA) per bitline column reads
the XNOR of the driven input against ONE stored weight vector per step;
popcount then happens in digital peripherals (a 5-bit counter per
column + a tree across arrays).

Functionally the result equals ``popcount(XNOR(a, w_j))`` — the mapping
is lossless, like TacitMap. The difference is *throughput*: one weight
vector per step ("at most one single vector operation at a time", §I),
so a layer with n output vectors costs n steps (vs TacitMap's 1).

This simulator reproduces the step structure (a Python-level scan over
weight rows would be slow and adds nothing — the per-step output is the
XNOR row, so we compute all steps' outputs vectorized and report the
step count separately, exactly what the cost model needs).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import bnn
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, TileGrid

Array = jax.Array


@dataclasses.dataclass
class MappedLayerCBM:
    """Weight vectors stored row-wise, bit-interleaved with complements.

    ``rows`` has shape (n, 2m): row j = interleave(w_j, w̄_j). The
    interleaving matches Fig. 2-(a): device pair (x, x̄) per 2T2R cell.
    """

    rows: Array
    m: int
    n: int
    spec: CrossbarSpec
    grid: TileGrid


def interleave_complement(w_row_bits: Array) -> Array:
    """(..., m) -> (..., 2m) with [w0, w̄0, w1, w̄1, ...] interleaving."""
    stacked = jnp.stack([w_row_bits, 1 - w_row_bits], axis=-1)
    return stacked.reshape(*w_row_bits.shape[:-1], 2 * w_row_bits.shape[-1])


def map_weights(w_bits: Array, spec: CrossbarSpec = EPCM_TILE) -> MappedLayerCBM:
    """Map a {0,1} weight matrix (m, n) row-wise (one vector per row)."""
    m, n = w_bits.shape
    rows = interleave_complement(w_bits.T)  # (n, 2m)
    # fairness bookkeeping: same device count as TacitMap — n rows of 2m
    # cells. Rows per array = spec.rows; a vector spans ceil(2m/cols)
    # arrays horizontally.
    grid = TileGrid(rows=n, cols=2 * m, spec=spec)
    return MappedLayerCBM(rows=rows, m=m, n=n, spec=spec, grid=grid)


def apply(layer: MappedLayerCBM, a_bits: Array) -> Array:
    """PCSA readout: XNOR of input with every stored row, then popcount.

    ``a_bits``: (..., m). Returns (..., n) popcounts. Each of the n rows
    costs one sequential step in hardware (`steps_for`); the digital
    popcount (counter + tree) is pipelined behind the reads.
    """
    if a_bits.shape[-1] != layer.m:
        raise ValueError(f"input length {a_bits.shape[-1]} != mapped m={layer.m}")
    drive = interleave_complement(a_bits)  # (..., 2m)
    # PCSA differential sensing of the 2T2R pair == XNOR bit:
    # sense(a,ā vs w,w̄) = 1 iff a == w. With the interleaved encoding
    # this is exactly a "match" of consecutive device pairs:
    a_pairs = drive.reshape(*drive.shape[:-1], layer.m, 2)
    w_pairs = layer.rows.reshape(layer.n, layer.m, 2)
    # match when the pair patterns are equal: sum of elementwise AND == 1
    xnor_bits = jnp.einsum(
        "...mp,nmp->...nm", a_pairs.astype(jnp.float32), w_pairs.astype(jnp.float32)
    )
    # digital popcount: 5-bit counters per column + adder tree
    return xnor_bits.sum(axis=-1)


def binary_matmul(a_signs: Array, w_signs: Array, spec: CrossbarSpec = EPCM_TILE) -> Array:
    """±1 binary matmul through the CustBinaryMap path (for equivalence tests)."""
    m = a_signs.shape[-1]
    mapped = map_weights(bnn.signs_to_bits(w_signs).astype(jnp.int32), spec)
    pc = apply(mapped, bnn.signs_to_bits(a_signs))
    return 2 * pc - m


def steps_for(m: int, n: int, n_inputs: int, spec: CrossbarSpec = EPCM_TILE) -> int:
    """Sequential steps: one vector operation at a time (§I critique (b)).

    Per input vector, all n stored weight vectors are read out one row
    per step. The digital popcount is pipelined (counters run during the
    next row read), so it does not add steps, only a small drain latency
    that we fold into the per-step time.
    """
    del m, spec
    return n_inputs * n
