"""Analytical latency/energy model for the four evaluated designs.

Designs (§V-B of the paper):

* ``Baseline-ePCM``  — CustBinaryMap on ePCM (Hirtzlin et al. [15]):
  one weight-vector operation at a time (§I critique (b)), PCSA readout,
  digital popcount pipelined behind row reads.
* ``TacitMap-ePCM``  — TacitMap on the same ePCM tiles: one VMM step per
  input vector, all tiles/columns parallel, ADC readout.
* ``EinsteinBarrier``— TacitMap on oPCM tiles + WDM (K wavelengths per
  step => MMM), faster photonic step, transmitter/TIA overheads
  (Eq. 2/3) shared at the ECore level.
* ``Baseline-GPU``   — roofline GPU model with per-kernel launch
  overhead (the paper's observation 4: GPUs win on serialization-heavy
  MLPs, can lose on small CNNs).

Step-count structure is *derived from the mappings*: each CIM design
names an execution backend in the ``repro.core.engine`` registry
(``engine_name``) and binary-layer step counts come from that engine's
``steps_for`` — one interface instead of per-mapping special cases.
Binary-layer energy dispatches the same way through
:func:`register_binary_energy`, so a new backend plugs its counters in
without touching this module's evaluation loop.
Device constants are calibrated against the paper's reported bands
because the underlying MNEMOSENE device characterizations are not
public. Every constant lives in one dataclass below; the calibration is
asserted (with tolerance bands) in ``benchmarks/paper_latency.py``.

Common policies (applied identically across CIM designs for fairness):

* Edge (first/last, high-precision) layers run bit-serial over
  ``edge_bits`` input bits. On the VMM designs (TacitMap/EinsteinBarrier)
  all output columns convert in parallel; on Baseline-ePCM — whose PCSA
  arrays have no ADC/VMM path — the edge layers run on a near-memory
  digital unit that produces ``edge_parallel`` outputs per cycle. This
  is what dilutes TacitMap's gains on edge-heavy networks (paper §VI-A
  observation 2).
* Conv layers may replicate weights across spare crossbars
  (ISAAC/PUMA-style) to process up to ``conv_replication`` im2col
  positions in parallel; FC layers do not replicate (area).
* The accelerator streams inference requests in batches of ``batch``
  (16): WDM multiplexes *independent* input vectors — im2col positions
  within an image for convs, images within the stream for MLPs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core import engine as engine_lib
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE
from repro.core.networks import LayerDesc, NetworkDesc

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CIMParams:
    """One CIM design point. Times in ns, energies in pJ, power in mW."""

    name: str
    tile: CrossbarSpec
    mapping: str                      # "tacitmap" | "custbinarymap"
    batch: int = 16
    edge_bits: int = 8                # first/last layer input precision
    conv_replication: int = 64        # max position-parallel weight copies
    edge_conv_replication: int = 256  # first conv layer is tiny: replicate 4x more
    edge_parallel: int = 64           # baseline digital unit: outputs/cycle
    # CustBinaryMap step: one 2T2R row read (PCSA) + popcount-counter
    # drain, at array-cycle speed (100 ns) + 20 ns pipelined tree drain.
    t_row_step_ns: float = 120.0
    # energy constants (pJ) — calibrated, see module docstring
    e_pcsa_pj: float = 0.001          # one PCSA differential sense (1 fJ)
    e_adc_pj: float = 2.0             # one ADC conversion (ISAAC-class, 9-bit)
    e_dig_mac_pj: float = 0.001       # near-memory digital MAC (edge layers)
    # one-time crossbar programming (PCM write) — kept SEPARATE from the
    # per-step readout constants above: CIM programs weights once and
    # amortizes the write over every subsequent inference / decode tick
    # (the stationary-weight premise the prepared-weights path encodes).
    # SET/RESET pulse energies for PCM are orders of magnitude above a
    # read (~10 pJ vs ~fJ, Burr et al. survey); writes are word-line
    # serial with all columns of a tile programmed in parallel.
    e_cell_write_pj: float = 10.0     # one PCM SET/RESET pulse per cell
    t_row_write_ns: float = 100.0     # one word-line programming pulse
    # photonics (EinsteinBarrier only)
    use_wdm: bool = False
    p_laser_mw: float = 200.0         # pump laser
    voa_mw_per_line: float = 3.0      # Eq. 3: 3 mW per VOA line
    tuning_mw: float = 45.0           # Eq. 3: 45 mW per tuning group
    vcores_per_ecore: int = 32        # transmitter shared across VCores (§IV-A3)

    @property
    def k(self) -> int:
        return self.tile.wdm_k if self.use_wdm else 1

    @property
    def engine_name(self) -> str:
        """The registered execution backend this design's binary layers
        step like (WDM turns the TacitMap VMM into a K-way MMM)."""
        return "wdm" if self.use_wdm else self.mapping

    def engine(self) -> engine_lib.Engine:
        return engine_lib.get_engine(self.engine_name, spec=self.tile)


@dataclasses.dataclass(frozen=True)
class GPUParams:
    """Roofline GPU with per-kernel launch overhead.

    ``batch=1`` is the latency metric (what Fig. 7 compares); the
    benchmark also reports a batch-16 throughput variant — the paper's
    GPU setup is not fully specified, and its MLP-L observation (~27x
    faster than Baseline-ePCM) lies between our two endpoints (see
    EXPERIMENTS.md).
    """

    name: str = "Baseline-GPU"
    batch: int = 1
    peak_binary_ops: float = 10e12    # fused XNOR+popcount throughput
    peak_fp: float = 20e12            # fp16 FLOP/s
    conv_efficiency: float = 0.10     # tiny-image conv utilization
    mem_bw: float = 300e9             # B/s
    launch_overhead_us: float = 8.0   # per-kernel launch+sync
    power_w: float = 150.0

    def kernels_for(self, layer: LayerDesc) -> int:
        # conv: im2col + GEMM + binarize + pool; fc: GEMM + binarize
        return 4 if layer.positions > 1 else 2


BASELINE_EPCM = CIMParams(name="Baseline-ePCM", tile=EPCM_TILE, mapping="custbinarymap")
TACITMAP_EPCM = CIMParams(name="TacitMap-ePCM", tile=EPCM_TILE, mapping="tacitmap")
EINSTEINBARRIER = CIMParams(
    name="EinsteinBarrier", tile=OPCM_TILE, mapping="tacitmap", use_wdm=True
)
BASELINE_GPU = GPUParams()


# ---------------------------------------------------------------------------
# Step counting (per batch of `params.batch` inferences)
# ---------------------------------------------------------------------------


def position_stream(params: CIMParams, layer: LayerDesc) -> int:
    """Sequential input-vector slots for one batch, after replication.

    Public: the mapping scheduler (repro/mapping/schedule.py) charges
    plans through this same convention so plan numbers and the
    paper-figure numbers agree (conv layers replicate weights across
    spare tiles; FC layers do not)."""
    if layer.positions > 1:  # conv: replicate weights across spare tiles
        repl = params.conv_replication if layer.binary else params.edge_conv_replication
        par = min(repl, layer.positions)
        per_image = math.ceil(layer.positions / par)
    else:
        per_image = 1
    return params.batch * per_image


def layer_steps(params: CIMParams, layer: LayerDesc) -> int:
    """Sequential steps for one *batch* through this layer.

    Binary layers delegate to the design's execution backend
    (``Engine.steps_for`` — WDM grouping, row-serial baselines, etc. all
    live behind that one interface); edge (hi-res) layers run the shared
    bit-serial policy below.
    """
    stream = position_stream(params, layer)
    if layer.binary:
        return params.engine().steps_for(layer.m, layer.n, stream)
    if params.use_wdm:  # WDM groups the stream K vectors per step
        stream = math.ceil(stream / params.k)
    if params.mapping == "custbinarymap":
        # digital near-memory unit: edge_parallel outputs per cycle
        return stream * params.edge_bits * math.ceil(layer.n / params.edge_parallel)
    return stream * params.edge_bits              # bit-serial hi-res VMM


def layer_latency_ns(params: CIMParams, layer: LayerDesc) -> float:
    steps = layer_steps(params, layer)
    if params.mapping == "custbinarymap":
        t = params.t_row_step_ns if layer.binary else params.tile.t_vmm_ns
        return steps * t
    return steps * params.tile.t_vmm_ns


def network_latency_s(params: CIMParams, net: NetworkDesc) -> float:
    """Per-image latency (batch latency / batch): the spatial pipeline
    streams one batch through all layers; layer times add."""
    total_ns = sum(layer_latency_ns(params, l) for l in net.layers)
    return total_ns * 1e-9 / params.batch


# ---------------------------------------------------------------------------
# Energy (per image)
# ---------------------------------------------------------------------------


def _row_tiles(params: CIMParams, layer: LayerDesc) -> int:
    rows = 2 * layer.m if layer.binary else layer.m
    return max(1, math.ceil(rows / params.tile.rows))


def transmitter_power_mw(params: CIMParams) -> float:
    """Eq. 3: P = P_laser + 3·K·M mW + (3·K·M + 1)/K · 45 mW.

    M is the crossbar row count (VOA lines per wavelength); the paper's
    lowercase ``k`` in the denominator is read as the WDM capacity K
    (dimensional analysis — see DESIGN.md §8).
    """
    k, m = params.k, params.tile.rows
    return (
        params.p_laser_mw
        + params.voa_mw_per_line * k * m
        + (3 * k * m + 1) / k * params.tuning_mw
    )


def tia_power_mw(params: CIMParams, n_cols: int) -> float:
    """Eq. 2: P = N × 2 mW (one TIA per active output column)."""
    return n_cols * params.tile.p_tia_mw


# Binary-layer energy, dispatched by the design's execution backend —
# the same seam as ``Engine.steps_for``: a new backend registers its
# counter here instead of growing special cases in layer_energy_pj.
_BINARY_ENERGY: dict[str, Callable[[CIMParams, LayerDesc], float]] = {}


def register_binary_energy(
    name: str,
) -> Callable[[Callable[[CIMParams, LayerDesc], float]], Callable[[CIMParams, LayerDesc], float]]:
    def deco(fn: Callable[[CIMParams, LayerDesc], float]):
        _BINARY_ENERGY[name] = fn
        return fn

    return deco


@register_binary_energy("custbinarymap")
def _cbm_binary_energy(params: CIMParams, layer: LayerDesc) -> float:
    # n row-reads per input vector; m 2T2R pairs sensed per read
    stream = params.batch * layer.positions
    reads = stream * layer.n
    cell = reads * layer.m * 2 * params.tile.e_cell_read_fj * 1e-3
    sense = reads * layer.m * params.e_pcsa_pj
    return cell + sense


@register_binary_energy("tacitmap")
@register_binary_energy("wdm")
def _vmm_binary_energy(params: CIMParams, layer: LayerDesc) -> float:
    # VMM path (TacitMap / EinsteinBarrier binary layers)
    tile = params.tile
    stream = params.batch * layer.positions
    cols = layer.n
    activations = params.engine().steps_for(layer.m, layer.n, stream)
    rows_active = 2 * layer.m
    cell = activations * rows_active * cols * tile.e_cell_read_fj * 1e-3
    # readout chain energy scales with crossbar *activations* (the paper:
    # WDM "uses the same crossbar, ADCs and other peripheries" per step)
    conv = activations * cols * _row_tiles(params, layer) * params.e_adc_pj
    dyn = cell + conv
    if params.use_wdm:
        t_ns = activations * tile.t_vmm_ns
        static_mw = (
            transmitter_power_mw(params) / params.vcores_per_ecore
            + tia_power_mw(params, min(cols, tile.cols))
        )
        dyn += static_mw * 1e-3 * t_ns  # mW·ns = pJ
    return dyn


def layer_energy_pj(params: CIMParams, layer: LayerDesc) -> float:
    """Energy for one *batch* through this layer (pJ)."""
    if layer.binary:
        return _BINARY_ENERGY[params.engine_name](params, layer)
    # Edge (hi-res) layers: shared high-precision path — identical
    # energy for every CIM design. The paper's energy story (Fig. 8)
    # is about binary layers' ADC-vs-SA readout; edge layers dilute
    # both sides equally.
    stream = params.batch * layer.positions  # real vector slots (no repl. savings)
    return stream * layer.m * layer.n * params.e_dig_mac_pj


def network_energy_j(params: CIMParams, net: NetworkDesc) -> float:
    total_pj = sum(layer_energy_pj(params, l) for l in net.layers)
    return total_pj * 1e-12 / params.batch


# ---------------------------------------------------------------------------
# One-time weight programming (PCM write) — the prepared-weights phase
# ---------------------------------------------------------------------------
#
# The execution engines' two-phase contract (Engine.prepare, PR 4)
# mirrors the hardware's: weights are written into the crossbar once,
# then every inference only reads. These helpers price that one-time
# write separately from the per-step readout energies above, so serving
# reports can show when the stationary-weight premise has paid for its
# programming cost (the break-even tick count).


@dataclasses.dataclass(frozen=True)
class ProgrammingCost:
    """One-time crossbar-programming cost (PCM writes), per weight copy."""

    cells: int            # devices written (complement pairs for binary)
    energy_pj: float
    time_ns: float        # word-line-serial write schedule

    def __add__(self, other: "ProgrammingCost") -> "ProgrammingCost":
        return ProgrammingCost(
            cells=self.cells + other.cells,
            energy_pj=self.energy_pj + other.energy_pj,
            time_ns=self.time_ns + other.time_ns,
        )


def layer_programming_cost(params: CIMParams, layer: LayerDesc) -> ProgrammingCost:
    """Price programming one layer's weights into the design's tiles.

    Binary layers store the complement pair (2m x n cells, TacitMap's
    Fig. 2-(b) layout); writes are word-line serial per tile with all
    columns pulsed in parallel, and row tiles program concurrently
    (independent word-line drivers per tile).
    """
    rows = 2 * layer.m if layer.binary else layer.m
    cells = rows * layer.n
    # rows within a tile serialize; the col-tile count multiplies the
    # cells but not the time (each tile has its own drivers)
    rows_per_tile = min(rows, params.tile.rows)
    time_ns = rows_per_tile * params.t_row_write_ns
    return ProgrammingCost(
        cells=cells,
        energy_pj=cells * params.e_cell_write_pj,
        time_ns=time_ns,
    )


def network_programming_cost(params: CIMParams, net: NetworkDesc) -> ProgrammingCost:
    """One-time programming cost of a whole network (no replication)."""
    total = ProgrammingCost(cells=0, energy_pj=0.0, time_ns=0.0)
    for layer in net.layers:
        total = total + layer_programming_cost(params, layer)
    return total


def programming_break_even_ticks(
    params: CIMParams, layer: LayerDesc, n_active: int
) -> float:
    """Decode ticks whose readout energy equals the one-time write.

    After this many K-grouped serving ticks the stationary-weight
    premise has paid for itself — the number the prepared-weights
    serving path amortizes against.
    """
    prog = layer_programming_cost(params, layer)
    tick = grouped_decode_tick(params, layer, n_active)
    return prog.energy_pj / max(tick.energy_pj, 1e-12)


# ---------------------------------------------------------------------------
# Grouped serving-decode accounting (WDM K-group batching)
# ---------------------------------------------------------------------------
#
# The serving engine (repro/serving/engine.py) groups each decode
# tick's active slots into K-groups and issues one ``binary_mmm`` per
# projection. These helpers report what that tick costs in hardware
# terms, through the same ``Engine.steps_for`` / binary-energy seams as
# the per-network numbers above — so EinsteinBarrier's K-way latency
# division shows up directly in serving-tick numbers (groups =
# ceil(active / K) crossbar activations instead of `active`).


@dataclasses.dataclass(frozen=True)
class GroupedDecodeTick:
    """Hardware cost of ONE K-grouped serving decode tick through one
    binary projection layer, vs slot-at-a-time execution."""

    engine: str
    k: int                # WDM capacity of the design's tile
    n_active: int         # active serving slots this tick
    groups: int           # crossbar activations with K-group batching
    slot_steps: int       # activations decoding one slot at a time
    speedup: float        # slot_steps / groups (≤ K; < K on ragged ticks)
    latency_ns: float
    energy_pj: float


def grouped_decode_tick(
    params: CIMParams, layer: LayerDesc, n_active: int
) -> GroupedDecodeTick:
    """Cost one serving tick of ``n_active`` slots through ``layer``."""
    eng = params.engine()
    groups = eng.steps_for(layer.m, layer.n, n_active)
    slot_steps = n_active * eng.steps_for(layer.m, layer.n, 1)
    t_step = (
        params.t_row_step_ns if params.mapping == "custbinarymap"
        else params.tile.t_vmm_ns
    )
    tick_params = dataclasses.replace(params, batch=n_active)
    tick_layer = dataclasses.replace(layer, positions=1)
    return GroupedDecodeTick(
        engine=params.engine_name,
        k=params.k,
        n_active=n_active,
        groups=groups,
        slot_steps=slot_steps,
        speedup=slot_steps / groups,
        latency_ns=groups * t_step,
        energy_pj=_BINARY_ENERGY[params.engine_name](tick_params, tick_layer),
    )


def grouped_decode_sweep(
    params: CIMParams, layer: LayerDesc, n_active: int, ks: tuple[int, ...]
) -> list[GroupedDecodeTick]:
    """``grouped_decode_tick`` across WDM capacities (K sweep): the
    design's tile is rebound to each K (non-WDM designs are K-invariant
    — their electrical tiles pin K=1, the serving fallback's vmap'd
    group — and return identical rows)."""
    out = []
    for k in ks:
        p = params
        if params.use_wdm:
            p = dataclasses.replace(
                params, tile=dataclasses.replace(params.tile, wdm_k=k)
            )
        out.append(grouped_decode_tick(p, layer, n_active))
    return out


# ---------------------------------------------------------------------------
# Mapping-plan pricing (repro/mapping compilation artifacts)
# ---------------------------------------------------------------------------
#
# The mapping compiler (repro/mapping) turns a model into an explicit
# MappingPlan — which tile holds which weight block, under which policy.
# price_plan() is the costmodel's direct entry point for those plans:
# binary layers are charged through the plan's own schedule (which sees
# tile-budget serialization the implicit per-network numbers above
# cannot), hi-res edge layers through the shared edge policy.


def params_for_spec(spec: CrossbarSpec) -> CIMParams:
    """The CIM design a tile spec implies: ePCM tiles price as
    TacitMap-ePCM, oPCM tiles as EinsteinBarrier (WDM iff K > 1)."""
    if spec.technology == "oPCM":
        return dataclasses.replace(
            EINSTEINBARRIER, tile=spec, use_wdm=spec.wdm_k > 1
        )
    return dataclasses.replace(TACITMAP_EPCM, tile=spec)


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """What one MappingPlan costs end to end on its implied design."""

    model: str
    policy: str
    design: str
    batch: int
    n_tiles: int          # physical tiles the plan provisions
    utilization: float    # active cells / provisioned cells (>1 = shared)
    binary_steps: int     # sequential crossbar activations, batch stream
    latency_s: float      # per inference (batch latency / batch)
    energy_j: float       # per inference
    layers: tuple[dict, ...]  # per-IR-entry aggregate rows


def price_plan(plan, params: CIMParams | None = None, batch: int | None = None) -> PlanCost:
    """Price a :class:`repro.mapping.allocator.MappingPlan` directly.

    Binary layers go through the plan's schedule (tile phases, WDM
    grouping, registered energy counters); non-binary edge layers run
    the shared hi-res policy. Returns per-inference latency/energy plus
    per-layer aggregates, so benchmark sweeps and serve-time reports
    price policies without re-deriving any counters.
    """
    from repro.mapping import schedule as schedule_lib  # mapping imports costmodel

    params = params or params_for_spec(plan.spec)
    if batch is not None:
        params = dataclasses.replace(params, batch=batch)
    sch = schedule_lib.schedule(plan, params=params)

    # aggregate instance rows back to IR entries for readable reports
    agg: dict[str, dict] = {}
    for lp, ls in zip(plan.layers, sch.layers):
        row = agg.setdefault(
            lp.ir.name,
            {"layer": lp.ir.name, "m": lp.ir.m, "n": lp.ir.n, "instances": 0,
             "blocks": 0, "steps_per_vector": 0, "steps": 0,
             "latency_ns": 0.0, "energy_pj": 0.0},
        )
        row["instances"] += 1
        row["blocks"] += ls.n_blocks
        row["steps_per_vector"] = max(row["steps_per_vector"], ls.steps_per_vector)
        row["steps"] += ls.steps
        row["latency_ns"] += ls.latency_ns
        row["energy_pj"] += ls.energy_pj

    total_ns = sch.total_latency_ns
    total_pj = sch.total_energy_pj
    for ir in plan.model.layers:
        if ir.binary:
            continue
        desc = ir.to_layer_desc()
        e_ns = ir.count * layer_latency_ns(params, desc)
        e_pj = ir.count * layer_energy_pj(params, desc)
        total_ns += e_ns
        total_pj += e_pj
        agg[ir.name] = {
            "layer": ir.name, "m": ir.m, "n": ir.n, "instances": ir.count,
            "blocks": 0, "steps_per_vector": 0,
            "steps": ir.count * layer_steps(params, desc),
            "latency_ns": e_ns, "energy_pj": e_pj,
        }

    return PlanCost(
        model=plan.model.name,
        policy=plan.policy,
        design=params.name,
        batch=params.batch,
        n_tiles=plan.n_tiles,
        utilization=plan.utilization(),
        binary_steps=sch.total_steps,
        latency_s=total_ns * 1e-9 / params.batch,
        energy_j=total_pj * 1e-12 / params.batch,
        layers=tuple(agg.values()),
    )


def plan_programming_cost(plan, params: CIMParams | None = None) -> ProgrammingCost:
    """One-time PCM-write cost of programming a whole MappingPlan.

    Sums :func:`layer_programming_cost` over the plan's binary IR
    entries (scan-repeat ``count`` expanded) on the design the plan's
    tile spec implies — the programming half of
    ``repro.compiler.CompiledModel.price()``.
    """
    params = params or params_for_spec(plan.spec)
    total = ProgrammingCost(cells=0, energy_pj=0.0, time_ns=0.0)
    for ir in plan.model.layers:
        if not ir.binary:
            continue
        one = layer_programming_cost(params, ir.to_layer_desc())
        total = total + ProgrammingCost(
            cells=one.cells * ir.count,
            energy_pj=one.energy_pj * ir.count,
            time_ns=one.time_ns * ir.count,
        )
    return total


@dataclasses.dataclass(frozen=True)
class PlanTickCost:
    """One K-grouped serving decode tick through EVERY binary layer of a
    plan (the per-tick readout half of ``CompiledModel.price()``)."""

    n_active: int
    k: int
    groups: int           # crossbar activations per tick, all layers
    latency_ns: float
    energy_pj: float


def plan_decode_tick(
    plan, n_active: int, params: CIMParams | None = None
) -> PlanTickCost:
    """Price one serving tick of ``n_active`` slots through a plan.

    Aggregates :func:`grouped_decode_tick` over the plan's binary IR
    entries × instance counts — what one decode token costs on the
    placed hardware once the weights are resident.
    """
    params = params or params_for_spec(plan.spec)
    groups, lat, en = 0, 0.0, 0.0
    for ir in plan.model.layers:
        if not ir.binary:
            continue
        tick = grouped_decode_tick(params, ir.to_layer_desc(), n_active)
        groups += ir.count * tick.groups
        lat += ir.count * tick.latency_ns
        en += ir.count * tick.energy_pj
    return PlanTickCost(
        n_active=n_active, k=params.k, groups=groups,
        latency_ns=lat, energy_pj=en,
    )


@dataclasses.dataclass(frozen=True)
class ScheduledTickCost:
    """One scheduler tick under partial admission: the tick's price at
    the ADMITTED width, plus how much of the provisioned pool it leaves
    idle (the request scheduler admits fewer slots than the pool holds
    whenever the KV budget or the waiting queue runs short)."""

    pool: int               # provisioned serving slots (max_batch)
    n_admitted: int         # slots the scheduler actually ran this tick
    k: int
    groups: int             # crossbar activations, all binary layers
    latency_ns: float
    energy_pj: float
    idle_lane_fraction: float   # provisioned-lane capacity left dark
    tokens_per_s: float         # admitted tokens / tick latency


def scheduled_decode_tick(
    plan, n_admitted: int, pool: int, params: CIMParams | None = None
) -> ScheduledTickCost:
    """Price one scheduler tick of ``n_admitted`` running slots out of a
    ``pool``-slot engine.

    Wraps :func:`plan_decode_tick` at the admitted width — a tick only
    pays for the K-groups it actually issues — and reports the idle
    fraction of the pool's lane capacity, so offered-load sweeps
    (benchmarks/scheduler.py) can chart throughput *and* the dark-lane
    cost of admission control under one price.
    """
    if not 0 <= n_admitted <= pool:
        raise ValueError(
            f"n_admitted must be in [0, pool={pool}], got {n_admitted}"
        )
    params = params or params_for_spec(plan.spec)
    if n_admitted == 0:
        return ScheduledTickCost(
            pool=pool, n_admitted=0, k=params.k, groups=0,
            latency_ns=0.0, energy_pj=0.0, idle_lane_fraction=1.0,
            tokens_per_s=0.0,
        )
    tick = plan_decode_tick(plan, n_admitted, params=params)
    # dark fraction of the provisioned pool, not a groups ratio: with
    # K >= pool one K-group covers every admitted width and a
    # groups-quantized metric would read 0% idle at n_admitted == 1
    idle = 1.0 - n_admitted / pool
    return ScheduledTickCost(
        pool=pool,
        n_admitted=n_admitted,
        k=tick.k,
        groups=tick.groups,
        latency_ns=tick.latency_ns,
        energy_pj=tick.energy_pj,
        idle_lane_fraction=idle,
        tokens_per_s=n_admitted / max(tick.latency_ns * 1e-9, 1e-18),
    )


@dataclasses.dataclass(frozen=True)
class FleetPrice:
    """N replicas of one priced target (PR 10 fleet serving).

    Replication on program-once CIM is an AREA trade, not a time one:
    every replica provisions and programs its own crossbars (tiles and
    write energy scale linearly), but the replicas program — and then
    tick — in parallel, so wall-clock programming time stays that of
    one target while fleet decode throughput scales with the replica
    count. Break-even stays per-replica: each replica's write pays for
    itself at the same tick count it would alone.
    """

    n_replicas: int
    n_active: int               # serving slots per replica per tick
    base: Any                   # the single-replica TargetPrice
    tiles_total: int            # n_replicas x tiles per replica
    programming_uj: float       # total fleet write energy
    programming_us: float       # wall-clock (replicas program in parallel)
    tick_latency_ns: float      # one fleet tick == one replica tick
    tick_energy_pj: float       # all replicas' ticks summed
    fleet_tokens_per_s: float   # n_replicas x n_active per tick latency
    break_even_ticks: float     # per replica — unchanged by replication

    def summary(self) -> str:
        return (
            f"[fleet] {self.n_replicas} x {self.base.plan_cost.model} on "
            f"{self.base.design}: {self.tiles_total} tiles total, program "
            f"{self.programming_uj:.2f} uJ in {self.programming_us:.1f} us "
            f"wall; tick {self.tick_latency_ns * 1e-3:.2f} us / "
            f"{self.tick_energy_pj:.1f} pJ fleet-wide; "
            f"{self.fleet_tokens_per_s:.2e} tok/s"
        )


def fleet_price(base, n_replicas: int, *, n_active: int = 16) -> FleetPrice:
    """Price ``n_replicas`` copies of one compiled target.

    ``base`` is the single target's
    :class:`~repro.compiler.pipeline.TargetPrice` (each replica is an
    identical program of the same plan). Tiles, programming energy and
    per-tick energy are linear in the replica count; programming time
    and tick latency are not (replicas run concurrently).
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    return FleetPrice(
        n_replicas=n_replicas,
        n_active=n_active,
        base=base,
        tiles_total=n_replicas * base.n_tiles,
        programming_uj=n_replicas * base.programming_uj,
        programming_us=base.programming_us,
        tick_latency_ns=base.tick_latency_ns,
        tick_energy_pj=n_replicas * base.tick_energy_pj,
        fleet_tokens_per_s=(
            n_replicas * n_active
            / max(base.tick_latency_ns * 1e-9, 1e-18)
        ),
        break_even_ticks=base.break_even_ticks,
    )


# ---------------------------------------------------------------------------
# GPU model
# ---------------------------------------------------------------------------


def gpu_layer_latency_s(params: GPUParams, layer: LayerDesc) -> float:
    ops = 2.0 * layer.macs * params.batch
    peak = params.peak_binary_ops if layer.binary else params.peak_fp
    if layer.positions > 1:
        peak *= params.conv_efficiency
    wbytes = layer.m * layer.n * (0.125 if layer.binary else 2.0)
    abytes = params.batch * layer.positions * layer.m * (0.125 if layer.binary else 2.0)
    t = max(ops / peak, (wbytes + abytes) / params.mem_bw)
    return t + params.kernels_for(layer) * params.launch_overhead_us * 1e-6


def gpu_network_latency_s(params: GPUParams, net: NetworkDesc) -> float:
    return sum(gpu_layer_latency_s(params, l) for l in net.layers) / params.batch


def gpu_network_energy_j(params: GPUParams, net: NetworkDesc) -> float:
    return gpu_network_latency_s(params, net) * params.power_w


# ---------------------------------------------------------------------------
# Report helpers
# ---------------------------------------------------------------------------


def evaluate_all(net: NetworkDesc) -> dict[str, dict[str, float]]:
    """Latency (s/image) and energy (J/image) for all four designs."""
    out: dict[str, dict[str, float]] = {}
    for p in (BASELINE_EPCM, TACITMAP_EPCM, EINSTEINBARRIER):
        out[p.name] = {
            "latency_s": network_latency_s(p, net),
            "energy_j": network_energy_j(p, net),
        }
    out[BASELINE_GPU.name] = {
        "latency_s": gpu_network_latency_s(BASELINE_GPU, net),
        "energy_j": gpu_network_energy_j(BASELINE_GPU, net),
    }
    return out


def speedup_over_baseline(net: NetworkDesc) -> dict[str, float]:
    r = evaluate_all(net)
    base = r["Baseline-ePCM"]["latency_s"]
    return {k: base / v["latency_s"] for k, v in r.items()}
