"""Pluggable execution-engine registry — the seam every backend plugs into.

The paper's central claim is that TacitMap / EinsteinBarrier "simply
accelerate" BNN inference: every execution path computes the *same*
XNOR+popcount contract (Eq. 1) and is therefore bit-exact and swappable.
PIMBALL (arXiv:1812.03989) and the optical XNOR-bitcount accelerator
(arXiv:2302.06405) frame that identity as the common contract across
hardware backends; this module encodes exactly that contract in
software.

An :class:`Engine` executes ±1 binary matmuls::

    binary_vmm(a_signs, w)         # (..., m) x (m, n) -> (..., n)
    binary_mmm(groups, w)          # (G, K, m) x (m, n) -> (G, K, n)

and exposes capability/cost metadata (``info``, ``steps_for``,
``preferred_group_size``) that the analytical cost model, the serving
engine's :class:`~repro.serving.engine.BatchPlanner` and the benchmark
sweeps consume uniformly.

**Two-phase program/execute contract (PR 4).** The paper's premise is
Computation-In-Memory: weights are programmed into the PCM crossbar
ONCE and only activations stream. ``Engine.prepare(w_signs)`` is that
programming phase in software — it runs every weight-side transform
once (complement-stack + tile mapping for the crossbar simulators,
int32 bit-packing for the packed kernel, placement-ordered block
gathers for the plan-driven tiled backend) and returns an opaque
:class:`PreparedWeights` artifact. ``binary_vmm``/``binary_mmm`` accept
either raw ±1 weights or a ``PreparedWeights``; the raw path delegates
through ``prepare``, so prepared and raw execution are bit-identical by
construction. ``prepare_cached`` memoizes programming on weight-array
identity (a bounded :class:`WeightCache` per engine instance), and the
serving engine programs every binarized projection at construction time
so decode ticks trace zero weight-side transforms.

``binary_mmm`` is the batching contract: one call executes G stacked
K-groups against shared binarized weights. Engines with
``info.native_mmm`` (WDM) execute each K-group as ONE hardware step —
``preferred_group_size()`` reports the K the substrate natively
multiplexes (the wavelength count); every other backend reports 1 and
serves ``binary_mmm`` through the flattened-VMM fallback (a "vmap'd
group"), so consumers can group unconditionally.

Capability matrix of the registered backends (``prepared`` = what
``prepare`` programs and holds resident):

====================  =======================================  ==========  ====================
name                  models                                   native MMM  prepared artifact
====================  =======================================  ==========  ====================
``reference``         Eq. 1 in plain jnp (ground truth)        no          plain ±1 signs
``tacitmap``          tiled ePCM/oPCM crossbar simulator       no          complement cell states
``wdm``               oPCM + K-wavelength WDM (EinsteinBarrier) yes (K)    complement cell states
``packed``            TPU bit-packed XNOR+popcount Pallas       no          int32 packed words
``tiled``             mapping-plan sharded tile execution       no          gathered block stacks
                                                                           + placement indices
``custbinarymap``     2T2R/PCSA row-serial baseline [15]       no          plain ±1 signs
====================  =======================================  ==========  ====================

All are bit-exact against ``reference`` (tests/test_engines.py,
tests/test_prepared.py). The ``packed`` backend is the TPU-native
analogue of the crossbar step — 32 weights per int32 lane, XOR +
population_count on the VPU — and runs in Pallas interpret mode on CPU
so it is testable everywhere.

Consumers resolve engines by name (CLI flags, configs) or pass
:class:`Engine` instances directly::

    eng = get_engine("packed")
    pw = eng.prepare(w_signs)          # program once ("crossbar write")
    out = eng.binary_vmm(a_signs, pw)  # stream activations

Model-level consumers should not hand-wire this: the one-call
``repro.compiler`` pipeline runs engine resolution, K-grouping and the
programming phase in the canonical order from a single target::

    # was: get_engine(name) + replace(cfg, quant="bnn", bnn_engine=name)
    #      + resolve_group_size(...) + GroupedEngine(eng, k)
    #      + lm.program_weights(params, cfg, eng)
    cm = repro.compiler.compile(cfg, params, HardwareTarget(engine="packed"))

New backends (multi-level cells, sharded crossbars, GPU) register with
:func:`register_engine` and become available to models, serving,
benchmarks and hardware targets without touching any consumer.
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bnn, custbinarymap, tacitmap, wdm
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE

Array = jax.Array


# ---------------------------------------------------------------------------
# Prepared weights (the programming-phase artifact) + caches
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PreparedWeights:
    """Weights programmed into one engine's resident execution form.

    Produced by :meth:`Engine.prepare`; consumed by ``binary_vmm`` /
    ``binary_mmm`` in place of raw ±1 weights, so the weight-side
    transforms run once per bind instead of once per call — the paper's
    stationary-weight (CIM) premise made explicit.

    Registered as a JAX pytree: ``data`` holds the array leaves (they
    ride through jit/scan/vmap like any operand — the serving engine
    stacks per-repeat artifacts and ``lax.scan`` slices them back per
    layer), while ``(engine, m, n, aux)`` are static treedef metadata.
    ``aux`` is engine-specific *hashable* host-side state (e.g. the
    tiled backend's placement index tuples).
    """

    engine: str          # name of the backend that programmed this
    m: int               # logical contraction length
    n: int               # stored weight vectors (output columns)
    data: Any            # engine-specific pytree of arrays
    aux: Any = None      # hashable host-side placement metadata

    def tree_flatten(self):
        return (self.data,), (self.engine, self.m, self.n, self.aux)

    @classmethod
    def tree_unflatten(cls, static, children):
        engine, m, n, aux = static
        return cls(engine=engine, m=m, n=n, data=children[0], aux=aux)


class LRUCache:
    """Small bounded LRU with hit/miss/eviction counters (host-side).

    A ``name`` makes the counters *live*: every hit/miss/eviction is
    mirrored into the active telemetry session's metrics registry
    (``repro_cache_events_total{cache=<name>,kind=...}``) — one ``None``
    check per event when telemetry is off. The frozen ``stats`` snapshot
    stays the source of truth either way.
    """

    def __init__(self, maxsize: int = 32, name: str | None = None):
        if maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self.name = name
        self._store: OrderedDict[Any, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        try:
            value = self._store[key]
        except KeyError:
            self.misses += 1
            if self.name is not None:
                obs.cache_event(self.name, "miss")
            return default
        self._store.move_to_end(key)
        self.hits += 1
        if self.name is not None:
            obs.cache_event(self.name, "hit")
        return value

    def put(self, key, value) -> None:
        self._store[key] = value
        self._store.move_to_end(key)
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
            self.evictions += 1
            if self.name is not None:
                obs.cache_event(self.name, "eviction")

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key) -> bool:
        return key in self._store

    @property
    def stats(self) -> dict[str, int]:
        return {
            "size": len(self._store),
            "maxsize": self.maxsize,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }


class WeightCache:
    """Prepared-weight cache keyed by weight-array *identity*.

    A parameter update produces a NEW ``jax.Array``, so identity keying
    is the invalidation rule: a changed weight is a guaranteed miss and
    its stale entry ages out of the bounded LRU. Each entry keeps a
    strong reference to its key array, so an ``id()`` can never be
    recycled while the entry is alive. Tracers are never cached — a
    prepare traced inside jit belongs to that trace only.
    """

    def __init__(self, maxsize: int = 32):
        self._lru = LRUCache(maxsize, name="weight_cache")

    def get(self, w) -> PreparedWeights | None:
        entry = self._lru.get(id(w))
        if entry is not None and entry[0] is w:
            return entry[1]
        return None

    def put(self, w, pw: PreparedWeights) -> None:
        self._lru.put(id(w), (w, pw))

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def stats(self) -> dict[str, int]:
        return self._lru.stats


@dataclasses.dataclass(frozen=True)
class EngineInfo:
    """Capability/cost metadata for one backend (the capability matrix)."""

    name: str
    description: str
    hardware: str                 # what physical substrate this models
    native_mmm: bool = False      # executes K input vectors per step (WDM)
    packed: bool = False          # bit-packed operands (1 bit / lane)
    default_spec: str = "ePCM"    # which tile catalogue entry it defaults to

    @property
    def bit_exact(self) -> bool:
        """Every registered engine must reproduce Eq. 1 exactly."""
        return True


@runtime_checkable
class Engine(Protocol):
    """The execution contract every backend implements.

    ``binary_vmm``/``binary_mmm`` consume ±1-valued activations (any
    float or integer carrier) against either raw ±1 weights or a
    :class:`PreparedWeights` from this engine's ``prepare``, and return
    the exact ±1 dot products (integer valued; the carrier dtype may
    differ per backend — callers cast).
    """

    name: str
    info: EngineInfo
    spec: CrossbarSpec

    def prepare(self, w_signs) -> PreparedWeights: ...

    def binary_vmm(self, a_signs: Array, w) -> Array: ...

    def binary_mmm(self, groups: Array, w) -> Array: ...

    def steps_for(self, m: int, n: int, n_inputs: int) -> int: ...

    def preferred_group_size(self) -> int: ...


class _EngineBase:
    """Shared plumbing: spec binding, the two-phase program/execute
    contract, MMM-via-VMM fallback, weight cache, repr.

    Subclasses implement ``_program`` (weight signs -> resident data
    pytree), optionally ``_program_aux`` (hashable host-side placement
    metadata) and ``_vmm_prepared`` (execute against the artifact).
    """

    info: EngineInfo

    def __init__(self, spec: CrossbarSpec | None = None):
        default = OPCM_TILE if self.info.default_spec == "oPCM" else EPCM_TILE
        self.spec = spec or default
        self.weight_cache = WeightCache()

    @property
    def name(self) -> str:
        return self.info.name

    # -- programming phase --------------------------------------------------

    def _program(self, w_signs: Array):
        """Engine-specific weight compilation -> ``PreparedWeights.data``.
        Default: plain ±1 signs (reference / custbinarymap)."""
        return w_signs

    def _program_aux(self, m: int, n: int):
        """Hashable host-side placement metadata (``tiled`` overrides)."""
        del m, n
        return None

    def prepare(self, w_signs) -> PreparedWeights:
        """Program ±1 weights (m, n) into this engine's resident form.

        One-time per weight matrix — the paper's crossbar-programming
        (PCM write) phase. The artifact is accepted by
        ``binary_vmm``/``binary_mmm`` in place of raw signs; the raw-w
        path delegates through here, so prepared and raw execution are
        bit-identical by construction. Idempotent on an already-prepared
        artifact (validated against this engine's name).
        """
        if isinstance(w_signs, PreparedWeights):
            return self._check_prepared(w_signs)
        m, n = w_signs.shape
        return PreparedWeights(
            engine=self.name,
            m=int(m),
            n=int(n),
            data=self._program(w_signs),
            aux=self._program_aux(int(m), int(n)),
        )

    def prepare_cached(self, w_signs, key=None) -> PreparedWeights:
        """``prepare`` memoized on the *identity* of ``key`` (default:
        the weight array itself; model layers pass the latent fp32 param
        so a hit skips re-binarization of an unchanged param entirely).

        ``w_signs`` may be a zero-arg callable producing the signs — it
        is only invoked on a cache miss, so hits pay no weight-side
        work at all. Tracers bypass the cache: a prepare traced inside
        jit is part of that trace and must not leak across calls.
        """
        if isinstance(w_signs, PreparedWeights):
            return self._check_prepared(w_signs)
        lazy = callable(w_signs)
        if key is None:
            if lazy:
                raise ValueError("a callable w_signs needs an explicit cache key")
            key = w_signs
        if isinstance(key, jax.core.Tracer) or isinstance(w_signs, jax.core.Tracer):
            return self.prepare(w_signs() if lazy else w_signs)
        pw = self.weight_cache.get(key)
        if pw is None:
            pw = self.prepare(w_signs() if lazy else w_signs)
            self.weight_cache.put(key, pw)
        return pw

    def _check_prepared(self, pw: PreparedWeights) -> PreparedWeights:
        if pw.engine != self.name:
            raise ValueError(
                f"prepared weights were programmed for engine {pw.engine!r}; "
                f"this engine is {self.name!r} — re-run prepare()"
            )
        return pw

    def cache_stats(self) -> dict[str, dict[str, int]]:
        """Hit/miss counters for every cache this engine maintains."""
        return {"weight_cache": self.weight_cache.stats}

    # -- execution phase ----------------------------------------------------

    def _check_operands(self, a_signs: Array, pw: PreparedWeights) -> PreparedWeights:
        """A mis-paired artifact whose m happens to divide the activation
        length would otherwise reshape into silent garbage (wdm/packed)."""
        if a_signs.shape[-1] != pw.m:
            raise ValueError(
                f"activation length {a_signs.shape[-1]} does not match the "
                f"prepared weights' m={pw.m} (engine {self.name}) — wrong "
                f"artifact for this projection?"
            )
        return pw

    def binary_vmm(self, a_signs: Array, w) -> Array:
        """(..., m) x (m, n) -> (..., n); ``w`` raw or prepared."""
        return self._vmm_prepared(a_signs, self._check_operands(a_signs, self.prepare(w)))

    def binary_mmm(self, groups: Array, w) -> Array:
        """(G, K, m) x (m, n) -> (G, K, n); default: flatten to a VMM."""
        g, k, m = groups.shape
        pw = self._check_operands(groups, self.prepare(w))
        out = self._vmm_prepared(groups.reshape(g * k, m), pw)
        return out.reshape(g, k, -1)

    def preferred_group_size(self) -> int:
        """K-vectors the substrate executes per hardware step.

        1 for every non-``native_mmm`` backend: grouping still works
        (``binary_mmm`` flattens), but each vector in the group costs a
        sequential step — the serving engine treats these as a vmap'd
        group and picks its own K.
        """
        return 1

    def with_spec(self, spec: CrossbarSpec) -> "Engine":
        """Same backend rebound to another tile spec (subclasses with
        extra constructor state override to preserve it)."""
        return type(self)(spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """Sequential hardware steps for ``n_inputs`` vectors (cost model)."""
        del m, n
        return n_inputs

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Engine {self.name} spec={self.spec.technology}>"


def _stacked_cells(w_signs: Array) -> Array:
    """The crossbar simulators' programmed state: the complement-stacked
    {0,1} cell matrix (2m, n) — Fig. 2-(b), the mapping's PCM write.

    Stored COMPACT, not as the padded (row_tiles, R, col_tiles, C) tile
    array: the tile grid is a pure reshape *view* of this matrix, and
    holding the padded form resident makes every execute read
    RT·R·CT·C cells where the logical matrix is only 2m x n — measured
    slower than the unprepared path on CPU (memory traffic dominates at
    decode sizes). The pad+reshape at execute time fuses into the MAC
    einsum; the weight-side *arithmetic* (binarize, complement stack)
    is what prepare hoists.
    """
    return bnn.stack_complement_weights(bnn.signs_to_bits(w_signs)).astype(jnp.float32)


def _mapped_layer(pw: PreparedWeights, spec: CrossbarSpec) -> tacitmap.MappedLayer:
    """Rehydrate a :class:`tacitmap.MappedLayer` around prepared cell
    states (the tile grid is a pure function of (m, n, spec) — only the
    cell matrix carries state; layout shared with ``map_weights``)."""
    return tacitmap.layer_from_cells(pw.data, pw.m, pw.n, spec)


class ReferenceEngine(_EngineBase):
    """Eq. 1 in plain jnp — the ground truth every backend must match."""

    info = EngineInfo(
        name="reference",
        description="plain jnp ±1 matmul (Eq. 1 ground truth)",
        hardware="any (XLA)",
    )

    def _vmm_prepared(self, a_signs: Array, pw: PreparedWeights) -> Array:
        return bnn.binary_matmul_signs(a_signs, pw.data)


class TacitMapEngine(_EngineBase):
    """The paper's mapping run through the full tiled-crossbar simulator."""

    info = EngineInfo(
        name="tacitmap",
        description="tiled crossbar functional simulator (complement VMM)",
        hardware="ePCM/oPCM crossbar tiles + ADC readout",
    )

    def _program(self, w_signs: Array):
        # the paper's programming step: write the complement cell states
        return _stacked_cells(w_signs)

    def _vmm_prepared(self, a_signs: Array, pw: PreparedWeights) -> Array:
        pc = tacitmap.apply(_mapped_layer(pw, self.spec), bnn.signs_to_bits(a_signs))
        return 2 * pc - pw.m

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return tacitmap.steps_for(m, n, n_inputs, self.spec)


class WDMEngine(_EngineBase):
    """EinsteinBarrier: oPCM crossbar + K-wavelength MMM steps."""

    info = EngineInfo(
        name="wdm",
        description="oPCM + WDM: K input vectors per crossbar step (MMM)",
        hardware="oPCM photonic crossbar, K-wavelength transmitter",
        native_mmm=True,
        default_spec="oPCM",
    )

    def _program(self, w_signs: Array):
        return _stacked_cells(w_signs)

    def _vmm_prepared(self, a_signs: Array, pw: PreparedWeights) -> Array:
        flat = a_signs.reshape(-1, pw.m)
        pc = wdm.wdm_apply(_mapped_layer(pw, self.spec), bnn.signs_to_bits(flat))
        return (2 * pc - pw.m).reshape(*a_signs.shape[:-1], -1)

    def binary_mmm(self, groups: Array, w) -> Array:
        pw = self._check_operands(groups, self.prepare(w))
        pc = wdm.mmm(_mapped_layer(pw, self.spec), bnn.signs_to_bits(groups))
        return 2 * pc - pw.m

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        del m, n
        return wdm.steps_for(n_inputs, self.spec.wdm_k)

    def preferred_group_size(self) -> int:
        """The wavelength count: K input vectors ride one crossbar step."""
        return self.spec.wdm_k


class PackedEngine(_EngineBase):
    """Bit-packed XNOR+popcount Pallas kernel — the TPU-native crossbar step.

    32 binary weights/activations per int32 lane, XOR + population_count
    on the VPU (kernels/xnor_matmul.py). On CPU the kernel runs in
    Pallas interpret mode automatically (``interpret=None``), so the
    backend is testable everywhere; on TPU it compiles. ``prepare``
    holds the weight words resident (``ops.pack_weights``) so only the
    activation side packs per call.

    ``fused=True`` (the default) additionally advertises the fused
    decode-tick capability: :meth:`fused_dense` runs the whole BitLinear
    seam — binarize + bit-pack + XNOR + popcount + Eq. 1 affine + α/β
    rescale — as ONE ``kernels/fused_decode.py`` launch against prepared
    weights; ``fused=False`` keeps the unfused multi-op path as the
    benchmark baseline. ``prepad=True`` makes ``prepare`` emit weight
    words already padded to kernel block multiples
    (``ops.pad_packed_weights``) so the execute-phase re-pad is a no-op;
    results are bit-identical either way.
    """

    info = EngineInfo(
        name="packed",
        description="bit-packed XNOR+popcount Pallas kernel (Eq. 1 affine)",
        hardware="TPU VPU (interpret-mode on CPU)",
        packed=True,
    )

    def __init__(
        self,
        spec: CrossbarSpec | None = None,
        *,
        interpret: bool | None = None,
        fused: bool = True,
        prepad: bool = False,
    ):
        super().__init__(spec)
        self.interpret = interpret
        self.fused = bool(fused)
        self.prepad = bool(prepad)

    def with_spec(self, spec: CrossbarSpec) -> "PackedEngine":
        return type(self)(
            spec, interpret=self.interpret, fused=self.fused, prepad=self.prepad
        )

    def _program(self, w_signs: Array):
        from repro.kernels import ops

        wp = ops.pack_weights(w_signs)
        return ops.pad_packed_weights(wp) if self.prepad else wp

    def _vmm_prepared(self, a_signs: Array, pw: PreparedWeights) -> Array:
        from repro.kernels import ops

        return ops.xnor_matmul_packed_weights(
            a_signs, pw.data, m=pw.m, n=pw.n, interpret=self.interpret
        )

    @property
    def supports_fused_dense(self) -> bool:
        """Capability flag the BitLinear seam (``models.layers.dense``)
        probes before routing raw activations through the fused kernel."""
        return self.fused

    def fused_dense(self, x: Array, pw: PreparedWeights, alpha: Array) -> Array:
        """Whole BitLinear against prepared weights in one kernel launch.

        (..., m) RAW activations (not pre-binarized) x prepared words x
        alpha (scalar, or (n,) for concatenated fused projections) ->
        (..., n) fp32 of ``(binarize(x) @ w±1) * (alpha * mean|x|)`` —
        bit-exact vs the unfused binarize/pack/matmul/rescale chain.
        Leading dims flatten, so the serving engine's stacked (G, K, m)
        grouped activations are one launch.
        """
        from repro.kernels import ops

        pw = self._check_operands(x, self._check_prepared(pw))
        return ops.fused_bnn_matmul(
            x, pw.data, alpha, m=pw.m, n=pw.n, interpret=self.interpret
        )

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        # one fused kernel launch executes the whole (B, m, n) matmul
        del m, n, n_inputs
        return 1


class TiledEngine(_EngineBase):
    """Mapping-plan-driven sharded tile execution.

    Where ``tacitmap`` simulates the whole tiled array in one einsum,
    this backend executes the *compiled placement*: operands are sliced
    into exactly the ``spec.rows x spec.cols`` blocks a
    :class:`repro.mapping.allocator.MappingPlan` placed, the per-block
    partial popcounts run as ONE vmap over the tile axis (in the plan's
    block order), and digital partial-sum accumulation scatters them
    back per output column group. Bit-exact vs ``reference`` for every
    allocator policy — placement permutes tile order, never the math.

    ``prepare`` programs the placement: the complement cell states are
    compiled once and the host-side placement indices (block order,
    gather/segment ids — previously recomputed per call) ride along as
    hashable aux metadata; execute rebuilds the plan-ordered (T, R, C)
    block stack as a fused view. Ad-hoc placements and their index
    arrays are memoized per (m, n) in bounded LRUs on the engine
    instance.

    The tile axis is the sharding axis: under an active
    ``activation_hints`` mesh the stacked tiles and their partials are
    constrained to the engine's ``mesh_axis`` (default ``model``;
    ``HardwareTarget.mesh_axis`` threads through here), so a
    multi-device run splits the plan's tile pool across devices (the
    ROADMAP's "sharded-crossbar tiles" backend).

    Construction: ``get_engine("tiled", plan=plan)`` executes per a
    compiled plan (and inherits its tile spec); without a plan, each
    distinct (m, n) weight shape is placed on the fly under ``policy``
    and cached on the engine instance.
    """

    info = EngineInfo(
        name="tiled",
        description="plan-driven sharded tile execution (complement blocks, vmap over tiles)",
        hardware="ePCM/oPCM crossbar tile pool; tile axis shards over a jax mesh",
    )

    ADHOC_CACHE_SIZE = 32

    def __init__(
        self,
        spec: CrossbarSpec | None = None,
        *,
        plan=None,
        policy: str = "tacitmap",
        mesh_axis: str = "model",
    ):
        if plan is not None and spec is None:
            spec = plan.spec
        super().__init__(spec)
        if plan is not None and plan.spec != self.spec:
            raise ValueError(
                f"plan was compiled for {plan.spec.technology} "
                f"{plan.spec.rows}x{plan.spec.cols} tiles but the engine is "
                f"bound to {self.spec.technology} {self.spec.rows}x{self.spec.cols}"
            )
        self.plan = plan
        self.policy = policy
        self.mesh_axis = mesh_axis
        self._adhoc_cache = LRUCache(self.ADHOC_CACHE_SIZE, name="adhoc_placements")
        self._index_cache = LRUCache(self.ADHOC_CACHE_SIZE, name="placement_indices")

    def with_spec(self, spec: CrossbarSpec) -> "TiledEngine":
        keep = self.plan if (self.plan is not None and self.plan.spec == spec) else None
        return type(self)(spec, plan=keep, policy=self.policy, mesh_axis=self.mesh_axis)

    def cache_stats(self) -> dict[str, dict[str, int]]:
        return {
            **super().cache_stats(),
            "adhoc_placements": self._adhoc_cache.stats,
            "placement_indices": self._index_cache.stats,
        }

    def _placement(self, m: int, n: int):
        """The plan's LayerPlan for a (m, n) matrix, or an on-the-fly
        single-layer placement under this engine's policy (cached)."""
        if self.plan is not None:
            lp = self.plan.layer_for(m, n)
            if lp is not None:
                return lp
        lp = self._adhoc_cache.get((m, n))
        if lp is None:
            from repro.mapping import allocator, ir  # lazy: mapping imports costmodel

            lp = allocator.allocate(
                ir.adhoc_layer(m, n), spec=self.spec, policy=self.policy
            ).layers[0]
            self._adhoc_cache.put((m, n), lp)
        return lp

    def _indices(self, m: int, n: int):
        """Placement + host-side index arrays for a (m, n) matrix,
        memoized per shape: the plan's block order and the derived
        gather/segment ids used to be rebuilt on every ``binary_vmm``."""
        cached = self._index_cache.get((m, n))
        if cached is None:
            lp = self._placement(m, n)
            order = lp.block_order()
            ct = lp.grid.col_tiles
            block_ids = np.asarray([rb * ct + cb for rb, cb in order], np.int32)
            row_ids = np.asarray([rb for rb, _ in order], np.int32)
            col_ids = np.asarray([cb for _, cb in order], np.int32)
            cached = (lp, block_ids, row_ids, col_ids)
            self._index_cache.put((m, n), cached)
        return cached

    def _program(self, w_signs: Array):
        # programmed cell states (complement-stacked, compact). The
        # placement-ordered (T, R, C) block stack is rebuilt as a fused
        # pad+reshape+gather VIEW at execute time: holding the gathered
        # stack resident makes every call (and every lax.scan slice in
        # the serving decode) move T·R·C cells where the logical matrix
        # is 2m x n — measured slower than the unprepared path. What
        # prepare hoists is the weight-side arithmetic and the
        # placement computation (allocator + block order, in aux).
        return _stacked_cells(w_signs)

    def _program_aux(self, m: int, n: int):
        lp, block_ids, row_ids, col_ids = self._indices(m, n)
        return (
            tuple(int(i) for i in block_ids),
            tuple(int(i) for i in row_ids),
            tuple(int(i) for i in col_ids),
            int(lp.grid.row_tiles),
            int(lp.grid.col_tiles),
            int(self.spec.rows),
            int(self.spec.cols),
        )

    def _vmm_prepared(self, a_signs: Array, pw: PreparedWeights) -> Array:
        from repro.core.crossbar import adc_quantize
        from repro.distributed.hints import hint

        block_ids, row_ids, col_ids, RT, CT, R, C = pw.aux
        if (R, C) != (self.spec.rows, self.spec.cols):
            raise ValueError(
                f"prepared cells were placed on {R}x{C} blocks but the engine "
                f"is bound to {self.spec.rows}x{self.spec.cols} tiles — re-run prepare()"
            )
        m, n = pw.m, pw.n
        spec = self.spec
        # the placement view: pad to the grid, gather blocks in the
        # PLAN'S order (the policy's layout)
        padded = jnp.pad(pw.data, ((0, RT * R - 2 * m), (0, CT * C - n)))
        blocks = padded.reshape(RT, R, CT, C).transpose(0, 2, 1, 3).reshape(RT * CT, R, C)
        tiles = jnp.take(blocks, jnp.asarray(block_ids, jnp.int32), axis=0)
        tiles = hint(tiles, self.mesh_axis)  # shard the tile axis when a mesh is active

        # inputs: complement drive, cut into the row blocks each tile sees
        drive = bnn.concat_complement_input(bnn.signs_to_bits(a_signs))
        drive = jnp.pad(drive, [(0, 0)] * (drive.ndim - 1) + [(0, RT * R - 2 * m)])
        drive = drive.reshape(*drive.shape[:-1], RT, R)
        gather = jnp.take(drive, jnp.asarray(row_ids, jnp.int32), axis=-2)
        drive_t = jnp.moveaxis(gather, -2, 0)  # (T, ..., R)

        def one_tile(tile: Array, drv: Array) -> Array:
            # one crossbar activation: analog MAC + that tile's ADC
            pc = jnp.einsum("...r,rc->...c", drv.astype(jnp.float32), tile)
            return adc_quantize(pc, spec, active_rows=R)

        partial = jax.vmap(one_tile)(tiles, drive_t)  # (T, ..., C)
        partial = hint(partial, self.mesh_axis)
        # digital partial-sum accumulation: row-block partials of each
        # output column group add up, in whatever order the plan placed them
        summed = jax.ops.segment_sum(
            partial, jnp.asarray(col_ids, jnp.int32), num_segments=CT
        )
        out = jnp.moveaxis(summed, 0, -2)  # (..., CT, C)
        pc = out.reshape(*out.shape[:-2], CT * C)[..., :n]
        return 2 * pc - m

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """WDM-grouped stream x the plan's per-vector serialization (a
        tile co-hosting j blocks of one layer fires j times)."""
        lp = self._placement(m, n)
        groups = math.ceil(n_inputs / max(1, self.spec.wdm_k))
        return groups * lp.steps_per_vector

    def preferred_group_size(self) -> int:
        """The plan's WDM capacity (== spec.wdm_k for the bound tiles)."""
        if self.plan is not None:
            return self.plan.preferred_group_size()
        return self.spec.wdm_k

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        planned = self.plan.model.name if self.plan is not None else f"adhoc/{self.policy}"
        return f"<Engine tiled spec={self.spec.technology} plan={planned}>"


class CustBinaryMapEngine(_EngineBase):
    """The SotA baseline mapping [15]: one weight vector per step (PCSA)."""

    info = EngineInfo(
        name="custbinarymap",
        description="2T2R row-serial baseline (PCSA readout, digital popcount)",
        hardware="ePCM 2T2R arrays + precharge sense amplifiers",
    )

    def _vmm_prepared(self, a_signs: Array, pw: PreparedWeights) -> Array:
        return custbinarymap.binary_matmul(a_signs, pw.data, self.spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return custbinarymap.steps_for(m, n, n_inputs, self.spec)


# ---------------------------------------------------------------------------
# K-group batching adapter (WDM-style MMM execution of any backend)
# ---------------------------------------------------------------------------


class GroupedEngine:
    """Execute a backend's VMMs as K-grouped ``binary_mmm`` calls.

    This is the serving engine's unit-of-work change: a batch of B
    input vectors becomes G = ceil(B / K) stacked K-groups and issues
    ONE ``binary_mmm`` registry call (stacked activations, shared
    binarized weights) instead of B vector calls. Ragged tails are
    padded with +1 signs — idle comb lines in WDM hardware — and the
    pad outputs are discarded, so the adapter is bit-exact for any B.

    For ``native_mmm`` backends (WDM) each K-group is one crossbar
    step; for the rest the group flattens back to a VMM (a vmap'd
    group), so the adapter composes with every registered engine.
    Prepared weights pass straight through to the base backend —
    ``prepare``/``prepare_cached`` and the weight cache delegate.
    """

    def __init__(self, base: Engine, k: int):
        if k < 1:
            raise ValueError(f"group size must be >= 1, got {k}")
        self.base = base
        self.k = int(k)
        self.info = base.info
        self.spec = base.spec

    @property
    def name(self) -> str:
        return f"{self.base.name}@k{self.k}"

    @property
    def weight_cache(self) -> WeightCache | None:
        return getattr(self.base, "weight_cache", None)

    def prepare(self, w_signs):
        """Delegates programming to the base backend; a minimal backend
        without the two-phase contract is served raw signs (which its
        ``binary_mmm`` accepts unchanged)."""
        if hasattr(self.base, "prepare"):
            return self.base.prepare(w_signs)
        return w_signs

    def prepare_cached(self, w_signs, key=None):
        if hasattr(self.base, "prepare_cached"):
            return self.base.prepare_cached(w_signs, key)
        return w_signs() if callable(w_signs) else w_signs

    def cache_stats(self) -> dict[str, dict[str, int]]:
        if hasattr(self.base, "cache_stats"):
            return self.base.cache_stats()
        return {}

    def binary_vmm(self, a_signs: Array, w) -> Array:
        m = a_signs.shape[-1]
        flat = a_signs.reshape(-1, m)
        b = flat.shape[0]
        g = max(1, math.ceil(b / self.k))
        pad = g * self.k - b
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.ones((pad, m), flat.dtype)], axis=0
            )
        out = self.base.binary_mmm(flat.reshape(g, self.k, m), w)
        out = out.reshape(g * self.k, -1)[:b]
        return out.reshape(*a_signs.shape[:-1], -1)

    def binary_mmm(self, groups: Array, w) -> Array:
        return self.base.binary_mmm(groups, w)

    @property
    def supports_fused_dense(self) -> bool:
        return getattr(self.base, "supports_fused_dense", False)

    def fused_dense(self, x: Array, pw, alpha) -> Array:
        """Fused BitLinear passes straight through: the fused kernel
        flattens leading dims itself, so a stacked (G, K, m) group is
        already one launch — no pad-to-K bookkeeping needed."""
        return self.base.fused_dense(x, pw, alpha)

    def with_spec(self, spec: CrossbarSpec) -> "GroupedEngine":
        return GroupedEngine(resolve(self.base, spec), self.k)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """ceil(B / K) group launches, each costing the base engine a
        K-vector step (1 for native MMM, K sequential otherwise)."""
        groups = math.ceil(n_inputs / self.k)
        return groups * self.base.steps_for(m, n, self.k)

    def preferred_group_size(self) -> int:
        return self.k

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<GroupedEngine {self.name}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Engine]] = {}


def register_engine(name: str, factory: Callable[..., Engine]) -> None:
    """Register a backend factory: ``factory(spec=None, **kw) -> Engine``.

    Re-registration under an existing name replaces the factory (useful
    for tests and for swapping in tuned variants).
    """
    _REGISTRY[name] = factory


def list_engines() -> list[str]:
    """Sorted names of every registered backend."""
    return sorted(_REGISTRY)


def get_engine(name: str, spec: CrossbarSpec | None = None, **kw) -> Engine:
    """Instantiate a registered backend, optionally binding a tile spec."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {', '.join(list_engines())}"
        ) from None
    return factory(spec, **kw)


def resolve(engine: str | Engine, spec: CrossbarSpec | None = None) -> Engine:
    """Accept an engine name or an already-constructed Engine instance.

    Spec comparison is by *equality*, not identity: an equal-but-distinct
    ``CrossbarSpec`` must not rebuild the engine (rebuilding would bust
    its per-instance weight/placement caches for no functional change).
    """
    if isinstance(engine, str):
        return get_engine(engine, spec)
    if spec is not None and engine.spec != spec:
        if hasattr(engine, "with_spec"):  # preserves extra ctor state
            return engine.with_spec(spec)
        return get_engine(engine.name, spec)
    return engine


def engine_info(name: str) -> EngineInfo:
    """Capability metadata without instantiating arrays/specs."""
    return get_engine(name).info


def resolve_group_size(
    engine: Engine | None, requested: int | None, batch: int, plan=None
) -> int:
    """The K-group sizing policy shared by the serving engine and CLIs.

    Explicit request (> 0) wins; else a compiled mapping plan
    contributes its WDM capacity (``plan.preferred_group_size()`` — the
    static mapping artifact knows the placed tile technology even when
    the executing backend has no native MMM); else any engine whose
    ``preferred_group_size()`` exceeds 1 contributes it (WDM's
    wavelength count, a plan-bound tiled engine's tile K); else one
    vmap'd group spans the batch. Always clamped to [1, batch].
    """
    if requested:
        k = requested
    elif plan is not None and plan.preferred_group_size() > 1:
        k = plan.preferred_group_size()
    elif engine is not None and engine.preferred_group_size() > 1:
        k = engine.preferred_group_size()
    else:
        k = batch
    return max(1, min(int(k), batch))


for _cls in (
    ReferenceEngine,
    TacitMapEngine,
    WDMEngine,
    PackedEngine,
    TiledEngine,
    CustBinaryMapEngine,
):
    register_engine(_cls.info.name, _cls)
