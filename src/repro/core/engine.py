"""Pluggable execution-engine registry — the seam every backend plugs into.

The paper's central claim is that TacitMap / EinsteinBarrier "simply
accelerate" BNN inference: every execution path computes the *same*
XNOR+popcount contract (Eq. 1) and is therefore bit-exact and swappable.
PIMBALL (arXiv:1812.03989) and the optical XNOR-bitcount accelerator
(arXiv:2302.06405) frame that identity as the common contract across
hardware backends; this module encodes exactly that contract in
software.

An :class:`Engine` executes ±1 binary matmuls::

    binary_vmm(a_signs, w_signs)   # (..., m) x (m, n) -> (..., n)
    binary_mmm(groups, w_signs)    # (G, K, m) x (m, n) -> (G, K, n)

and exposes capability/cost metadata (``info``, ``steps_for``,
``preferred_group_size``) that the analytical cost model, the serving
engine's :class:`~repro.serving.engine.BatchPlanner` and the benchmark
sweeps consume uniformly.

``binary_mmm`` is the batching contract: one call executes G stacked
K-groups against shared binarized weights. Engines with
``info.native_mmm`` (WDM) execute each K-group as ONE hardware step —
``preferred_group_size()`` reports the K the substrate natively
multiplexes (the wavelength count); every other backend reports 1 and
serves ``binary_mmm`` through the flattened-VMM fallback (a "vmap'd
group"), so consumers can group unconditionally.

Capability matrix of the registered backends:

====================  =======================================  ==========
name                  models                                   native MMM
====================  =======================================  ==========
``reference``         Eq. 1 in plain jnp (ground truth)        no
``tacitmap``          tiled ePCM/oPCM crossbar simulator       no
``wdm``               oPCM + K-wavelength WDM (EinsteinBarrier) yes (K)
``packed``            TPU bit-packed XNOR+popcount Pallas       no
``custbinarymap``     2T2R/PCSA row-serial baseline [15]        no
====================  =======================================  ==========

All are bit-exact against ``reference`` (tests/test_engines.py). The
``packed`` backend is the TPU-native analogue of the crossbar step —
32 weights per int32 lane, XOR + population_count on the VPU — and runs
in Pallas interpret mode on CPU so it is testable everywhere.

Consumers resolve engines by name (CLI flags, configs) or pass
:class:`Engine` instances directly::

    eng = get_engine("packed")
    out = eng.binary_vmm(a_signs, w_signs)

New backends (multi-level cells, sharded crossbars, GPU) register with
:func:`register_engine` and become available to models, serving and
benchmarks without touching any consumer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import bnn, custbinarymap, tacitmap, wdm
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineInfo:
    """Capability/cost metadata for one backend (the capability matrix)."""

    name: str
    description: str
    hardware: str                 # what physical substrate this models
    native_mmm: bool = False      # executes K input vectors per step (WDM)
    packed: bool = False          # bit-packed operands (1 bit / lane)
    default_spec: str = "ePCM"    # which tile catalogue entry it defaults to

    @property
    def bit_exact(self) -> bool:
        """Every registered engine must reproduce Eq. 1 exactly."""
        return True


@runtime_checkable
class Engine(Protocol):
    """The execution contract every backend implements.

    ``binary_vmm``/``binary_mmm`` consume ±1-valued arrays (any float or
    integer carrier) and return the exact ±1 dot products (integer
    valued; the carrier dtype may differ per backend — callers cast).
    """

    name: str
    info: EngineInfo
    spec: CrossbarSpec

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array: ...

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array: ...

    def steps_for(self, m: int, n: int, n_inputs: int) -> int: ...

    def preferred_group_size(self) -> int: ...


class _EngineBase:
    """Shared plumbing: spec binding, MMM-via-VMM fallback, repr."""

    info: EngineInfo

    def __init__(self, spec: CrossbarSpec | None = None):
        default = OPCM_TILE if self.info.default_spec == "oPCM" else EPCM_TILE
        self.spec = spec or default

    @property
    def name(self) -> str:
        return self.info.name

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array:
        """(G, K, m) x (m, n) -> (G, K, n); default: flatten to a VMM."""
        g, k, m = groups.shape
        out = self.binary_vmm(groups.reshape(g * k, m), w_signs)
        return out.reshape(g, k, -1)

    def preferred_group_size(self) -> int:
        """K-vectors the substrate executes per hardware step.

        1 for every non-``native_mmm`` backend: grouping still works
        (``binary_mmm`` flattens), but each vector in the group costs a
        sequential step — the serving engine treats these as a vmap'd
        group and picks its own K.
        """
        return 1

    def with_spec(self, spec: CrossbarSpec) -> "Engine":
        """Same backend rebound to another tile spec (subclasses with
        extra constructor state override to preserve it)."""
        return type(self)(spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """Sequential hardware steps for ``n_inputs`` vectors (cost model)."""
        del m, n
        return n_inputs

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Engine {self.name} spec={self.spec.technology}>"


class ReferenceEngine(_EngineBase):
    """Eq. 1 in plain jnp — the ground truth every backend must match."""

    info = EngineInfo(
        name="reference",
        description="plain jnp ±1 matmul (Eq. 1 ground truth)",
        hardware="any (XLA)",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        return bnn.binary_matmul_signs(a_signs, w_signs)


class TacitMapEngine(_EngineBase):
    """The paper's mapping run through the full tiled-crossbar simulator."""

    info = EngineInfo(
        name="tacitmap",
        description="tiled crossbar functional simulator (complement VMM)",
        hardware="ePCM/oPCM crossbar tiles + ADC readout",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        return tacitmap.binary_matmul(a_signs, w_signs, self.spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return tacitmap.steps_for(m, n, n_inputs, self.spec)


class WDMEngine(_EngineBase):
    """EinsteinBarrier: oPCM crossbar + K-wavelength MMM steps."""

    info = EngineInfo(
        name="wdm",
        description="oPCM + WDM: K input vectors per crossbar step (MMM)",
        hardware="oPCM photonic crossbar, K-wavelength transmitter",
        native_mmm=True,
        default_spec="oPCM",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        m = a_signs.shape[-1]
        mapped = tacitmap.map_weights(
            bnn.signs_to_bits(w_signs).astype(jnp.int32), self.spec
        )
        flat = a_signs.reshape(-1, m)
        pc = wdm.wdm_apply(mapped, bnn.signs_to_bits(flat))
        return (2 * pc - m).reshape(*a_signs.shape[:-1], -1)

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array:
        m = groups.shape[-1]
        mapped = tacitmap.map_weights(
            bnn.signs_to_bits(w_signs).astype(jnp.int32), self.spec
        )
        pc = wdm.mmm(mapped, bnn.signs_to_bits(groups))
        return 2 * pc - m

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        del m, n
        return wdm.steps_for(n_inputs, self.spec.wdm_k)

    def preferred_group_size(self) -> int:
        """The wavelength count: K input vectors ride one crossbar step."""
        return self.spec.wdm_k


class PackedEngine(_EngineBase):
    """Bit-packed XNOR+popcount Pallas kernel — the TPU-native crossbar step.

    32 binary weights/activations per int32 lane, XOR + population_count
    on the VPU (kernels/xnor_matmul.py). On CPU the kernel runs in
    Pallas interpret mode automatically (``interpret=None``), so the
    backend is testable everywhere; on TPU it compiles.
    """

    info = EngineInfo(
        name="packed",
        description="bit-packed XNOR+popcount Pallas kernel (Eq. 1 affine)",
        hardware="TPU VPU (interpret-mode on CPU)",
        packed=True,
    )

    def __init__(self, spec: CrossbarSpec | None = None, *, interpret: bool | None = None):
        super().__init__(spec)
        self.interpret = interpret

    def with_spec(self, spec: CrossbarSpec) -> "PackedEngine":
        return type(self)(spec, interpret=self.interpret)

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        from repro.kernels import ops

        return ops.xnor_matmul(a_signs, w_signs, interpret=self.interpret)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        # one fused kernel launch executes the whole (B, m, n) matmul
        del m, n, n_inputs
        return 1


class CustBinaryMapEngine(_EngineBase):
    """The SotA baseline mapping [15]: one weight vector per step (PCSA)."""

    info = EngineInfo(
        name="custbinarymap",
        description="2T2R row-serial baseline (PCSA readout, digital popcount)",
        hardware="ePCM 2T2R arrays + precharge sense amplifiers",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        return custbinarymap.binary_matmul(a_signs, w_signs, self.spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return custbinarymap.steps_for(m, n, n_inputs, self.spec)


# ---------------------------------------------------------------------------
# K-group batching adapter (WDM-style MMM execution of any backend)
# ---------------------------------------------------------------------------


class GroupedEngine:
    """Execute a backend's VMMs as K-grouped ``binary_mmm`` calls.

    This is the serving engine's unit-of-work change: a batch of B
    input vectors becomes G = ceil(B / K) stacked K-groups and issues
    ONE ``binary_mmm`` registry call (stacked activations, shared
    binarized weights) instead of B vector calls. Ragged tails are
    padded with +1 signs — idle comb lines in WDM hardware — and the
    pad outputs are discarded, so the adapter is bit-exact for any B.

    For ``native_mmm`` backends (WDM) each K-group is one crossbar
    step; for the rest the group flattens back to a VMM (a vmap'd
    group), so the adapter composes with every registered engine.
    """

    def __init__(self, base: Engine, k: int):
        if k < 1:
            raise ValueError(f"group size must be >= 1, got {k}")
        self.base = base
        self.k = int(k)
        self.info = base.info
        self.spec = base.spec

    @property
    def name(self) -> str:
        return f"{self.base.name}@k{self.k}"

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        m = a_signs.shape[-1]
        flat = a_signs.reshape(-1, m)
        b = flat.shape[0]
        g = max(1, math.ceil(b / self.k))
        pad = g * self.k - b
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.ones((pad, m), flat.dtype)], axis=0
            )
        out = self.base.binary_mmm(flat.reshape(g, self.k, m), w_signs)
        out = out.reshape(g * self.k, -1)[:b]
        return out.reshape(*a_signs.shape[:-1], -1)

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array:
        return self.base.binary_mmm(groups, w_signs)

    def with_spec(self, spec: CrossbarSpec) -> "GroupedEngine":
        return GroupedEngine(resolve(self.base, spec), self.k)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """ceil(B / K) group launches, each costing the base engine a
        K-vector step (1 for native MMM, K sequential otherwise)."""
        groups = math.ceil(n_inputs / self.k)
        return groups * self.base.steps_for(m, n, self.k)

    def preferred_group_size(self) -> int:
        return self.k

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<GroupedEngine {self.name}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Engine]] = {}


def register_engine(name: str, factory: Callable[..., Engine]) -> None:
    """Register a backend factory: ``factory(spec=None, **kw) -> Engine``.

    Re-registration under an existing name replaces the factory (useful
    for tests and for swapping in tuned variants).
    """
    _REGISTRY[name] = factory


def list_engines() -> list[str]:
    """Sorted names of every registered backend."""
    return sorted(_REGISTRY)


def get_engine(name: str, spec: CrossbarSpec | None = None, **kw) -> Engine:
    """Instantiate a registered backend, optionally binding a tile spec."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {', '.join(list_engines())}"
        ) from None
    return factory(spec, **kw)


def resolve(engine: str | Engine, spec: CrossbarSpec | None = None) -> Engine:
    """Accept an engine name or an already-constructed Engine instance."""
    if isinstance(engine, str):
        return get_engine(engine, spec)
    if spec is not None and engine.spec is not spec:
        if hasattr(engine, "with_spec"):  # preserves extra ctor state
            return engine.with_spec(spec)
        return get_engine(engine.name, spec)
    return engine


def engine_info(name: str) -> EngineInfo:
    """Capability metadata without instantiating arrays/specs."""
    return get_engine(name).info


def resolve_group_size(engine: Engine | None, requested: int | None, batch: int) -> int:
    """The K-group sizing policy shared by the serving engine and CLIs.

    Explicit request (> 0) wins; else ``native_mmm`` engines contribute
    their ``preferred_group_size()`` (WDM's wavelength count); else one
    vmap'd group spans the batch. Always clamped to [1, batch].
    """
    if requested:
        k = requested
    elif engine is not None and engine.info.native_mmm:
        k = engine.preferred_group_size()
    else:
        k = batch
    return max(1, min(int(k), batch))


for _cls in (
    ReferenceEngine,
    TacitMapEngine,
    WDMEngine,
    PackedEngine,
    CustBinaryMapEngine,
):
    register_engine(_cls.info.name, _cls)
