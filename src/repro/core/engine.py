"""Pluggable execution-engine registry — the seam every backend plugs into.

The paper's central claim is that TacitMap / EinsteinBarrier "simply
accelerate" BNN inference: every execution path computes the *same*
XNOR+popcount contract (Eq. 1) and is therefore bit-exact and swappable.
PIMBALL (arXiv:1812.03989) and the optical XNOR-bitcount accelerator
(arXiv:2302.06405) frame that identity as the common contract across
hardware backends; this module encodes exactly that contract in
software.

An :class:`Engine` executes ±1 binary matmuls::

    binary_vmm(a_signs, w_signs)   # (..., m) x (m, n) -> (..., n)
    binary_mmm(groups, w_signs)    # (G, K, m) x (m, n) -> (G, K, n)

and exposes capability/cost metadata (``info``, ``steps_for``,
``preferred_group_size``) that the analytical cost model, the serving
engine's :class:`~repro.serving.engine.BatchPlanner` and the benchmark
sweeps consume uniformly.

``binary_mmm`` is the batching contract: one call executes G stacked
K-groups against shared binarized weights. Engines with
``info.native_mmm`` (WDM) execute each K-group as ONE hardware step —
``preferred_group_size()`` reports the K the substrate natively
multiplexes (the wavelength count); every other backend reports 1 and
serves ``binary_mmm`` through the flattened-VMM fallback (a "vmap'd
group"), so consumers can group unconditionally.

Capability matrix of the registered backends:

====================  =======================================  ==========
name                  models                                   native MMM
====================  =======================================  ==========
``reference``         Eq. 1 in plain jnp (ground truth)        no
``tacitmap``          tiled ePCM/oPCM crossbar simulator       no
``wdm``               oPCM + K-wavelength WDM (EinsteinBarrier) yes (K)
``packed``            TPU bit-packed XNOR+popcount Pallas       no
``tiled``             mapping-plan sharded tile execution       no
``custbinarymap``     2T2R/PCSA row-serial baseline [15]        no
====================  =======================================  ==========

All are bit-exact against ``reference`` (tests/test_engines.py). The
``packed`` backend is the TPU-native analogue of the crossbar step —
32 weights per int32 lane, XOR + population_count on the VPU — and runs
in Pallas interpret mode on CPU so it is testable everywhere.

Consumers resolve engines by name (CLI flags, configs) or pass
:class:`Engine` instances directly::

    eng = get_engine("packed")
    out = eng.binary_vmm(a_signs, w_signs)

New backends (multi-level cells, sharded crossbars, GPU) register with
:func:`register_engine` and become available to models, serving and
benchmarks without touching any consumer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import bnn, custbinarymap, tacitmap, wdm
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineInfo:
    """Capability/cost metadata for one backend (the capability matrix)."""

    name: str
    description: str
    hardware: str                 # what physical substrate this models
    native_mmm: bool = False      # executes K input vectors per step (WDM)
    packed: bool = False          # bit-packed operands (1 bit / lane)
    default_spec: str = "ePCM"    # which tile catalogue entry it defaults to

    @property
    def bit_exact(self) -> bool:
        """Every registered engine must reproduce Eq. 1 exactly."""
        return True


@runtime_checkable
class Engine(Protocol):
    """The execution contract every backend implements.

    ``binary_vmm``/``binary_mmm`` consume ±1-valued arrays (any float or
    integer carrier) and return the exact ±1 dot products (integer
    valued; the carrier dtype may differ per backend — callers cast).
    """

    name: str
    info: EngineInfo
    spec: CrossbarSpec

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array: ...

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array: ...

    def steps_for(self, m: int, n: int, n_inputs: int) -> int: ...

    def preferred_group_size(self) -> int: ...


class _EngineBase:
    """Shared plumbing: spec binding, MMM-via-VMM fallback, repr."""

    info: EngineInfo

    def __init__(self, spec: CrossbarSpec | None = None):
        default = OPCM_TILE if self.info.default_spec == "oPCM" else EPCM_TILE
        self.spec = spec or default

    @property
    def name(self) -> str:
        return self.info.name

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array:
        """(G, K, m) x (m, n) -> (G, K, n); default: flatten to a VMM."""
        g, k, m = groups.shape
        out = self.binary_vmm(groups.reshape(g * k, m), w_signs)
        return out.reshape(g, k, -1)

    def preferred_group_size(self) -> int:
        """K-vectors the substrate executes per hardware step.

        1 for every non-``native_mmm`` backend: grouping still works
        (``binary_mmm`` flattens), but each vector in the group costs a
        sequential step — the serving engine treats these as a vmap'd
        group and picks its own K.
        """
        return 1

    def with_spec(self, spec: CrossbarSpec) -> "Engine":
        """Same backend rebound to another tile spec (subclasses with
        extra constructor state override to preserve it)."""
        return type(self)(spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """Sequential hardware steps for ``n_inputs`` vectors (cost model)."""
        del m, n
        return n_inputs

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Engine {self.name} spec={self.spec.technology}>"


class ReferenceEngine(_EngineBase):
    """Eq. 1 in plain jnp — the ground truth every backend must match."""

    info = EngineInfo(
        name="reference",
        description="plain jnp ±1 matmul (Eq. 1 ground truth)",
        hardware="any (XLA)",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        return bnn.binary_matmul_signs(a_signs, w_signs)


class TacitMapEngine(_EngineBase):
    """The paper's mapping run through the full tiled-crossbar simulator."""

    info = EngineInfo(
        name="tacitmap",
        description="tiled crossbar functional simulator (complement VMM)",
        hardware="ePCM/oPCM crossbar tiles + ADC readout",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        return tacitmap.binary_matmul(a_signs, w_signs, self.spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return tacitmap.steps_for(m, n, n_inputs, self.spec)


class WDMEngine(_EngineBase):
    """EinsteinBarrier: oPCM crossbar + K-wavelength MMM steps."""

    info = EngineInfo(
        name="wdm",
        description="oPCM + WDM: K input vectors per crossbar step (MMM)",
        hardware="oPCM photonic crossbar, K-wavelength transmitter",
        native_mmm=True,
        default_spec="oPCM",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        m = a_signs.shape[-1]
        mapped = tacitmap.map_weights(
            bnn.signs_to_bits(w_signs).astype(jnp.int32), self.spec
        )
        flat = a_signs.reshape(-1, m)
        pc = wdm.wdm_apply(mapped, bnn.signs_to_bits(flat))
        return (2 * pc - m).reshape(*a_signs.shape[:-1], -1)

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array:
        m = groups.shape[-1]
        mapped = tacitmap.map_weights(
            bnn.signs_to_bits(w_signs).astype(jnp.int32), self.spec
        )
        pc = wdm.mmm(mapped, bnn.signs_to_bits(groups))
        return 2 * pc - m

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        del m, n
        return wdm.steps_for(n_inputs, self.spec.wdm_k)

    def preferred_group_size(self) -> int:
        """The wavelength count: K input vectors ride one crossbar step."""
        return self.spec.wdm_k


class PackedEngine(_EngineBase):
    """Bit-packed XNOR+popcount Pallas kernel — the TPU-native crossbar step.

    32 binary weights/activations per int32 lane, XOR + population_count
    on the VPU (kernels/xnor_matmul.py). On CPU the kernel runs in
    Pallas interpret mode automatically (``interpret=None``), so the
    backend is testable everywhere; on TPU it compiles.
    """

    info = EngineInfo(
        name="packed",
        description="bit-packed XNOR+popcount Pallas kernel (Eq. 1 affine)",
        hardware="TPU VPU (interpret-mode on CPU)",
        packed=True,
    )

    def __init__(self, spec: CrossbarSpec | None = None, *, interpret: bool | None = None):
        super().__init__(spec)
        self.interpret = interpret

    def with_spec(self, spec: CrossbarSpec) -> "PackedEngine":
        return type(self)(spec, interpret=self.interpret)

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        from repro.kernels import ops

        return ops.xnor_matmul(a_signs, w_signs, interpret=self.interpret)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        # one fused kernel launch executes the whole (B, m, n) matmul
        del m, n, n_inputs
        return 1


class TiledEngine(_EngineBase):
    """Mapping-plan-driven sharded tile execution.

    Where ``tacitmap`` simulates the whole tiled array in one einsum,
    this backend executes the *compiled placement*: operands are sliced
    into exactly the ``spec.rows x spec.cols`` blocks a
    :class:`repro.mapping.allocator.MappingPlan` placed, the per-block
    partial popcounts run as ONE vmap over the tile axis (in the plan's
    block order), and digital partial-sum accumulation scatters them
    back per output column group. Bit-exact vs ``reference`` for every
    allocator policy — placement permutes tile order, never the math.

    The tile axis is the sharding axis: under an active
    ``activation_hints`` mesh the stacked tiles and their partials are
    constrained to the ``model`` axis, so a multi-device run splits the
    plan's tile pool across devices (the ROADMAP's "sharded-crossbar
    tiles" backend).

    Construction: ``get_engine("tiled", plan=plan)`` executes per a
    compiled plan (and inherits its tile spec); without a plan, each
    distinct (m, n) weight shape is placed on the fly under ``policy``
    and cached on the engine instance.
    """

    info = EngineInfo(
        name="tiled",
        description="plan-driven sharded tile execution (complement blocks, vmap over tiles)",
        hardware="ePCM/oPCM crossbar tile pool; tile axis shards over a jax mesh",
    )

    def __init__(self, spec: CrossbarSpec | None = None, *, plan=None, policy: str = "tacitmap"):
        if plan is not None and spec is None:
            spec = plan.spec
        super().__init__(spec)
        if plan is not None and plan.spec != self.spec:
            raise ValueError(
                f"plan was compiled for {plan.spec.technology} "
                f"{plan.spec.rows}x{plan.spec.cols} tiles but the engine is "
                f"bound to {self.spec.technology} {self.spec.rows}x{self.spec.cols}"
            )
        self.plan = plan
        self.policy = policy
        self._adhoc_cache: dict[tuple[int, int], object] = {}

    def with_spec(self, spec: CrossbarSpec) -> "TiledEngine":
        keep = self.plan if (self.plan is not None and self.plan.spec == spec) else None
        return type(self)(spec, plan=keep, policy=self.policy)

    def _placement(self, m: int, n: int):
        """The plan's LayerPlan for a (m, n) matrix, or an on-the-fly
        single-layer placement under this engine's policy (cached)."""
        if self.plan is not None:
            lp = self.plan.layer_for(m, n)
            if lp is not None:
                return lp
        lp = self._adhoc_cache.get((m, n))
        if lp is None:
            from repro.mapping import allocator, ir  # lazy: mapping imports costmodel

            lp = allocator.allocate(
                ir.adhoc_layer(m, n), spec=self.spec, policy=self.policy
            ).layers[0]
            self._adhoc_cache[(m, n)] = lp
        return lp

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        import numpy as np

        from repro.core.crossbar import adc_quantize
        from repro.distributed.hints import hint

        m, n = w_signs.shape
        lp = self._placement(m, n)
        spec, grid = self.spec, lp.grid
        R, C = spec.rows, spec.cols
        RT, CT = grid.row_tiles, grid.col_tiles

        order = lp.block_order()
        block_ids = np.asarray([rb * CT + cb for rb, cb in order], np.int32)
        row_ids = np.asarray([rb for rb, _ in order], np.int32)
        col_ids = np.asarray([cb for _, cb in order], np.int32)

        # weights: complement-stack, pad to the tile grid, gather the
        # blocks in the PLAN'S placement order (the policy's layout)
        stacked = bnn.stack_complement_weights(bnn.signs_to_bits(w_signs))
        padded = jnp.pad(stacked, ((0, RT * R - 2 * m), (0, CT * C - n)))
        blocks = padded.reshape(RT, R, CT, C).transpose(0, 2, 1, 3).reshape(RT * CT, R, C)
        tiles = jnp.take(blocks, block_ids, axis=0).astype(jnp.float32)
        tiles = hint(tiles, "model")  # shard the tile axis when a mesh is active

        # inputs: complement drive, cut into the row blocks each tile sees
        drive = bnn.concat_complement_input(bnn.signs_to_bits(a_signs))
        drive = jnp.pad(drive, [(0, 0)] * (drive.ndim - 1) + [(0, RT * R - 2 * m)])
        drive = drive.reshape(*drive.shape[:-1], RT, R)
        drive_t = jnp.moveaxis(jnp.take(drive, row_ids, axis=-2), -2, 0)  # (T, ..., R)

        def one_tile(tile: Array, drv: Array) -> Array:
            # one crossbar activation: analog MAC + that tile's ADC
            pc = jnp.einsum("...r,rc->...c", drv.astype(jnp.float32), tile)
            return adc_quantize(pc, spec, active_rows=R)

        partial = jax.vmap(one_tile)(tiles, drive_t)  # (T, ..., C)
        partial = hint(partial, "model")
        # digital partial-sum accumulation: row-block partials of each
        # output column group add up, in whatever order the plan placed them
        summed = jax.ops.segment_sum(partial, jnp.asarray(col_ids), num_segments=CT)
        out = jnp.moveaxis(summed, 0, -2)  # (..., CT, C)
        pc = out.reshape(*out.shape[:-2], CT * C)[..., :n]
        return 2 * pc - m

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """WDM-grouped stream x the plan's per-vector serialization (a
        tile co-hosting j blocks of one layer fires j times)."""
        lp = self._placement(m, n)
        groups = math.ceil(n_inputs / max(1, self.spec.wdm_k))
        return groups * lp.steps_per_vector

    def preferred_group_size(self) -> int:
        """The plan's WDM capacity (== spec.wdm_k for the bound tiles)."""
        if self.plan is not None:
            return self.plan.preferred_group_size()
        return self.spec.wdm_k

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        planned = self.plan.model.name if self.plan is not None else f"adhoc/{self.policy}"
        return f"<Engine tiled spec={self.spec.technology} plan={planned}>"


class CustBinaryMapEngine(_EngineBase):
    """The SotA baseline mapping [15]: one weight vector per step (PCSA)."""

    info = EngineInfo(
        name="custbinarymap",
        description="2T2R row-serial baseline (PCSA readout, digital popcount)",
        hardware="ePCM 2T2R arrays + precharge sense amplifiers",
    )

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        return custbinarymap.binary_matmul(a_signs, w_signs, self.spec)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        return custbinarymap.steps_for(m, n, n_inputs, self.spec)


# ---------------------------------------------------------------------------
# K-group batching adapter (WDM-style MMM execution of any backend)
# ---------------------------------------------------------------------------


class GroupedEngine:
    """Execute a backend's VMMs as K-grouped ``binary_mmm`` calls.

    This is the serving engine's unit-of-work change: a batch of B
    input vectors becomes G = ceil(B / K) stacked K-groups and issues
    ONE ``binary_mmm`` registry call (stacked activations, shared
    binarized weights) instead of B vector calls. Ragged tails are
    padded with +1 signs — idle comb lines in WDM hardware — and the
    pad outputs are discarded, so the adapter is bit-exact for any B.

    For ``native_mmm`` backends (WDM) each K-group is one crossbar
    step; for the rest the group flattens back to a VMM (a vmap'd
    group), so the adapter composes with every registered engine.
    """

    def __init__(self, base: Engine, k: int):
        if k < 1:
            raise ValueError(f"group size must be >= 1, got {k}")
        self.base = base
        self.k = int(k)
        self.info = base.info
        self.spec = base.spec

    @property
    def name(self) -> str:
        return f"{self.base.name}@k{self.k}"

    def binary_vmm(self, a_signs: Array, w_signs: Array) -> Array:
        m = a_signs.shape[-1]
        flat = a_signs.reshape(-1, m)
        b = flat.shape[0]
        g = max(1, math.ceil(b / self.k))
        pad = g * self.k - b
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.ones((pad, m), flat.dtype)], axis=0
            )
        out = self.base.binary_mmm(flat.reshape(g, self.k, m), w_signs)
        out = out.reshape(g * self.k, -1)[:b]
        return out.reshape(*a_signs.shape[:-1], -1)

    def binary_mmm(self, groups: Array, w_signs: Array) -> Array:
        return self.base.binary_mmm(groups, w_signs)

    def with_spec(self, spec: CrossbarSpec) -> "GroupedEngine":
        return GroupedEngine(resolve(self.base, spec), self.k)

    def steps_for(self, m: int, n: int, n_inputs: int) -> int:
        """ceil(B / K) group launches, each costing the base engine a
        K-vector step (1 for native MMM, K sequential otherwise)."""
        groups = math.ceil(n_inputs / self.k)
        return groups * self.base.steps_for(m, n, self.k)

    def preferred_group_size(self) -> int:
        return self.k

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<GroupedEngine {self.name}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Engine]] = {}


def register_engine(name: str, factory: Callable[..., Engine]) -> None:
    """Register a backend factory: ``factory(spec=None, **kw) -> Engine``.

    Re-registration under an existing name replaces the factory (useful
    for tests and for swapping in tuned variants).
    """
    _REGISTRY[name] = factory


def list_engines() -> list[str]:
    """Sorted names of every registered backend."""
    return sorted(_REGISTRY)


def get_engine(name: str, spec: CrossbarSpec | None = None, **kw) -> Engine:
    """Instantiate a registered backend, optionally binding a tile spec."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {', '.join(list_engines())}"
        ) from None
    return factory(spec, **kw)


def resolve(engine: str | Engine, spec: CrossbarSpec | None = None) -> Engine:
    """Accept an engine name or an already-constructed Engine instance."""
    if isinstance(engine, str):
        return get_engine(engine, spec)
    if spec is not None and engine.spec is not spec:
        if hasattr(engine, "with_spec"):  # preserves extra ctor state
            return engine.with_spec(spec)
        return get_engine(engine.name, spec)
    return engine


def engine_info(name: str) -> EngineInfo:
    """Capability metadata without instantiating arrays/specs."""
    return get_engine(name).info


def resolve_group_size(
    engine: Engine | None, requested: int | None, batch: int, plan=None
) -> int:
    """The K-group sizing policy shared by the serving engine and CLIs.

    Explicit request (> 0) wins; else a compiled mapping plan
    contributes its WDM capacity (``plan.preferred_group_size()`` — the
    static mapping artifact knows the placed tile technology even when
    the executing backend has no native MMM); else any engine whose
    ``preferred_group_size()`` exceeds 1 contributes it (WDM's
    wavelength count, a plan-bound tiled engine's tile K); else one
    vmap'd group spans the batch. Always clamped to [1, batch].
    """
    if requested:
        k = requested
    elif plan is not None and plan.preferred_group_size() > 1:
        k = plan.preferred_group_size()
    elif engine is not None and engine.preferred_group_size() > 1:
        k = engine.preferred_group_size()
    else:
        k = batch
    return max(1, min(int(k), batch))


for _cls in (
    ReferenceEngine,
    TacitMapEngine,
    WDMEngine,
    PackedEngine,
    TiledEngine,
    CustBinaryMapEngine,
):
    register_engine(_cls.info.name, _cls)
