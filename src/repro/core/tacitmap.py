"""TacitMap: the paper's data mapping, as a functional tiled-crossbar simulator.

Given a binary weight matrix ``W`` (m, n) in {0,1}:

1. stack the complement below it -> (2m, n)   (Fig. 2-(b))
2. cut into crossbar tiles of ``spec.rows x spec.cols``
3. for an input bit-vector ``a`` (m,): drive ``[a ; ā]`` onto the rows;
   every tile performs one analog MAC per column; per-tile column sums
   pass through that tile's ADC; row-tile partials are summed digitally.

The result is ``popcount(XNOR(a, w_j))`` for every stored column ``j`` in
ONE step — the mapping's whole point. This module is bit-exact against
``bnn.tacitmap_vmm`` when the ADC is sized losslessly (the default), and
exposes step/energy counters the cost model consumes.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import bnn
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, TileGrid, adc_quantize, readout_noise

Array = jax.Array


@dataclasses.dataclass
class MappedLayer:
    """A binary weight matrix mapped onto a tiled crossbar array.

    ``tiles`` has shape (row_tiles, R, col_tiles, C): the physical cell
    states ({0,1} conductances / PCM phases), zero-padded outside the
    logical (2m, n) region. Padding cells contribute 0 to column sums by
    construction (input pad bits are driven as 0), so no masking is
    needed at readout.
    """

    tiles: Array
    m: int  # logical vector length (rows used = 2m)
    n: int  # stored weight vectors (columns used)
    spec: CrossbarSpec
    grid: TileGrid

    @property
    def steps_per_input(self) -> int:
        """Sequential crossbar steps per input vector: 1 (all tiles parallel)."""
        return 1


def layer_from_cells(
    cells: Array, m: int, n: int, spec: CrossbarSpec = EPCM_TILE
) -> MappedLayer:
    """Lay programmed complement cell states (2m, n) onto the tile grid.

    The single source of truth for the pad/reshape layout — used both by
    :func:`map_weights` (raw path) and the prepared-weights execute path
    (``repro.core.engine``), so the two can never drift apart.
    """
    grid = TileGrid(rows=2 * m, cols=n, spec=spec)
    R, C = spec.rows, spec.cols
    pad_r = grid.row_tiles * R - 2 * m
    pad_c = grid.col_tiles * C - n
    padded = jnp.pad(cells, ((0, pad_r), (0, pad_c)))
    tiles = padded.reshape(grid.row_tiles, R, grid.col_tiles, C)
    return MappedLayer(tiles=tiles, m=m, n=n, spec=spec, grid=grid)


def map_weights(w_bits: Array, spec: CrossbarSpec = EPCM_TILE) -> MappedLayer:
    """Map a {0,1} weight matrix (m, n) onto crossbar tiles, TacitMap-style."""
    m, n = w_bits.shape
    return layer_from_cells(bnn.stack_complement_weights(w_bits), m, n, spec)


def apply(
    layer: MappedLayer,
    a_bits: Array,
    *,
    noise_sigma: float = 0.0,
    key: jax.Array | None = None,
) -> Array:
    """Drive input bit-vectors through the mapped crossbar.

    ``a_bits``: (..., m) in {0,1}. Returns popcount(XNOR) of shape
    (..., n). Every input vector costs ONE crossbar step; the batch
    dimension models sequential steps (ePCM) or WDM wavelengths (oPCM —
    see ``wdm.py`` for the grouping that decides which).
    """
    if a_bits.shape[-1] != layer.m:
        raise ValueError(f"input length {a_bits.shape[-1]} != mapped m={layer.m}")
    spec = layer.spec
    R = spec.rows
    drive = bnn.concat_complement_input(a_bits)  # (..., 2m)
    pad = layer.grid.row_tiles * R - drive.shape[-1]
    drive = jnp.pad(drive, [(0, 0)] * (drive.ndim - 1) + [(0, pad)])
    drive = drive.reshape(*drive.shape[:-1], layer.grid.row_tiles, R)
    # analog MAC: per row-tile partial column sums ("...rm" x "rmcn")
    partial = jnp.einsum(
        "...rm,rmcn->...rcn", drive.astype(jnp.float32), layer.tiles.astype(jnp.float32)
    )
    # each tile's columns go through that tile's ADC (active rows = R)
    partial = adc_quantize(partial, spec, active_rows=R)
    partial = readout_noise(partial, noise_sigma, key)
    # digital partial-sum accumulation across row tiles
    out = partial.sum(axis=-3)  # (..., col_tiles, C)
    out = out.reshape(*out.shape[:-2], layer.grid.col_tiles * spec.cols)
    return out[..., : layer.n]


def binary_matmul(
    a_signs: Array, w_signs: Array, spec: CrossbarSpec = EPCM_TILE, **kw
) -> Array:
    """±1 binary matmul executed through the full crossbar simulation."""
    m = a_signs.shape[-1]
    mapped = map_weights(bnn.signs_to_bits(w_signs).astype(jnp.int32), spec)
    pc = apply(mapped, bnn.signs_to_bits(a_signs), **kw)
    return 2 * pc - m


def steps_for(m: int, n: int, n_inputs: int, spec: CrossbarSpec = EPCM_TILE) -> int:
    """Sequential VMM steps TacitMap needs for ``n_inputs`` vectors.

    All row/col tiles fire in parallel (spatial architecture, digital
    partial-sum adders), so the count is just the input count — compare
    ``custbinarymap.steps_for``.
    """
    del m, n, spec
    return n_inputs
