"""Binary-neural-network arithmetic: Eq. 1 of the paper, binarization, STE.

The paper's Eq. 1 (for equally-sized binary vectors)::

    In (*) W = 2 * Popcount(In' XNOR W') - VectorLength

where ``In', W'`` are the {0,1} encodings of the ±1 vectors ``In, W``.
Everything in this module is pure jnp and differentiable where it needs
to be (straight-through estimators for training).

Conventions
-----------
* ``bits``    — arrays with values in {0, 1} (any integer/float dtype).
* ``signs``   — arrays with values in {-1, +1}.
* ``latent``  — real-valued master weights (training time).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Encodings
# ---------------------------------------------------------------------------


def signs_to_bits(x: Array) -> Array:
    """Map {-1,+1} -> {0,1} (``-1 -> 0``, ``+1 -> 1``)."""
    return ((x + 1) // 2).astype(x.dtype) if jnp.issubdtype(x.dtype, jnp.integer) else (x + 1.0) * 0.5


def bits_to_signs(b: Array) -> Array:
    """Map {0,1} -> {-1,+1}."""
    return 2 * b - 1


def binarize_ste(x: Array) -> Array:
    """Sign-binarize with a straight-through estimator.

    Forward: ``sign(x)`` in {-1, +1} (zero maps to +1).
    Backward: identity within the clip region |x| <= 1 (hard-tanh STE,
    the standard BNN estimator from Courbariaux et al.).
    """
    binary = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    # straight-through: forward uses `binary`, gradient flows through the
    # clipped identity.
    clipped = jnp.clip(x, -1.0, 1.0)
    return clipped + jax.lax.stop_gradient(binary - clipped)


def binarize_ste_bits(x: Array) -> Array:
    """STE binarization straight to the {0,1} encoding."""
    return signs_to_bits(binarize_ste(x))


# ---------------------------------------------------------------------------
# Eq. 1: XNOR + Popcount
# ---------------------------------------------------------------------------


def xnor(a_bits: Array, w_bits: Array) -> Array:
    """Element-wise XNOR on {0,1} arrays (dtype-preserving, no bitwise ops
    so it also works on float carriers)."""
    return 1 - (a_bits + w_bits - 2 * a_bits * w_bits)


def popcount(bits: Array, axis: int = -1) -> Array:
    """Population count (number of set bits) along ``axis``."""
    return jnp.sum(bits, axis=axis)


def xnor_popcount(a_bits: Array, w_bits: Array) -> Array:
    """``popcount(xnor(a, w))`` along the last axis — the BNN MAC."""
    return popcount(xnor(a_bits, w_bits))


def binary_dot_eq1(a_bits: Array, w_bits: Array) -> Array:
    """Eq. 1: the ±1-domain dot product recovered from XNOR+popcount."""
    m = a_bits.shape[-1]
    return 2 * xnor_popcount(a_bits, w_bits) - m


def binary_matmul_signs(a_signs: Array, w_signs: Array) -> Array:
    """Reference ±1 binary matmul: ``a @ w`` for sign-valued arrays.

    ``a_signs``: (..., m), ``w_signs``: (m, n) -> (..., n).
    This is the ground truth every mapping/kernel must reproduce.
    """
    return jnp.matmul(a_signs, w_signs)


# ---------------------------------------------------------------------------
# The TacitMap algebraic core: complement concatenation
# ---------------------------------------------------------------------------


def concat_complement_input(a_bits: Array) -> Array:
    """TacitMap input prep: ``[a ; ā]`` along the last axis (length 2m)."""
    return jnp.concatenate([a_bits, 1 - a_bits], axis=-1)


def stack_complement_weights(w_bits: Array) -> Array:
    """TacitMap weight prep: ``[w ; w̄]`` stacked along the row axis.

    ``w_bits``: (m, n) -> (2m, n): weight column then its complement
    directly below it (Fig. 2-(b) of the paper).
    """
    return jnp.concatenate([w_bits, 1 - w_bits], axis=0)


def tacitmap_vmm(a_bits: Array, w_bits: Array) -> Array:
    """One-step XNOR+Popcount via a single VMM (the TacitMap identity).

    ``a_bits``: (..., m) in {0,1}; ``w_bits``: (m, n) in {0,1}.
    Returns popcount(XNOR) of shape (..., n), computed as
    ``[a ; ā] @ [w ; w̄]`` — exactly what the crossbar's analog MAC does.
    """
    return jnp.matmul(concat_complement_input(a_bits), stack_complement_weights(w_bits))


def tacitmap_binary_matmul(a_signs: Array, w_signs: Array) -> Array:
    """±1 binary matmul routed through the TacitMap VMM identity."""
    m = a_signs.shape[-1]
    pc = tacitmap_vmm(signs_to_bits(a_signs), signs_to_bits(w_signs))
    return 2 * pc - m
