"""Crossbar device + tile geometry models shared by the mappings.

Two kinds of objects live here:

* :class:`CrossbarSpec` — the geometry / peripheral configuration of one
  memristive (ePCM) or photonic (oPCM) crossbar tile, plus its timing
  and energy constants. All constants are documented with their source.
* :class:`TileGrid` — how a logical (rows x cols) weight matrix is cut
  into crossbar tiles, with the step/activation counters the cost model
  consumes.

The *functional* behaviour (what numbers come out) is implemented in
``tacitmap.py`` / ``custbinarymap.py``; this module is geometry+physics.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax
import jax.numpy as jnp

Array = jax.Array

Technology = Literal["ePCM", "oPCM"]


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    """One VMM-capable crossbar tile and its peripherals.

    Timing/energy constants and their provenance:

    * ``t_vmm_ns`` — one full VMM step (drive rows, settle, convert all
      columns through the shared ADC). 100 ns for ePCM follows
      ISAAC/PUMA (128-col readout through a 1.28 GS/s ADC ≈ 100 ns,
      scaled to 256 cols with 2 ADCs); oPCM uses 80 ns: photonic
      propagation is ~ps, so the readout remains ADC-limited but the
      *row drive + settle* phase collapses (Feldmann et al. report GHz
      photonic MACs; the deserializing TIA+ADC chain dominates).
    * ``t_row_read_ns`` — one PCSA differential row read (the
      CustBinaryMap primitive), 2T2R read-out at memory-array speed;
      10 ns per Hirtzlin et al.'s 1-transistor differential sensing.
    * ``e_adc_pj`` — energy per 8-bit ADC conversion (2 pJ, ISAAC ADC).
    * ``e_pcsa_fj`` — energy per PCSA sense (50 fJ, differential SA).
    * ``e_cell_read_fj`` — per-cell read energy (1 fJ ePCM, 0.1 fJ oPCM
      — photonic read is absorptive, no Joule heating).
    * ``p_tia_mw`` — TIA power per output column (Eq. 2: 2 mW).
    * ``wdm_k`` — WDM capacity (number of wavelengths, Eq. in §IV-A2;
      K = 16 for current technology, 1 for anything electronic).
    """

    rows: int = 256
    cols: int = 256
    technology: Technology = "ePCM"
    adc_bits: int = 9  # ceil(log2(256 rows)) + 1 — lossless popcount range
    n_adc: int = 2
    wdm_k: int = 1
    # timing (ns)
    t_vmm_ns: float = 100.0
    t_row_read_ns: float = 10.0
    t_write_ns: float = 100.0
    # energy / power
    e_adc_pj: float = 2.0
    e_pcsa_fj: float = 50.0
    e_cell_read_fj: float = 1.0
    p_tia_mw: float = 2.0
    p_laser_mw: float = 50.0

    def __post_init__(self):
        if self.technology == "ePCM" and self.wdm_k != 1:
            raise ValueError("WDM is a photonic feature; ePCM crossbars have K=1")

    # -- derived -----------------------------------------------------------
    @property
    def adc_levels(self) -> int:
        return 2**self.adc_bits

    def vmm_energy_pj(self, active_rows: int, active_cols: int, k: int = 1) -> float:
        """Energy of one VMM (or K-way MMM) step on this tile."""
        cell = active_rows * active_cols * k * self.e_cell_read_fj * 1e-3  # fJ->pJ
        conv = active_cols * k * self.e_adc_pj
        return cell + conv


# Catalogue of the tile configs used in the paper's evaluation ------------

EPCM_TILE = CrossbarSpec(technology="ePCM")

OPCM_TILE = CrossbarSpec(
    technology="oPCM",
    wdm_k=16,            # §IV-A2: current technology supports K=16
    t_vmm_ns=80.0,       # photonic row-drive collapses; ADC-limited readout
    e_cell_read_fj=0.1,  # absorptive photonic read
)


@dataclasses.dataclass(frozen=True)
class TileGrid:
    """A logical (rows x cols) binary matrix cut into crossbar tiles.

    ``rows`` is the *crossbar* row count required by the mapping (for
    TacitMap that is 2m: vector + complement), ``cols`` the number of
    stored weight vectors.
    """

    rows: int
    cols: int
    spec: CrossbarSpec

    @property
    def row_tiles(self) -> int:
        return max(1, math.ceil(self.rows / self.spec.rows))

    @property
    def col_tiles(self) -> int:
        return max(1, math.ceil(self.cols / self.spec.cols))

    @property
    def n_tiles(self) -> int:
        return self.row_tiles * self.col_tiles

    @property
    def n_devices(self) -> int:
        """Total memristor/oPCM cells provisioned (for area/fairness checks)."""
        return self.n_tiles * self.spec.rows * self.spec.cols


def adc_quantize(pc: Array, spec: CrossbarSpec, active_rows: int) -> Array:
    """Quantize an analog popcount through the tile ADC.

    With ``adc_bits >= ceil(log2(active_rows)) + 1`` this is exact (the
    popcount of up to ``rows`` cells is an integer < 2**adc_bits), which
    is how the paper sizes ADCs (lossless: the mapping does not affect
    accuracy). A smaller ADC introduces uniform quantization — exposed
    for design-space exploration.
    """
    if active_rows < spec.adc_levels:
        return pc  # exact integer range — bit-true readout
    scale = active_rows / (spec.adc_levels - 1)
    return jnp.round(pc / scale) * scale


def readout_noise(pc: Array, sigma: float, key: jax.Array | None) -> Array:
    """Optional additive Gaussian readout noise (σ in popcount LSBs).

    The paper's robustness argument (§II-C) is that binary PCM states
    are maximally separated, so realistic noise does not flip results;
    tests verify exactness for σ=0 and tolerance under small σ.
    """
    if key is None or sigma == 0.0:
        return pc
    return pc + sigma * jax.random.normal(key, pc.shape, dtype=jnp.float32)
