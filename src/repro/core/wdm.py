"""WDM (wavelength-division multiplexing) — EinsteinBarrier's extra axis.

K input vectors are encoded on K wavelengths by the transmitter (laser →
comb → DMUX → VOAs → MUX, Fig. 6) and driven through the SAME crossbar
in one step: a VMM becomes an MMM of size (K x 2m x n), Fig. 5-(b).

Functionally this is a batched `tacitmap.apply`; the value of this
module is the grouping/step accounting and the faithful "one step per
K-group" execution used by the serving engine and the cost model.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import tacitmap
from repro.core.crossbar import CrossbarSpec, OPCM_TILE
from repro.core.tacitmap import MappedLayer

Array = jax.Array


def group_inputs(a_bits: Array, k: int) -> tuple[Array, int]:
    """Pack a stream of input vectors (B, m) into WDM groups (G, k, m).

    Returns the padded groups and the number of *real* vectors B. The
    pad vectors are zeros — they ride unused wavelengths and their
    outputs are discarded, exactly like idle comb lines in hardware.
    """
    B, m = a_bits.shape
    g = math.ceil(B / k)
    pad = g * k - B
    padded = jnp.pad(a_bits, ((0, pad), (0, 0)))
    return padded.reshape(g, k, m), B


def mmm(layer: MappedLayer, groups: Array) -> Array:
    """Execute one MMM per WDM group: (G, k, m) -> (G, k, n).

    Each group is ONE crossbar step (all k wavelengths simultaneous).
    """
    return tacitmap.apply(layer, groups)


def wdm_apply(layer: MappedLayer, a_bits: Array, k: int | None = None) -> Array:
    """Full WDM pipeline: group -> MMM per group -> unpack. (B, m) -> (B, n)."""
    k = k or layer.spec.wdm_k
    groups, b = group_inputs(a_bits, k)
    out = mmm(layer, groups)
    return out.reshape(-1, out.shape[-1])[:b]


def steps_for(n_inputs: int, k: int) -> int:
    """Crossbar activations with WDM capacity k: ceil(B / k)."""
    return math.ceil(n_inputs / k)


def effective_speedup(n_inputs: int, k: int) -> float:
    """Achieved WDM parallelism (≤ k; < k when groups are ragged).

    The paper observes ~15x average for K=16 — raggedness plus
    non-WDM-able work is why the technology's K is not fully realized.
    """
    return n_inputs / steps_for(n_inputs, k)
