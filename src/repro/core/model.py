"""Executable BNN models (MLP / conv) with selectable execution engines.

Training uses latent real-valued master weights with STE binarization
(§II-B: "tracking the updates of parameters during training via higher
resolutions while keeping the actual weights binarized"); first and last
layers stay high-precision.

Inference runs each binary layer through any backend registered in
``repro.core.engine`` (reference / tacitmap / wdm / packed / ...) —
pass an engine name or an :class:`repro.core.engine.Engine` instance.
All backends are bit-exact (tests assert it) — the paper's point that
the mapping "simply accelerates" BNNs without touching accuracy.

Convolutions are expressed as im2col + VMM, which is literally how the
crossbar executes them (one im2col position = one input vector).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import bnn
from repro.core import engine as engine_lib
from repro.core.crossbar import CrossbarSpec
from repro.core.engine import Engine

Array = jax.Array

EngineLike = str | Engine  # registry name or constructed backend


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    dims: tuple[int, ...] = (784, 500, 250, 10)

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1


def init_mlp(key: jax.Array, cfg: MLPConfig) -> dict:
    params = {}
    for i, (m, n) in enumerate(zip(cfg.dims[:-1], cfg.dims[1:])):
        key, sub = jax.random.split(key)
        scale = 1.0 / math.sqrt(m)
        params[f"w{i}"] = jax.random.uniform(sub, (m, n), jnp.float32, -scale, scale)
        params[f"b{i}"] = jnp.zeros((n,), jnp.float32)
        params[f"g{i}"] = jnp.ones((n,), jnp.float32)  # BN-lite scale
    return params


def _is_edge(i: int, n_layers: int) -> bool:
    return i == 0 or i == n_layers - 1


def _programmed(eng, w: Array):
    """Program the binarized weights through the engine's identity-keyed
    ``WeightCache``, keyed on the latent param ``w`` (stable across
    calls). Binarization is passed lazily — a cache hit pays zero
    weight-side work. Falls back to raw signs for minimal third-party
    engines without the two-phase contract."""
    make = lambda: jnp.where(w >= 0, 1.0, -1.0)  # noqa: E731
    if hasattr(eng, "prepare_cached"):
        return eng.prepare_cached(make, key=w)
    return make()


def mlp_forward_train(params: dict, x: Array, cfg: MLPConfig) -> Array:
    """Training forward: STE binarization on hidden layers.

    No ReLU before ``sign`` (sign(relu(h)) is constantly +1 — it would
    destroy the activation signal); instead each layer ends with a
    learnable affine (g, b) that acts as the next sign's threshold, and
    binary MACs are scaled by 1/sqrt(m) so pre-activations stay in the
    STE's |h| <= 1 pass-through band.
    """
    h = x
    for i in range(cfg.n_layers):
        w = params[f"w{i}"]
        if _is_edge(i, cfg.n_layers):
            h = h @ w + params[f"b{i}"]
        else:
            a = bnn.binarize_ste(h)
            wb = bnn.binarize_ste(w)
            h = bnn.binary_matmul_signs(a, wb) / math.sqrt(w.shape[0]) + params[f"b{i}"]
        if i < cfg.n_layers - 1:
            h = params[f"g{i}"] * h
    return h


def mlp_forward_infer(
    params: dict,
    x: Array,
    cfg: MLPConfig,
    engine: EngineLike = "reference",
    spec: CrossbarSpec | None = None,
) -> Array:
    """Deploy-time forward: weights pre-binarized, selectable engine."""
    eng = engine_lib.resolve(engine, spec)
    h = x
    for i in range(cfg.n_layers):
        w = params[f"w{i}"]
        if _is_edge(i, cfg.n_layers):
            h = h @ w + params[f"b{i}"]
        else:
            a = jnp.where(h >= 0, 1.0, -1.0)
            pc = eng.binary_vmm(a, _programmed(eng, w))
            h = pc.astype(jnp.float32) / math.sqrt(w.shape[0]) + params[f"b{i}"]
        if i < cfg.n_layers - 1:
            h = params[f"g{i}"] * h
    return h


# ---------------------------------------------------------------------------
# Conv BNN (im2col — the crossbar's native view of convolution)
# ---------------------------------------------------------------------------


def im2col(x: Array, k: int, stride: int = 1) -> Array:
    """(B, H, W, C) -> (B, H', W', k*k*C): one row per conv position."""
    b, h, w, c = x.shape
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    patches = []
    for dy in range(k):
        for dx in range(k):
            patches.append(x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride, :])
    return jnp.concatenate(patches, axis=-1).reshape(b, oh, ow, k * k * c)


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    """LeNet-style BNN: convs then FCs; first/last layers hi-res."""

    in_hw: int = 28
    in_ch: int = 1
    convs: tuple[tuple[int, int], ...] = ((6, 5), (16, 5))  # (out_ch, k)
    pools: tuple[int, ...] = (2, 2)
    fcs: tuple[int, ...] = (120, 84, 10)


def conv_feature_dims(cfg: ConvConfig) -> tuple[int, int]:
    hw, c = cfg.in_hw, cfg.in_ch
    for (out_ch, k), pool in zip(cfg.convs, cfg.pools):
        hw = (hw - k + 1) // pool
        c = out_ch
    return hw, c


def init_conv(key: jax.Array, cfg: ConvConfig) -> dict:
    params = {}
    c = cfg.in_ch
    for i, (out_ch, k) in enumerate(cfg.convs):
        key, sub = jax.random.split(key)
        m = k * k * c
        params[f"cw{i}"] = jax.random.uniform(sub, (m, out_ch), jnp.float32, -1 / math.sqrt(m), 1 / math.sqrt(m))
        params[f"cg{i}"] = jnp.ones((out_ch,), jnp.float32)
        c = out_ch
    hw, c = conv_feature_dims(cfg)
    dims = (hw * hw * c,) + cfg.fcs
    for i, (m, n) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params[f"fw{i}"] = jax.random.uniform(sub, (m, n), jnp.float32, -1 / math.sqrt(m), 1 / math.sqrt(m))
        params[f"fb{i}"] = jnp.zeros((n,), jnp.float32)
    return params


def _avgpool(x: Array, p: int) -> Array:
    b, h, w, c = x.shape
    return x.reshape(b, h // p, p, w // p, p, c).mean(axis=(2, 4))


def conv_forward(
    params: dict,
    x: Array,
    cfg: ConvConfig,
    train: bool = True,
    engine: EngineLike = "reference",
    spec: CrossbarSpec | None = None,
) -> Array:
    """(B, H, W, C) images -> logits. Binary layers = all but first/last."""
    eng = engine_lib.resolve(engine, spec)
    n_fc = len(cfg.fcs)
    h = x
    for i, ((out_ch, k), pool) in enumerate(zip(cfg.convs, cfg.pools)):
        cols = im2col(h, k)  # (B, oh, ow, m)
        w = params[f"cw{i}"]
        scale = 1.0 / math.sqrt(w.shape[0])
        if i == 0:  # hi-res edge layer
            h = cols @ w
        else:
            if train:
                a = bnn.binarize_ste(cols)
                wb = bnn.binarize_ste(w)
                h = bnn.binary_matmul_signs(a, wb) * scale
            else:
                a = jnp.where(cols >= 0, 1.0, -1.0)
                h = eng.binary_vmm(a, _programmed(eng, w)).astype(jnp.float32) * scale
        h = params[f"cg{i}"] * h  # learnable pre-sign affine (no ReLU: see mlp)
        h = _avgpool(h, pool)
    h = h.reshape(h.shape[0], -1)
    for i in range(n_fc):
        w = params[f"fw{i}"]
        scale = 1.0 / math.sqrt(w.shape[0])
        if i == n_fc - 1:  # hi-res edge layer
            h = h @ w + params[f"fb{i}"]
        else:
            if train:
                a, wb = bnn.binarize_ste(h), bnn.binarize_ste(w)
                h = bnn.binary_matmul_signs(a, wb) * scale + params[f"fb{i}"]
            else:
                a = jnp.where(h >= 0, 1.0, -1.0)
                h = (
                    eng.binary_vmm(a, _programmed(eng, w)).astype(jnp.float32) * scale
                    + params[f"fb{i}"]
                )
    return h
