"""The paper's primary contribution: TacitMap data mapping + the
EinsteinBarrier oPCM/WDM accelerator, as composable JAX modules.

Layout:
  bnn.py            Eq. 1 arithmetic (XNOR+Popcount == complement-VMM), STE
  crossbar.py       tile geometry + device (ADC/PCSA/TIA) models
  tacitmap.py       the proposed vertical mapping (functional simulator)
  custbinarymap.py  the SotA baseline mapping [15]
  wdm.py            wavelength-division multiplexing (VMM -> MMM)
  engine.py         pluggable execution-backend registry (Engine protocol)
  einsteinbarrier.py  Node/Tile/ECore/VCore hierarchy + placement
  costmodel.py      latency/energy analytical models (Fig. 7 / Fig. 8)
  networks.py       the 6 MlBench BNN workloads
  model.py          trainable/executable BNNs with selectable engines
"""

from repro.core import (
    bnn,
    costmodel,
    crossbar,
    custbinarymap,
    einsteinbarrier,
    engine,
    model,
    networks,
    tacitmap,
    wdm,
)

__all__ = [
    "bnn",
    "costmodel",
    "crossbar",
    "custbinarymap",
    "einsteinbarrier",
    "engine",
    "model",
    "networks",
    "tacitmap",
    "wdm",
]
