"""The paper's evaluation workloads: 6 BNNs (3 MLPs + 3 CNNs), MlBench-style.

The paper evaluates "6 BNNs (3 convolutional networks and 3 multilayer
perceptrons) with various sizes from MlBench [44]" on MNIST and
CIFAR-10. MlBench (from PRIME [44]) does not publish exact layer lists
in the paper, so we use its standard members: the classic MLPs on MNIST
and LeNet-5 / BinaryNet-VGG-small / VGG-16 on MNIST/CIFAR-10 — the same
suite every CIM-for-BNN paper in this line uses.

Each layer is reduced to the quantities the mappings care about:
``m`` (fan-in = weight-vector length), ``n`` (number of stored weight
vectors = output features/channels) and ``positions`` (input vectors per
inference: 1 for FC, H_out*W_out for conv via im2col). First and last
layers stay high-precision (§II-B), marked ``binary=False``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    name: str
    m: int           # fan-in (vector length driven onto rows)
    n: int           # output vectors (stored columns)
    positions: int   # input vectors per inference (im2col positions)
    binary: bool     # hidden binary layer (XNOR+Popcount) or hi-res edge layer

    @property
    def macs(self) -> int:
        return self.m * self.n * self.positions


@dataclasses.dataclass(frozen=True)
class NetworkDesc:
    name: str
    dataset: str
    layers: tuple[LayerDesc, ...]

    @property
    def macs(self) -> int:
        return sum(l.macs for l in self.layers)


def _mlp(name: str, dims: tuple[int, ...]) -> NetworkDesc:
    layers = []
    for i, (m, n) in enumerate(zip(dims[:-1], dims[1:])):
        edge = i == 0 or i == len(dims) - 2
        layers.append(LayerDesc(f"fc{i}", m=m, n=n, positions=1, binary=not edge))
    return NetworkDesc(name, "MNIST", tuple(layers))


def _conv(name, c_in, c_out, k, out_hw, binary=True) -> LayerDesc:
    return LayerDesc(name, m=c_in * k * k, n=c_out, positions=out_hw * out_hw, binary=binary)


MLP_S = _mlp("MLP-S", (784, 500, 250, 10))
MLP_M = _mlp("MLP-M", (784, 1000, 500, 250, 10))
MLP_L = _mlp("MLP-L", (784, 1500, 1000, 500, 10))

# LeNet-5 on MNIST (CNN-S)
CNN_S = NetworkDesc(
    "CNN-S",
    "MNIST",
    (
        _conv("conv1", 1, 6, 5, 24, binary=False),   # first layer hi-res
        _conv("conv2", 6, 16, 5, 8),
        LayerDesc("fc1", m=400, n=120, positions=1, binary=True),
        LayerDesc("fc2", m=120, n=84, positions=1, binary=True),
        LayerDesc("fc3", m=84, n=10, positions=1, binary=False),
    ),
)

# BinaryNet VGG-small on CIFAR-10 (CNN-M): 2x128C3-P-2x256C3-P-2x512C3-P-1024FC-10
CNN_M = NetworkDesc(
    "CNN-M",
    "CIFAR-10",
    (
        _conv("conv1", 3, 128, 3, 32, binary=False),
        _conv("conv2", 128, 128, 3, 32),
        _conv("conv3", 128, 256, 3, 16),
        _conv("conv4", 256, 256, 3, 16),
        _conv("conv5", 256, 512, 3, 8),
        _conv("conv6", 512, 512, 3, 8),
        LayerDesc("fc1", m=512 * 4 * 4, n=1024, positions=1, binary=True),
        LayerDesc("fc2", m=1024, n=1024, positions=1, binary=True),
        LayerDesc("fc3", m=1024, n=10, positions=1, binary=False),
    ),
)

# VGG-16 on CIFAR-10 (CNN-L)
CNN_L = NetworkDesc(
    "CNN-L",
    "CIFAR-10",
    (
        _conv("conv1", 3, 64, 3, 32, binary=False),
        _conv("conv2", 64, 64, 3, 32),
        _conv("conv3", 64, 128, 3, 16),
        _conv("conv4", 128, 128, 3, 16),
        _conv("conv5", 128, 256, 3, 8),
        _conv("conv6", 256, 256, 3, 8),
        _conv("conv7", 256, 256, 3, 8),
        _conv("conv8", 256, 512, 3, 4),
        _conv("conv9", 512, 512, 3, 4),
        _conv("conv10", 512, 512, 3, 4),
        _conv("conv11", 512, 512, 3, 2),
        _conv("conv12", 512, 512, 3, 2),
        _conv("conv13", 512, 512, 3, 2),
        LayerDesc("fc1", m=512, n=512, positions=1, binary=True),
        LayerDesc("fc2", m=512, n=512, positions=1, binary=True),
        LayerDesc("fc3", m=512, n=10, positions=1, binary=False),
    ),
)

NETWORKS: dict[str, NetworkDesc] = {
    n.name: n for n in (MLP_S, MLP_M, MLP_L, CNN_S, CNN_M, CNN_L)
}
