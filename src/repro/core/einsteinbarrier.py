"""EinsteinBarrier: spatial accelerator hierarchy, placement and schedule.

Fig. 4: Node → Tile → ECore → VCore. A VCore is one VMM-capable oPCM
crossbar (+DAC/ADC/TIA periphery); an ECore groups VCores behind one
WDM transmitter (§IV-A3); Tiles group ECores with shared scratch; Nodes
group Tiles. This module places a network's layers onto that hierarchy
(weights resident, PUMA-style), checks capacity, and produces the
per-layer schedule the cost model prices.

The *functional* result of executing a placement is produced by
``tacitmap.apply`` / ``wdm.wdm_apply`` — the hierarchy only decides how
many crossbars exist and how work is sequenced.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import costmodel
from repro.core.crossbar import CrossbarSpec, OPCM_TILE, TileGrid
from repro.core.networks import LayerDesc, NetworkDesc


@dataclasses.dataclass(frozen=True)
class HierarchyConfig:
    """Capacity of one EinsteinBarrier node."""

    vcores_per_ecore: int = 32
    ecores_per_tile: int = 8
    tiles_per_node: int = 16
    spec: CrossbarSpec = OPCM_TILE

    @property
    def vcores_per_node(self) -> int:
        return self.vcores_per_ecore * self.ecores_per_tile * self.tiles_per_node


@dataclasses.dataclass(frozen=True)
class LayerPlacement:
    layer: LayerDesc
    grid: TileGrid          # logical tiling of the (stacked) weight matrix
    replication: int        # extra weight copies for position parallelism
    vcores: int             # crossbars consumed = grid.n_tiles * replication
    ecore_span: int         # ECores this layer spans (ceil over transmitter groups)


@dataclasses.dataclass(frozen=True)
class Placement:
    network: NetworkDesc
    layers: tuple[LayerPlacement, ...]
    hierarchy: HierarchyConfig

    @property
    def total_vcores(self) -> int:
        return sum(p.vcores for p in self.layers)

    @property
    def nodes_needed(self) -> int:
        return max(1, math.ceil(self.total_vcores / self.hierarchy.vcores_per_node))

    @property
    def utilization(self) -> float:
        """Fraction of provisioned cells holding real (non-pad) weights."""
        used = sum(
            (2 if p.layer.binary else 1) * p.layer.m * p.layer.n * p.replication
            for p in self.layers
        )
        provisioned = sum(
            p.grid.n_devices * p.replication for p in self.layers
        )
        return used / provisioned if provisioned else 0.0


def place(
    net: NetworkDesc,
    hierarchy: HierarchyConfig | None = None,
    params: costmodel.CIMParams = costmodel.EINSTEINBARRIER,
) -> Placement:
    """Place every layer's (stacked) weight matrix onto VCores.

    Binary layers map TacitMap-style (2m rows); edge layers map their m
    rows with bit-sliced hi-res weights (edge_bits column slices).
    """
    h = hierarchy or HierarchyConfig(spec=params.tile)
    placements = []
    for layer in net.layers:
        rows = (2 if layer.binary else 1) * layer.m
        cols = layer.n * (1 if layer.binary else params.edge_bits)
        grid = TileGrid(rows=rows, cols=cols, spec=h.spec)
        if layer.positions > 1:
            cap = params.conv_replication if layer.binary else params.edge_conv_replication
            repl = min(cap, layer.positions)
        else:
            repl = 1
        vcores = grid.n_tiles * repl
        ecore_span = max(1, math.ceil(vcores / h.vcores_per_ecore))
        placements.append(
            LayerPlacement(layer=layer, grid=grid, replication=repl, vcores=vcores, ecore_span=ecore_span)
        )
    return Placement(network=net, layers=tuple(placements), hierarchy=h)


def schedule_summary(placement: Placement, params: costmodel.CIMParams) -> list[dict]:
    """Per-layer schedule: steps, latency, energy for one batch."""
    out = []
    for p in placement.layers:
        out.append(
            {
                "layer": p.layer.name,
                "binary": p.layer.binary,
                "vcores": p.vcores,
                "steps": costmodel.layer_steps(params, p.layer),
                "latency_ns": costmodel.layer_latency_ns(params, p.layer),
                "energy_pj": costmodel.layer_energy_pj(params, p.layer),
            }
        )
    return out
