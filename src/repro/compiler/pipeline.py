"""``compile(cfg, params, target) -> CompiledModel`` — the one-call
hardware-compilation pipeline.

The paper's flow is a single pipeline: map the BNN onto the crossbar
(TacitMap), program the oPCM cells once, then stream activations under
WDM (EinsteinBarrier). ``compile`` runs exactly that, in the canonical
order, from one :class:`~repro.compiler.target.HardwareTarget`:

1. **Validate** the target eagerly (named :class:`TargetError`\\ s —
   plan+engine mismatch, spec mismatch, K over plan capacity).
2. **Map**: compile an explicit layer->tile
   :class:`~repro.mapping.allocator.MappingPlan`
   (``mapping.compile_plan``) when the target names a policy/budget, or
   bind a pre-compiled plan passed by the caller.
3. **Resolve** the execution backend from the registry
   (``engine_lib.get_engine``; ``tiled`` binds the plan) and flip the
   model config to ``quant="bnn"`` for non-reference engines — a
   hardware backend executes the binarized projections.
4. **Program**: run the one-time crossbar write
   (``lm.program_weights``) so every binarized projection is resident
   in the engine's prepared form and decode ticks stream only
   activations.

The returned :class:`CompiledModel` is the single artifact every
consumer drives: ``prefill()`` / ``decode_step()`` for batch serving
loops, ``serve()`` for a bound continuous-batching
:class:`~repro.serving.engine.ServingEngine`, ``price()`` for the cost
model's plan + programming + per-tick readout report, ``describe()``
for the placement/pricing tables.

One-call replacements for the old multi-knob recipes::

    # was: get_engine("wdm") + replace(cfg, quant="bnn", bnn_engine=..)
    #      + GroupedEngine(eng, k) + lm.program_weights(...) in 4 places
    cm = compile(cfg, params, HardwareTarget(engine="wdm", group_size=4))
    logits, caches = cm.prefill(tokens)
    logits, caches = cm.decode_step(tok, pos, caches)

    # was: compile_plan(cfg, policy=..) + get_engine("tiled", plan=..)
    #      + ServingEngine(cfg, params, engine="tiled", mapping_plan=..)
    cm = compile(cfg, params, HardwareTarget(engine="tiled",
                                             mapping_policy="greedy"))
    se = cm.serve(max_batch=8, max_len=256)

    # was: nothing — pricing required hand-wiring costmodel pieces
    print(cm.price().summary())
    print(cm.describe())
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro import obs
from repro.compiler.target import (
    GroupSizeError,
    HardwareTarget,
    PlanEngineMismatchError,
    SpecMismatchError,
    TargetError,
)
from repro.core import engine as engine_lib
from repro.core.crossbar import CrossbarSpec, EPCM_TILE, OPCM_TILE


def _default_spec(engine_name: str) -> CrossbarSpec:
    """The tile catalogue entry an engine defaults to (its capability row)."""
    try:
        info = engine_lib.engine_info(engine_name)
    except Exception:
        return EPCM_TILE
    return OPCM_TILE if getattr(info, "default_spec", "ePCM") == "oPCM" else EPCM_TILE


def resolve_engine(target: HardwareTarget, cfg=None, plan=None):
    """Resolve a target's execution backend (``None`` = plain-jnp path).

    The ``tiled`` engine binds ``plan`` when given one, else places
    ad hoc under ``target.mapping_policy`` (falling back to the config's
    policy). Shared by :func:`compile` and by benchmark sweeps that need
    the raw engine without a model (e.g. the mapping parity sweep).
    """
    if target.engine in ("", "reference"):
        return None
    kw = {}
    if target.engine == "packed":
        # fused decode-tick kernel vs the unfused multi-op baseline
        kw = {"fused": target.fused}
    if target.engine == "tiled":
        # ad-hoc fallback placements (projection shapes absent from the
        # plan) must land under the SAME policy the plan/config reports:
        # explicit target policy > the bound plan's > the config's
        policy = target.mapping_policy
        if policy is None and plan is not None:
            policy = plan.policy
        if policy is None and cfg is not None:
            policy = getattr(cfg, "mapping_policy", None)
        kw = {"plan": plan, "policy": policy or "tacitmap"}
        if target.mesh_axis is not None:
            kw["mesh_axis"] = target.mesh_axis
    base = engine_lib.get_engine(target.engine, target.spec, **kw)
    if target.fault_model is not None:
        from repro.faults.engine import FaultyEngine

        base = FaultyEngine(base, target.fault_model)
    return base


def compile(cfg, params, target: HardwareTarget, *, plan=None) -> "CompiledModel":
    """Compile a model onto a hardware target: map -> program -> execute.

    ``cfg`` is a :class:`~repro.models.config.ModelConfig` (decoder-only
    LM stack); ``params`` its parameter pytree, or ``None`` for a
    price-only compilation (``price()``/``describe()`` work, execution
    entry points raise). ``plan`` optionally binds a pre-compiled
    :class:`~repro.mapping.allocator.MappingPlan` instead of compiling
    one from ``target.mapping_policy``.

    Validates the whole combination eagerly (:class:`TargetError`
    subclasses name the mismatch) and returns a :class:`CompiledModel`.

    When a telemetry session is active (:mod:`repro.obs`) each pipeline
    stage — validate / map / resolve / program — records a span on the
    ``compile`` track, with the one-time programming cost attached.
    """
    with obs.span("compile", track="compile",
                  engine=target.engine, model=getattr(cfg, "name", "?")) as root:
        cm = _compile_staged(cfg, params, target, plan)
        root.set(programmed=cm.programmed, program_s=cm.program_s)
        return cm


def _compile_staged(cfg, params, target, plan) -> "CompiledModel":
    with obs.span("compile.validate", track="compile"):
        target = target.validate()
        if getattr(cfg, "is_encdec", False) and target.engine != "reference":
            raise TargetError(
                f"{cfg.name}: hardware targets compile the decoder-only LM "
                "projection stack; enc-dec models serve through "
                "cfg.bnn_engine directly"
            )

    # -- map: the explicit layer->tile placement ---------------------------
    with obs.span("compile.map", track="compile") as map_span:
        plan = _map_stage(cfg, target, plan)
        if plan is not None:
            map_span.set(policy=plan.policy, n_tiles=plan.n_tiles)

    # -- resolve: registry backend + bnn config ----------------------------
    with obs.span("compile.resolve", track="compile") as res_span:
        base, cfg = _resolve_stage(cfg, target, plan)
        res_span.set(backend=base.name if base is not None else "none")

    # -- program: the one-time crossbar write ------------------------------
    programmed, program_s = 0, 0.0
    if params is not None and base is not None and target.prepare_weights:
        from repro.models import lm as lm_lib

        with obs.span("compile.program", track="compile") as prog_span:
            t0 = time.perf_counter()
            params, programmed = lm_lib.program_weights(params, cfg, base)
            prog_span.fence(params)
            program_s = time.perf_counter() - t0
            prog_span.set(programmed=programmed, program_s=program_s)

    return CompiledModel(
        cfg=cfg,
        params=params,
        target=target,
        plan=plan,
        engine=base,
        programmed=programmed,
        program_s=program_s,
    )


def _map_stage(cfg, target, plan):
    if plan is not None:
        if target.engine != "tiled":
            raise PlanEngineMismatchError(
                f"a MappingPlan was passed but the target's engine is "
                f"{target.engine!r} — only the plan-driven 'tiled' engine "
                "executes a placement (the old ServingEngine silently used "
                "such a plan for K only)"
            )
        if target.spec is not None and plan.spec != target.spec:
            raise SpecMismatchError(
                f"plan was compiled for {plan.spec.technology} "
                f"{plan.spec.rows}x{plan.spec.cols} tiles but the target "
                f"binds {target.spec.technology} "
                f"{target.spec.rows}x{target.spec.cols} — recompile the plan "
                "on the target's spec"
            )
        # a bound plan already fixed the allocator choices; a target
        # naming different ones would be a silent knob drop
        if (
            target.mapping_policy is not None
            and target.mapping_policy != plan.policy
        ):
            raise TargetError(
                f"target names mapping_policy={target.mapping_policy!r} but "
                f"binds a plan compiled under {plan.policy!r} — drop the "
                "field or recompile the plan under the target's policy"
            )
        if (
            target.tile_budget is not None
            and target.tile_budget != plan.tile_budget
        ):
            raise TargetError(
                f"target names tile_budget={target.tile_budget} but binds a "
                f"plan compiled with tile_budget={plan.tile_budget} — drop "
                "the field or recompile the plan under the target's budget"
            )
        if target.spare_tiles and len(plan.spares) != target.spare_tiles:
            raise TargetError(
                f"target names spare_tiles={target.spare_tiles} but binds a "
                f"plan provisioning {len(plan.spares)} spare(s) — drop the "
                "field or recompile the plan with the target's spare budget"
            )
    elif target.wants_plan:
        from repro.mapping import compile_plan

        plan = compile_plan(
            cfg,
            spec=target.spec or _default_spec(target.engine),
            policy=target.mapping_policy or cfg.mapping_policy or "tacitmap",
            tile_budget=target.tile_budget,
            spare_tiles=target.spare_tiles,
        )
    return plan


def _resolve_stage(cfg, target, plan):
    base = resolve_engine(target, cfg, plan)
    if base is not None:
        # a hardware backend executes the binarized projections, so it
        # implies quant="bnn" (same contract as the old per-consumer
        # wiring); for tiled, pin the policy so any ad-hoc fallback
        # placement matches the plan's policy
        upd: dict[str, Any] = {"quant": "bnn", "bnn_engine": target.engine}
        if target.engine == "tiled" and (target.mapping_policy or plan is not None):
            upd["mapping_policy"] = (
                target.mapping_policy if target.mapping_policy is not None
                else plan.policy
            )
        cfg = dataclasses.replace(cfg, **upd)

    # -- K-group capacity: reject widths the hardware cannot multiplex ----
    if target.group_size is not None:
        cap = None
        if plan is not None:
            cap, what = plan.preferred_group_size(), "the plan's placed tiles"
        elif base is not None and base.info.native_mmm:
            cap, what = base.preferred_group_size(), f"engine {base.name!r}"
        if cap is not None and target.group_size > cap:
            raise GroupSizeError(
                f"group_size={target.group_size} exceeds the WDM capacity "
                f"K={cap} of {what} — more K-groups cannot ride one "
                "crossbar step than the tile has wavelengths"
            )

    return base, cfg


@dataclasses.dataclass(frozen=True)
class RemapReport:
    """``CompiledModel.remap()``: what moved and what reprogramming cost.

    ``cost`` is a ``costmodel.ProgrammingCost`` covering ONLY the moved
    blocks — incremental remapping's point is that untouched tiles keep
    their cells."""

    moves: tuple          # mapping.BlockMove per relocated block
    cost: Any             # costmodel.ProgrammingCost of the reprogram
    failed_tiles: frozenset[int]
    spares_left: int


@dataclasses.dataclass(frozen=True)
class TargetPrice:
    """``CompiledModel.price()``: the cost model's three seams in one
    report — plan execution, one-time programming, per-tick readout."""

    target: HardwareTarget
    design: str           # CIM design the tile spec implies
    policy: str
    n_tiles: int          # physical tiles provisioned (the area axis)
    utilization: float
    k: int                # WDM capacity of the priced tiles
    binary_steps: int
    latency_s: float      # per inference (plan schedule + edge layers)
    energy_j: float
    programming_cells: int
    programming_uj: float  # one-time PCM write energy
    programming_us: float
    tick_latency_ns: float  # one K-grouped decode tick, all binary layers
    tick_energy_pj: float
    break_even_ticks: float  # ticks until the write has paid for itself
    plan_cost: Any        # the full costmodel.PlanCost (per-layer rows)

    def summary(self) -> str:
        return (
            f"[price] {self.plan_cost.model} on {self.design} "
            f"(policy={self.policy}, {self.n_tiles} tiles, K={self.k}): "
            f"{self.latency_s * 1e6:.2f} us/inf, {self.energy_j * 1e6:.3f} uJ/inf; "
            f"program {self.programming_uj:.2f} uJ / {self.programming_us:.1f} us "
            f"(break-even {self.break_even_ticks:.0f} ticks); "
            f"tick {self.tick_latency_ns * 1e-3:.2f} us / {self.tick_energy_pj:.1f} pJ"
        )


class CompiledModel:
    """The artifact ``compile()`` returns: model + target, executable.

    Holds the post-pipeline state — the bnn-flipped config, the
    programmed params, the compiled plan and the resolved backend — and
    exposes every way the stack is driven:

    * :meth:`prefill` / :meth:`decode_step` — jitted LM entry points
      with the target's K-grouped executor bound (batch loops,
      ``launch/serve.py``).
    * :meth:`serve` — a bound continuous-batching ``ServingEngine``.
    * :meth:`price` — plan + programming + per-tick readout in one
      :class:`TargetPrice` (works without params: DSE sweeps compile
      price-only models).
    * :meth:`describe` — placement + pricing tables via
      ``mapping.report``.
    """

    def __init__(self, *, cfg, params, target, plan, engine, programmed, program_s):
        self.cfg = cfg
        self.params = params
        self.target = target
        self.plan = plan
        self.engine = engine          # resolved base backend (None = plain jnp)
        self.programmed = programmed  # projection instances programmed
        self.program_s = program_s    # crossbar-programming wall time
        self._jit: dict[int, tuple] = {}
        self._price_plan = plan

    # -- execution ----------------------------------------------------------

    @property
    def spec(self) -> CrossbarSpec:
        if self.engine is not None:
            return self.engine.spec
        if self.plan is not None:
            return self.plan.spec
        return self.target.spec or _default_spec(self.target.engine)

    def group_size_for(self, batch: int) -> int:
        """The K the BatchPlanner/executor uses for a ``batch``-slot pool
        (explicit target K > plan WDM capacity > engine capability >
        one vmap'd group; clamped to the pool — and, under fault
        injection, to the surviving WDM lanes)."""
        k = engine_lib.resolve_group_size(
            self.engine, self.target.group_size, batch, plan=self.plan
        )
        cap_fn = getattr(self.engine, "effective_group_cap", None)
        if callable(cap_fn):
            cap = cap_fn()
            if cap is not None:
                k = max(1, min(k, cap))
        return k

    def executor(self, batch: int):
        """The K-grouped execution adapter for a ``batch``-slot pool
        (``None`` on the plain-jnp reference path)."""
        return self._fns(self.group_size_for(batch))[0]

    def _require_params(self):
        if self.params is None:
            raise TargetError(
                "this model was compiled without params (price-only); "
                "re-run compile(cfg, params, target) to execute"
            )

    def _fns(self, k: int):
        """(executor, jitted prefill, jitted decode) per K — cached so a
        steady serving loop traces once."""
        if k not in self._jit:
            from repro.models import lm as lm_lib

            import jax

            ex = (
                engine_lib.GroupedEngine(self.engine, k)
                if self.engine is not None
                else None
            )
            cfg = self.cfg
            prefill = jax.jit(
                lambda p, t, e: lm_lib.prefill(p, t, cfg, e, engine=ex)
            )
            # donate the KV-cache pytree: tick N's caches update in
            # place instead of being copied (decode_step returns the
            # same-shaped new caches, so XLA aliases input to output).
            # Callers must treat the passed caches as CONSUMED and carry
            # the returned pytree forward — every serving loop already
            # does (``logits, caches = decode_step(...)``).
            decode = jax.jit(
                lambda p, t, pos, c: lm_lib.decode_step(p, t, pos, c, cfg, engine=ex),
                donate_argnums=(3,),
            )
            self._jit[k] = (ex, prefill, decode)
        return self._jit[k]

    def prefill(self, tokens, extra_embeds=None):
        """Jitted LM prefill through the target's executor:
        (B, S) tokens -> (last-position logits, per-layer caches)."""
        self._require_params()
        _, prefill, _ = self._fns(self.group_size_for(int(tokens.shape[0])))
        return prefill(self.params, tokens, extra_embeds)

    def decode_step(self, token, pos, caches):
        """Jitted single-token decode through the target's executor:
        token (B,), pos scalar or (B,), caches -> (logits, new caches).

        ``caches`` is DONATED: its buffers are updated in place and the
        input pytree must not be reused after the call — carry the
        returned caches forward (``logits, caches = decode_step(...)``).
        """
        self._require_params()
        _, _, decode = self._fns(self.group_size_for(int(token.shape[0])))
        return decode(self.params, token, pos, caches)

    def init_cache(self, batch: int, max_len: int):
        from repro.models import lm as lm_lib

        return lm_lib.init_cache(self.cfg, batch, max_len)

    def graft_prefill_caches(self, caches, pre_caches):
        """Graft prefill-sized caches into a serving-capacity cache
        pytree from :meth:`init_cache` (the one place that knows the
        attn (L,B,T,KV,D) layout grafts by time prefix while ssm states
        carry over whole)."""
        import jax

        def graft(dst, src):
            if dst.ndim == 5 and dst.shape[2] >= src.shape[2]:  # attn (L,B,T,KV,D)
                return dst.at[:, :, : src.shape[2]].set(src.astype(dst.dtype))
            return src.astype(dst.dtype)  # ssm states carry over directly

        return jax.tree.map(graft, caches, pre_caches)

    def serve(self, *, max_batch: int = 4, max_len: int = 256, scheduler=None):
        """A scheduler-fronted continuous-batching ``ServingEngine``
        bound to this model. ``scheduler`` is an optional
        :class:`repro.serving.SchedulerConfig` (policy, admission mode,
        KV reserve ratio, queue cap, preemption) — serve-time knobs,
        deliberately NOT on the compile-time ``HardwareTarget``."""
        self._require_params()
        from repro.serving import ServingEngine  # lazy: serving imports compiler

        return ServingEngine(
            self, max_batch=max_batch, max_len=max_len, scheduler=scheduler
        )

    # -- fault tolerance (PR 9) ---------------------------------------------

    def _fault_engine(self):
        from repro.faults.engine import FaultyEngine

        return self.engine if isinstance(self.engine, FaultyEngine) else None

    def _fault_artifacts(self):
        """Every resident PreparedWeights in the programmed params."""
        if self.params is None:
            return []
        import jax

        leaf = lambda x: isinstance(x, engine_lib.PreparedWeights)  # noqa: E731
        return [
            pw for pw in jax.tree.leaves(self.params, is_leaf=leaf) if leaf(pw)
        ]

    def _refresh_artifacts(self):
        """Re-derive every resident artifact under the wrapper's CURRENT
        fault state / inner engine (the reprogramming step)."""
        import jax

        eng = self.engine
        leaf = lambda x: isinstance(x, engine_lib.PreparedWeights)  # noqa: E731
        return jax.tree.map(
            lambda x: eng.refresh(x) if leaf(x) else x, self.params, is_leaf=leaf
        )

    def scan_faults(self):
        """One consistency sweep over all resident artifacts: the
        :class:`repro.faults.FaultMap` of physical tiles holding
        corrupted cells plus the dead WDM lanes. Empty (falsy) on a
        non-fault-injecting target."""
        from repro.faults import FaultMap

        eng = self._fault_engine()
        if eng is None:
            return FaultMap()
        tiles: frozenset[int] = frozenset()
        for pw in self._fault_artifacts():
            tiles |= eng.locate(pw)
        if tiles:
            obs.count(
                "repro_faults_detected_total", len(tiles),
                "faulty physical tiles flagged by consistency sweeps",
            )
        return FaultMap(tiles=tiles, lanes=eng.dead_lanes())

    def refresh_faults(self) -> None:
        """Reprogram all artifacts after the fault state changed
        (``engine.fail_tile`` / ``engine.advance_drift``) so execution
        observes the new state."""
        if self._fault_engine() is None:
            raise TargetError(
                "refresh_faults() needs a fault-injecting target "
                "(HardwareTarget(fault_model=...))"
            )
        if self.params is not None:
            self.params = self._refresh_artifacts()
        self._jit.clear()

    def remap(self, fault_map) -> "RemapReport":
        """Move ONLY the blocks resident on the fault map's tiles onto
        clean spares, rebind the (re-placed) inner engine under the same
        fault state, and reprogram just the refreshed artifacts.

        Raises :class:`repro.mapping.SpareTilesExhaustedError` when the
        clean-spare pool can't cover the displaced blocks, and
        :class:`TargetError` when the target has no fault wrapper or no
        plan to re-place."""
        from repro.mapping import remap_plan

        eng = self._fault_engine()
        if eng is None:
            raise TargetError(
                "remap() needs a fault-injecting target "
                "(HardwareTarget(fault_model=...))"
            )
        if self.plan is None:
            raise TargetError(
                "remap() re-places an explicit MappingPlan — compile with "
                "the 'tiled' engine and spare_tiles/mapping_policy set"
            )
        tiles = frozenset(getattr(fault_map, "tiles", fault_map))
        with obs.span("remap", track="compile", tiles=sorted(tiles)) as sp:
            new_plan, delta = remap_plan(
                self.plan, tiles, tile_ok=eng.tile_is_clean
            )
            inner = resolve_engine(
                dataclasses.replace(self.target, fault_model=None),
                self.cfg, new_plan,
            )
            self.plan = new_plan
            self._price_plan = new_plan
            self.engine = eng.rebind(inner)
            if self.params is not None:
                self.params = self._refresh_artifacts()
            # cached executors close over the OLD wrapper — drop them
            self._jit.clear()
            sp.set(moves=len(delta.moves), spares_left=len(new_plan.spares))
        obs.count("repro_remaps_total", 1, "fault-driven incremental remaps")
        return RemapReport(
            moves=delta.moves,
            cost=delta.cost,
            failed_tiles=tiles,
            spares_left=len(new_plan.spares),
        )

    # -- pricing / reporting ------------------------------------------------

    def _pricing_plan(self):
        """The plan the cost model prices: the bound plan, else one
        compiled lazily on the target's spec/policy (pricing is static —
        a reference/wdm target still prices the paper's mapping)."""
        if self._price_plan is None:
            from repro.mapping import compile_plan

            self._price_plan = compile_plan(
                self.cfg,
                spec=self.target.spec or self.spec,
                policy=self.target.mapping_policy
                or getattr(self.cfg, "mapping_policy", None)
                or "tacitmap",
                tile_budget=self.target.tile_budget,
            )
        return self._price_plan

    def pricing_plan(self):
        """Public accessor for the plan the cost model prices (the bound
        plan, else one compiled lazily on the target's spec/policy).
        The telemetry cross-check (:mod:`repro.obs.crosscheck`) uses it
        to price traced decode ticks."""
        return self._pricing_plan()

    def price(self, n_active: int = 16) -> TargetPrice:
        """Plan execution + one-time programming + per-tick readout, in
        one report (``n_active`` = serving slots per decode tick)."""
        from repro.core import costmodel

        plan = self._pricing_plan()
        cost = costmodel.price_plan(plan)
        prog = costmodel.plan_programming_cost(plan)
        tick = costmodel.plan_decode_tick(plan, n_active)
        return TargetPrice(
            target=self.target,
            design=cost.design,
            policy=plan.policy,
            n_tiles=plan.n_tiles,
            utilization=plan.utilization(),
            k=plan.preferred_group_size(),
            binary_steps=cost.binary_steps,
            latency_s=cost.latency_s,
            energy_j=cost.energy_j,
            programming_cells=prog.cells,
            programming_uj=prog.energy_pj * 1e-6,
            programming_us=prog.time_ns * 1e-3,
            tick_latency_ns=tick.latency_ns,
            tick_energy_pj=tick.energy_pj,
            break_even_ticks=prog.energy_pj / max(tick.energy_pj, 1e-12),
            plan_cost=cost,
        )

    def describe(self, max_rows: int = 12) -> str:
        """Placement + pricing tables for this target (mapping.report)."""
        from repro.mapping import report

        plan = self._pricing_plan()
        price = self.price()  # carries the plan_cost format_priced needs
        lines = [self.target.describe(), report.summarize(plan)]
        lines.append(report.format_priced(price.plan_cost))
        lines.append(price.summary())
        if self.programmed:
            lines.append(
                f"[program] {self.programmed} projection instance(s) resident "
                f"in {self.target.engine} form ({self.program_s * 1e3:.1f} ms "
                "one-time PCM write)"
            )
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        eng = self.engine.name if self.engine is not None else "reference"
        planned = self.plan.policy if self.plan is not None else "-"
        return (
            f"<CompiledModel {self.cfg.name} engine={eng} plan={planned} "
            f"programmed={self.programmed}>"
        )
