"""One-call hardware compilation: ``HardwareTarget`` + ``compile()``
-> ``CompiledModel``.

The paper presents ONE pipeline — map the BNN onto the crossbar
(TacitMap), program the oPCM cells once, stream activations under WDM
(EinsteinBarrier) — and this package is that pipeline's single entry
point. Instead of hand-threading five knobs (engine name,
``CrossbarSpec``, mapping policy/plan, K-group width, prepare/cache
switches) through every consumer in a different order, a consumer
builds one :class:`HardwareTarget` and calls :func:`compile`::

    from repro.compiler import HardwareTarget, compile

    cm = compile(cfg, params, HardwareTarget(engine="tiled",
                                             mapping_policy="greedy",
                                             group_size=8))
    se = cm.serve(max_batch=8, max_len=256)     # continuous batching
    logits, caches = cm.prefill(tokens)          # or drive it directly
    print(cm.price().summary())                  # plan+program+tick cost
    print(cm.describe())                         # placement tables

Module map:

* :mod:`repro.compiler.target`   — :class:`HardwareTarget` + the named
  validation errors (:class:`TargetError`,
  :class:`PlanEngineMismatchError`, :class:`SpecMismatchError`,
  :class:`GroupSizeError`).
* :mod:`repro.compiler.pipeline` — :func:`compile`,
  :class:`CompiledModel`, :class:`TargetPrice`, :func:`resolve_engine`.
* :mod:`repro.compiler.cli`      — the shared ``--engine`` /
  ``--group-size`` / ``--mapping-policy`` / ``--tile-budget`` argparse
  surface (:func:`add_target_args` / :func:`target_from_args`) plus the
  serve-time scheduler flags (:func:`add_scheduler_args` /
  :func:`scheduler_from_args`), the telemetry flags
  (:func:`add_obs_args` / :func:`obs_from_args`) and the fleet flags
  (:func:`add_fleet_args`: ``--replicas`` / ``--routing`` /
  ``--prefix-block``).

Consumers: ``ServingEngine`` accepts ONLY a :class:`CompiledModel`
(the PR 5 legacy-kwarg shim was removed in PR 7 — old call sites get a
``LegacyServingSignatureError`` naming this package),
``launch/serve.py`` constructs a target from its flags, the serving /
mapping benchmarks sweep over targets, and ``benchmarks/dse.py`` grids
policy x tile budget x K through :meth:`CompiledModel.price`. Serve-time
knobs (scheduling policy, admission mode, KV reserve) live on
``repro.serving.SchedulerConfig`` and are passed to
``CompiledModel.serve(scheduler=...)`` — compile-time and serve-time
concerns stay on separate objects. A future multi-device serving path
is one more target field (``mesh_axis``), not a sixth ad-hoc knob.
"""

from repro.compiler.cli import (  # noqa: F401
    add_fleet_args,
    add_obs_args,
    add_scheduler_args,
    add_target_args,
    obs_from_args,
    scheduler_from_args,
    target_from_args,
)
from repro.compiler.pipeline import (  # noqa: F401
    CompiledModel,
    RemapReport,
    TargetPrice,
    compile,
    resolve_engine,
)
from repro.compiler.target import (  # noqa: F401
    GroupSizeError,
    HardwareTarget,
    PlanEngineMismatchError,
    SpecMismatchError,
    TargetError,
)
