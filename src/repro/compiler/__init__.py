"""One-call hardware compilation: ``HardwareTarget`` + ``compile()``
-> ``CompiledModel``.

The paper presents ONE pipeline — map the BNN onto the crossbar
(TacitMap), program the oPCM cells once, stream activations under WDM
(EinsteinBarrier) — and this package is that pipeline's single entry
point. Instead of hand-threading five knobs (engine name,
``CrossbarSpec``, mapping policy/plan, K-group width, prepare/cache
switches) through every consumer in a different order, a consumer
builds one :class:`HardwareTarget` and calls :func:`compile`::

    from repro.compiler import HardwareTarget, compile

    cm = compile(cfg, params, HardwareTarget(engine="tiled",
                                             mapping_policy="greedy",
                                             group_size=8))
    se = cm.serve(max_batch=8, max_len=256)     # continuous batching
    logits, caches = cm.prefill(tokens)          # or drive it directly
    print(cm.price().summary())                  # plan+program+tick cost
    print(cm.describe())                         # placement tables

Module map:

* :mod:`repro.compiler.target`   — :class:`HardwareTarget` + the named
  validation errors (:class:`TargetError`,
  :class:`PlanEngineMismatchError`, :class:`SpecMismatchError`,
  :class:`GroupSizeError`).
* :mod:`repro.compiler.pipeline` — :func:`compile`,
  :class:`CompiledModel`, :class:`TargetPrice`, :func:`resolve_engine`.
* :mod:`repro.compiler.cli`      — the shared ``--engine`` /
  ``--group-size`` / ``--mapping-policy`` / ``--tile-budget`` argparse
  surface (:func:`add_target_args` / :func:`target_from_args`).

Consumers: ``ServingEngine`` accepts a :class:`CompiledModel` (legacy
kwargs are a deprecation shim that builds a target),
``launch/serve.py`` constructs a target from its flags, the serving /
mapping benchmarks sweep over targets, and ``benchmarks/dse.py`` grids
policy x tile budget x K through :meth:`CompiledModel.price`. A future
multi-device serving path is one more target field (``mesh_axis``),
not a sixth ad-hoc knob.
"""

from repro.compiler.cli import add_target_args, target_from_args  # noqa: F401
from repro.compiler.pipeline import (  # noqa: F401
    CompiledModel,
    TargetPrice,
    compile,
    resolve_engine,
)
from repro.compiler.target import (  # noqa: F401
    GroupSizeError,
    HardwareTarget,
    PlanEngineMismatchError,
    SpecMismatchError,
    TargetError,
)
