"""Shared argparse surface for hardware targets and the request
scheduler.

``launch/serve.py`` and the benchmark drivers used to re-declare the
``--engine`` / ``--group-size`` / ``--mapping-policy`` blocks
independently (and in different orders); this module is the one place
the target flags are spelled. ``add_target_args(parser)`` installs
them, ``target_from_args(args)`` builds the
:class:`~repro.compiler.target.HardwareTarget` the rest of the stack
consumes; ``add_scheduler_args`` / ``scheduler_from_args`` do the same
for the serve-time :class:`repro.serving.SchedulerConfig` knobs
(scheduling policy, admission mode, KV reserve — deliberately separate
from the compile-time target)::

    ap = argparse.ArgumentParser()
    add_target_args(ap)
    add_scheduler_args(ap)
    args = ap.parse_args()
    compiled = compile(cfg, params, target_from_args(args))
    se = compiled.serve(scheduler=scheduler_from_args(args))
"""

from __future__ import annotations

import argparse

from repro.compiler.target import HardwareTarget


def add_target_args(
    ap: argparse.ArgumentParser, *, default_engine: str | None = "reference"
) -> argparse.ArgumentParser:
    """Install the shared hardware-target flags on a parser.

    ``default_engine=None`` leaves ``--engine`` unset by default —
    benchmark CLIs use that to mean "sweep the registry" while a passed
    flag restricts the sweep to one backend.
    """
    from repro.core import engine as engine_lib
    from repro.mapping import POLICIES

    ap.add_argument(
        "--engine",
        default=default_engine,
        # argparse-time validation: a typo'd backend fails here with the
        # registered names listed, not deep in engine construction
        choices=engine_lib.list_engines(),
        help="execution backend for binarized projections "
        "(registered in repro.core.engine)"
        + ("" if default_engine else "; default: sweep all"),
    )
    ap.add_argument(
        "--group-size",
        type=int,
        default=0,
        help="WDM K-group width for batched decode (0 = auto from the "
        "mapping plan / engine's preferred_group_size / batch)",
    )
    ap.add_argument(
        "--mapping-policy",
        default=None,
        choices=POLICIES,
        help="compile a layer->tile MappingPlan under this allocator "
        "policy and execute per it (requires --engine tiled)",
    )
    ap.add_argument(
        "--tile-budget",
        type=int,
        default=None,
        metavar="N",
        help="cap the physical tile pool the mapping plan provisions "
        "(co-resident blocks serialize; requires --engine tiled)",
    )
    ap.add_argument(
        "--raw-weights",
        action="store_true",
        help="skip the one-time crossbar-programming phase and re-run "
        "the weight-side transforms every tick (benchmark baseline)",
    )
    ap.add_argument(
        "--fault-rate",
        type=float,
        default=None,
        metavar="P",
        help="inject deterministic stuck-cell faults: per-cell "
        "probability P split evenly between stuck-SET and stuck-RESET "
        "(wraps the backend in repro.faults.FaultyEngine; requires a "
        "non-reference --engine)",
    )
    ap.add_argument(
        "--fault-seed",
        type=int,
        default=0,
        metavar="SEED",
        help="root seed of the per-tile fault RNG streams (only with "
        "--fault-rate)",
    )
    ap.add_argument(
        "--spare-tiles",
        type=int,
        default=0,
        metavar="N",
        help="provision N extra physical tiles as fault-remap "
        "destinations in the mapping plan (requires --engine tiled)",
    )
    return ap


def add_scheduler_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Install the serve-time request-scheduler flags on a parser."""
    from repro.serving.scheduler import ADMISSION_MODES, POLICIES

    ap.add_argument(
        "--sched-policy",
        default="fifo",
        choices=POLICIES,
        help="waiting-queue order: fifo (priority then submission) or "
        "deadline (earliest deadline first)",
    )
    ap.add_argument(
        "--admission",
        default="whole",
        choices=ADMISSION_MODES,
        help="KV-budget admission: whole commits prompt+max_new_tokens "
        "up front; partial admits on the prompt footprint and preempts "
        "under pressure",
    )
    ap.add_argument(
        "--kv-reserve",
        type=float,
        default=0.0,
        metavar="RATIO",
        help="fraction of the KV-token budget held back from admission "
        "(decode-growth headroom), in [0, 1]",
    )
    ap.add_argument(
        "--max-waiting",
        type=int,
        default=None,
        metavar="N",
        help="waiting-queue depth cap; submissions beyond it are "
        "rejected gracefully (default: unbounded)",
    )
    ap.add_argument(
        "--no-preempt",
        action="store_true",
        help="disable budget/priority preemption (over-budget partial "
        "pools stop admitting instead)",
    )
    return ap


def scheduler_from_args(args: argparse.Namespace):
    """Build (and validate) a SchedulerConfig from parsed
    ``add_scheduler_args`` flags."""
    from repro.serving.scheduler import SchedulerConfig

    return SchedulerConfig(
        policy=args.sched_policy,
        admission=args.admission,
        kv_reserve_ratio=args.kv_reserve,
        max_waiting=args.max_waiting,
        preempt=not getattr(args, "no_preempt", False),
    ).validate()


def add_fleet_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Install the fleet-serving flags (PR 10): replica count and
    routing policy for :class:`repro.fleet.FleetEngine`."""
    from repro.fleet.router import DEFAULT_BLOCK, ROUTING_POLICIES

    ap.add_argument(
        "--replicas",
        type=int,
        default=1,
        metavar="N",
        help="serve from a fleet of N identically-compiled replicas "
        "behind the prefix-affinity router (1 = plain single-replica "
        "serving, no fleet layer)",
    )
    ap.add_argument(
        "--routing",
        default="prefix",
        choices=ROUTING_POLICIES,
        help="fleet routing policy: prefix (longest KV-prefix match, "
        "then load), least-loaded, or round-robin (only with "
        "--replicas > 1)",
    )
    ap.add_argument(
        "--prefix-block",
        type=int,
        default=DEFAULT_BLOCK,
        metavar="TOKENS",
        help="token-block width of the router's chained prefix hashes "
        "(prefix policy only; smaller blocks match shorter shared "
        "prefixes at more index churn)",
    )
    return ap


def add_obs_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Install the shared telemetry flags (PR 8): either flag turns the
    :mod:`repro.obs` session on for the whole run."""
    ap.add_argument(
        "--trace-out",
        default=None,
        metavar="PATH",
        help="write a Chrome-trace (chrome://tracing / Perfetto) JSON of "
        "compile-stage and per-tick spans to PATH (enables telemetry)",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="PATH",
        help="write a Prometheus-style text snapshot (TTFT, tick latency, "
        "queue depth, cache counters) to PATH (enables telemetry)",
    )
    return ap


def obs_from_args(args: argparse.Namespace):
    """Start a telemetry session when any obs flag was passed; returns
    the :class:`repro.obs.Telemetry` or ``None`` (telemetry stays off).

    Call BEFORE ``compile()`` so the pipeline-stage spans are captured;
    export at the end with ``tel.write(trace_out=args.trace_out,
    metrics_out=args.metrics_out)``.
    """
    if getattr(args, "trace_out", None) or getattr(args, "metrics_out", None):
        from repro import obs

        return obs.start()
    return None


def target_from_args(args: argparse.Namespace) -> HardwareTarget:
    """Build (and statically validate) a HardwareTarget from parsed
    ``add_target_args`` flags."""
    fault_model = None
    fault_rate = getattr(args, "fault_rate", None)
    if fault_rate is not None:
        from repro.faults import FaultModel

        fault_model = FaultModel(
            seed=getattr(args, "fault_seed", 0),
            stuck_set_rate=fault_rate / 2.0,
            stuck_reset_rate=fault_rate / 2.0,
        )
    return HardwareTarget(
        engine=args.engine or "reference",
        group_size=args.group_size or None,
        mapping_policy=args.mapping_policy,
        tile_budget=args.tile_budget,
        prepare_weights=not getattr(args, "raw_weights", False),
        spare_tiles=getattr(args, "spare_tiles", 0),
        fault_model=fault_model,
    ).validate()
