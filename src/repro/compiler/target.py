"""The hardware target: every knob of the map->program->execute pipeline
in ONE frozen artifact.

Before this package, driving the stack meant hand-threading five
separately-spelled knobs in the right order — an engine name, a
``CrossbarSpec``, a mapping policy / ``MappingPlan``, a K-group width
and the prepare/cache switches — and every consumer (``ServingEngine``,
``launch/serve.py``, each benchmark) re-wired them differently.
:class:`HardwareTarget` bundles them; :func:`repro.compiler.compile`
consumes one and runs the pipeline in the canonical order.

Validation is EAGER and errors are NAMED: an inconsistent target
(a mapping policy on a non-tiled engine, a plan compiled for different
tiles than the target binds, a K-group wider than the placed tiles'
WDM capacity) fails at compile time with a
:class:`TargetError` subclass, not as a silently-dropped knob deep in
serving — the pre-redesign ``ServingEngine`` accepted
``mapping_plan=`` with ``engine="wdm"`` and quietly used it only for K.
"""

from __future__ import annotations

import dataclasses

from repro.core.crossbar import CrossbarSpec
from repro.faults.model import FaultModel, FaultModelError


class TargetError(ValueError):
    """An inconsistent or unsupported :class:`HardwareTarget`."""


class PlanEngineMismatchError(TargetError):
    """A mapping plan / policy / tile budget paired with an engine that
    does not execute placements (only ``tiled`` consumes a plan)."""


class SpecMismatchError(TargetError):
    """The target's tile spec disagrees with the plan it binds."""


class GroupSizeError(TargetError):
    """A K-group width the target's hardware cannot multiplex."""


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    """One complete description of WHERE and HOW a BNN executes.

    The paper's pipeline is map (TacitMap) -> program (oPCM write) ->
    execute (WDM streaming); a target names each stage's choice once:

    * ``engine`` — a backend registered in :mod:`repro.core.engine`
      (``reference`` | ``tacitmap`` | ``wdm`` | ``packed`` | ``tiled``
      | ``custbinarymap`` | any third-party registration).
    * ``spec`` — the crossbar tile geometry/technology; ``None`` uses
      the engine's default tile (ePCM or oPCM per its capability row).
    * ``mapping_policy`` / ``tile_budget`` — compile an explicit
      layer->tile :class:`~repro.mapping.allocator.MappingPlan` under
      this allocator policy (and optional physical-tile cap) and execute
      per it. Only meaningful for the plan-driven ``tiled`` engine.
    * ``group_size`` — explicit WDM K-group width for batched decode
      (``None`` = auto: plan WDM capacity > engine capability > one
      vmap'd group spanning the pool).
    * ``prepare_weights`` — run the one-time crossbar-programming phase
      (``lm.program_weights``) at compile time so decode streams only
      activations; ``False`` keeps the per-tick re-programming path
      (the prepared-vs-raw benchmark baseline).
    * ``mesh_axis`` — optional sharding hint: the named mesh axis the
      future multi-device serving path shards K-groups / plan tiles
      over. Recorded on the target (a mesh is one more field of the
      target, not a sixth ad-hoc knob); today only the ``tiled``
      engine's tile axis consumes it via ``distributed.hints``.
    * ``fused`` — route prepared binarized projections through the
      fused decode-tick kernel (``kernels/fused_decode.py``: binarize +
      bit-pack + XNOR + popcount + Eq. 1 affine + α/β rescale in one
      launch) on engines that support it (``packed``). ``False`` keeps
      the unfused multi-op path — the benchmark baseline. Bit-exact
      either way.
    * ``spare_tiles`` — provision that many extra physical tiles as
      fault-remap destinations in the compiled
      :class:`~repro.mapping.allocator.MappingPlan` (PR 9). Implies a
      plan, so only meaningful with the ``tiled`` engine.
    * ``fault_model`` — a :class:`repro.faults.FaultModel`: wrap the
      resolved backend in a :class:`repro.faults.FaultyEngine` that
      deterministically injects the model's stuck cells / drift / dead
      lanes / tile failures. A null model is bit-identical to the
      unwrapped engine.
    """

    engine: str = "reference"
    spec: CrossbarSpec | None = None
    mapping_policy: str | None = None
    tile_budget: int | None = None
    group_size: int | None = None
    prepare_weights: bool = True
    mesh_axis: str | None = None
    fused: bool = True
    spare_tiles: int = 0
    fault_model: FaultModel | None = None

    def __post_init__(self):
        # normalize the CLI's "0 = auto" convention to None
        if self.group_size == 0:
            object.__setattr__(self, "group_size", None)

    # -- validation ---------------------------------------------------------

    @property
    def wants_plan(self) -> bool:
        """True when this target asks for an explicit MappingPlan."""
        return (
            self.mapping_policy is not None
            or self.tile_budget is not None
            or self.spare_tiles > 0
        )

    def validate(self) -> "HardwareTarget":
        """Eager static validation (no model needed); returns self.

        :func:`repro.compiler.compile` calls this first, then adds the
        model/plan-dependent checks (spec mismatch, K vs plan capacity).
        """
        from repro.core import engine as engine_lib

        if self.engine not in engine_lib.list_engines():
            raise TargetError(
                f"unknown engine {self.engine!r}; registered: "
                f"{', '.join(engine_lib.list_engines())}"
            )
        if self.mapping_policy is not None:
            from repro.mapping import POLICIES

            if self.mapping_policy not in POLICIES:
                raise TargetError(
                    f"unknown mapping policy {self.mapping_policy!r}; "
                    f"known: {', '.join(POLICIES)}"
                )
        if self.wants_plan and self.engine != "tiled":
            raise PlanEngineMismatchError(
                f"mapping_policy/tile_budget compile a layer->tile plan for "
                f"the plan-driven 'tiled' engine, but the target's engine is "
                f"{self.engine!r} — it would silently ignore the placement. "
                f"Use engine='tiled' (or drop the mapping fields)."
            )
        if self.tile_budget is not None and self.tile_budget < 1:
            raise TargetError(
                f"tile_budget must be >= 1, got {self.tile_budget}"
            )
        if self.group_size is not None and self.group_size < 1:
            raise GroupSizeError(
                f"group_size must be >= 1 (or None for auto), got {self.group_size}"
            )
        if not self.fused and self.engine != "packed":
            raise TargetError(
                f"fused=False selects the unfused baseline of the 'packed' "
                f"engine's fused decode-tick kernel, but the target's engine "
                f"is {self.engine!r} — the knob would be silently dropped "
                "(no other engine has a fused path to disable)"
            )
        if self.spare_tiles < 0:
            raise TargetError(
                f"spare_tiles must be >= 0, got {self.spare_tiles}"
            )
        if self.fault_model is not None:
            try:
                self.fault_model.validate()
            except FaultModelError as e:
                raise TargetError(f"invalid fault_model: {e}") from e
            if self.engine == "reference":
                raise TargetError(
                    "fault_model requires a crossbar backend to wrap, but "
                    "engine='reference' executes the plain jnp math with no "
                    "engine object — pick tacitmap/wdm/packed/tiled/"
                    "custbinarymap to inject faults"
                )
        if self.mesh_axis is not None and self.engine != "tiled":
            raise TargetError(
                f"mesh_axis={self.mesh_axis!r} names the mesh axis the "
                "plan-driven 'tiled' engine shards its tile axis over, but "
                f"the target's engine is {self.engine!r} — it would silently "
                "ignore the hint (sharding K-groups across a mesh for other "
                "engines is the multi-device serving open item)"
            )
        return self

    # -- description --------------------------------------------------------

    def describe(self) -> str:
        """One line naming every pipeline choice this target pins."""
        spec = (
            "default"
            if self.spec is None
            else f"{self.spec.technology} {self.spec.rows}x{self.spec.cols}"
            + (f" K={self.spec.wdm_k}" if self.spec.wdm_k > 1 else "")
        )
        parts = [f"engine={self.engine}", f"spec={spec}"]
        if self.mapping_policy is not None:
            parts.append(f"policy={self.mapping_policy}")
        if self.tile_budget is not None:
            parts.append(f"tile_budget={self.tile_budget}")
        parts.append(f"K={'auto' if self.group_size is None else self.group_size}")
        parts.append(f"prepared={self.prepare_weights}")
        if self.engine == "packed":
            parts.append(f"fused={self.fused}")
        if self.mesh_axis is not None:
            parts.append(f"mesh_axis={self.mesh_axis}")
        if self.spare_tiles:
            parts.append(f"spares={self.spare_tiles}")
        if self.fault_model is not None:
            parts.append(
                "faults=" + self.fault_model.describe().removeprefix("[faults] ")
            )
        return "[target] " + " ".join(parts)
