"""Encoder-decoder transformer (seamless-m4t style): bidirectional
encoder over frontend embeddings (audio frames — stub per the brief),
causal decoder with per-layer cross-attention. Same scan/remat spine as
``lm.py``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (
    ACT_DTYPE,
    attention_block,
    attention_decode_step,
    attn_init,
    cross_attention_block,
    decode_attention,
    dense,
    ffn,
    ffn_init,
    infer_engine,
    rms_norm,
)

Array = jax.Array
Params = dict[str, Any]


def _init_enc_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn_init(k1, cfg),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn": ffn_init(k2, cfg),
    }


def _init_dec_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "self_attn": attn_init(k1, cfg),
        "norm_x": jnp.ones((cfg.d_model,), jnp.float32),
        "cross_attn": attn_init(k2, cfg),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn": ffn_init(k3, cfg),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    vp = cfg.padded_vocab  # tables padded for vocab-parallel sharding
    return {
        "embed": jax.random.normal(ks[2], (vp, cfg.d_model), jnp.float32) * 0.02,
        "head": jax.random.normal(ks[3], (cfg.d_model, vp), jnp.float32)
        * (1.0 / math.sqrt(cfg.d_model)),
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def encode(params: Params, src_embeds: Array, cfg: ModelConfig) -> Array:
    """Bidirectional encoder over (B, Ss, d) frontend embeddings."""
    h = src_embeds.astype(ACT_DTYPE)
    positions = jnp.arange(src_embeds.shape[1])

    def body(h, lp):
        hn = rms_norm(h, lp["norm1"], cfg.norm_eps)
        mix, _ = attention_block(lp["attn"], hn, positions, cfg, causal=False, quant=cfg.quant)
        h = h + mix
        hn = rms_norm(h, lp["norm2"], cfg.norm_eps)
        return h + ffn(lp["ffn"], hn, cfg.quant), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["enc_blocks"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp: Params, enc_out: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    b, ss, _ = enc_out.shape
    k = dense(lp["cross_attn"]["k"], enc_out, cfg.quant).reshape(b, ss, cfg.n_kv_heads, cfg.hd)
    v = dense(lp["cross_attn"]["v"], enc_out, cfg.quant).reshape(b, ss, cfg.n_kv_heads, cfg.hd)
    return k, v


def decoder(params: Params, enc_out: Array, tgt_tokens: Array, cfg: ModelConfig) -> Array:
    """Training decoder pass -> (B, St, d) hidden states."""
    h = params["embed"][tgt_tokens].astype(ACT_DTYPE)
    positions = jnp.arange(tgt_tokens.shape[1])

    def body(h, lp):
        hn = rms_norm(h, lp["norm1"], cfg.norm_eps)
        mix, _ = attention_block(lp["self_attn"], hn, positions, cfg, quant=cfg.quant)
        h = h + mix
        hn = rms_norm(h, lp["norm_x"], cfg.norm_eps)
        kv = _cross_kv(lp, enc_out, cfg)
        h = h + cross_attention_block(lp["cross_attn"], hn, kv, positions, cfg, cfg.quant)
        hn = rms_norm(h, lp["norm2"], cfg.norm_eps)
        return h + ffn(lp["ffn"], hn, cfg.quant), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["dec_blocks"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig, aux_coef: float = 0.0) -> Array:
    """batch = {src_embeds (B,Ss,d), tokens (B,St)} — next-token loss."""
    from repro.models.lm import lm_loss  # shared chunked loss

    enc_out = encode(params, batch["src_embeds"], cfg)
    hidden = decoder(params, enc_out, batch["tokens"], cfg)
    tokens = batch["tokens"]
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.full((tokens.shape[0], 1), -1, tokens.dtype)], axis=1
    )

    class _Cfg:  # lm_loss reads head/tying, chunking and vocab fields
        tie_embeddings = False
        loss_chunk = cfg.loss_chunk
        vocab_size = cfg.vocab_size
        padded_vocab = cfg.padded_vocab

    return lm_loss({"head": params["head"]}, hidden, targets, _Cfg)


def prefill(params: Params, src_embeds: Array, tgt_tokens: Array, cfg: ModelConfig):
    """Encode src, run decoder over the prompt, return (logits, caches).

    caches = {self: stacked (L,B,St,KV,hd) k/v, cross: stacked k/v over
    the full encoder output, used read-only during decode}.
    """
    enc_out = encode(params, src_embeds, cfg)
    positions = jnp.arange(tgt_tokens.shape[1])
    h = params["embed"][tgt_tokens].astype(ACT_DTYPE)
    eng = infer_engine(cfg)  # binarized projections run on cfg.bnn_engine

    def body(h, lp):
        hn = rms_norm(h, lp["norm1"], cfg.norm_eps)
        mix, (k, v) = attention_block(
            lp["self_attn"], hn, positions, cfg, quant=cfg.quant, engine=eng
        )
        h = h + mix
        hn = rms_norm(h, lp["norm_x"], cfg.norm_eps)
        ck, cv = _cross_kv(lp, enc_out, cfg)
        h = h + cross_attention_block(
            lp["cross_attn"], hn, (ck, cv), positions, cfg, cfg.quant, eng
        )
        hn = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + ffn(lp["ffn"], hn, cfg.quant, eng)
        cache = {
            "self_k": k.astype(ACT_DTYPE),
            "self_v": v.astype(ACT_DTYPE),
            "cross_k": ck.astype(ACT_DTYPE),
            "cross_v": cv.astype(ACT_DTYPE),
        }
        return h, cache

    h, caches = jax.lax.scan(body, h, params["dec_blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :].astype(jnp.float32), params["head"])
    from repro.models.lm import _mask_padded_vocab

    return _mask_padded_vocab(logits, cfg), caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int, dtype=ACT_DTYPE):
    l = cfg.n_layers
    kv = (l, batch, max_len, cfg.n_kv_heads, cfg.hd)
    cross = (l, batch, src_len, cfg.n_kv_heads, cfg.hd)
    return {
        "self_k": jnp.zeros(kv, dtype),
        "self_v": jnp.zeros(kv, dtype),
        "cross_k": jnp.zeros(cross, dtype),
        "cross_v": jnp.zeros(cross, dtype),
    }


def decode_step(params: Params, token: Array, pos: Array, caches: dict, cfg: ModelConfig):
    """One decoder step with fixed cross-KV. token (B,), pos scalar."""
    b = token.shape[0]
    h = params["embed"][token[:, None]].astype(ACT_DTYPE)
    eng = infer_engine(cfg)  # binarized projections run on cfg.bnn_engine

    def body(h, xs):
        lp, cache_l = xs
        hn = rms_norm(h, lp["norm1"], cfg.norm_eps)
        mix, nk, nv = attention_decode_step(
            lp["self_attn"], hn, pos, cache_l["self_k"], cache_l["self_v"], cfg,
            quant=cfg.quant, engine=eng,
        )
        h = h + mix
        hn = rms_norm(h, lp["norm_x"], cfg.norm_eps)
        q = dense(lp["cross_attn"]["q"], hn, cfg.quant, eng).reshape(b, 1, cfg.n_heads, cfg.hd)
        src_len = cache_l["cross_k"].shape[1]
        cross = decode_attention(
            q, cache_l["cross_k"], cache_l["cross_v"], jnp.full((b,), src_len, jnp.int32)
        )
        h = h + dense(lp["cross_attn"]["o"], cross.reshape(b, 1, cfg.n_heads * cfg.hd), cfg.quant, eng)
        hn = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + ffn(lp["ffn"], hn, cfg.quant, eng)
        new_cache = dict(cache_l, self_k=nk, self_v=nv)
        return h, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["dec_blocks"], caches))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0, :].astype(jnp.float32), params["head"])
    from repro.models.lm import _mask_padded_vocab

    return _mask_padded_vocab(logits, cfg), new_caches
