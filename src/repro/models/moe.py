"""Mixture-of-Experts FFN: top-k routing with capacity-bounded scatter
dispatch (Switch/GShard style).

Design notes for the 1000-node posture:

* No (S, E, C) one-hot dispatch tensor — at 1M tokens x 128 experts that
  is astronomically large. Instead tokens scatter into a dense
  (B, E, C, d) expert buffer via per-row ``.at[].add`` (XLA lowers to a
  sort-based scatter), keeping the biggest intermediate at
  S·k·capacity_factor token slots — the same asymptotics as the real
  top-k compute.
* The (B, S, E) router tensors shard over (batch=data, experts=model);
  position-in-expert uses an fp32 cumsum (exact for S·k < 2^24).
* Tokens over capacity are dropped (contribute zero) — standard; the
  auxiliary load-balancing loss keeps the drop rate low in training.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.hints import hint
from repro.models.config import ModelConfig
from repro.models.layers import ACT_DTYPE, dense_init

Array = jax.Array
Params = dict[str, Any]


def moe_init(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    s1, s2 = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s1,
        "w1": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s1,
        "w3": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s1,
        "w2": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s2,
    }


def capacity(cfg: ModelConfig, seq: int) -> int:
    return max(1, math.ceil(seq * cfg.moe_top_k / cfg.moe_experts * cfg.moe_capacity_factor))


def _dispatch_row(x_rep: Array, e_idx: Array, slot: Array, keep: Array, e: int, c: int) -> Array:
    """One batch row: scatter (S*k, d) token copies into (E, C, d)."""
    buf = jnp.zeros((e, c, x_rep.shape[-1]), x_rep.dtype)
    upd = x_rep * keep[:, None].astype(x_rep.dtype)
    return buf.at[e_idx, slot].add(upd, mode="drop")


def _combine_row(expert_out: Array, e_idx: Array, slot: Array, keep: Array) -> Array:
    """Gather (S*k, d) results back out of (E, C, d)."""
    got = expert_out[e_idx, slot]
    return got * keep[:, None].astype(got.dtype)


def moe_ffn(p: Params, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """(B, S, d) -> (B, S, d), plus the load-balancing aux loss.

    Routing/renormalized gates follow Mixtral/Qwen-MoE: softmax over all
    experts, take top-k, renormalize the k gates.
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    c = capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, top_idx = jax.lax.top_k(probs, k)                  # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # position of each (token, expert) assignment within its expert
    flat_e = top_idx.reshape(b, s * k)                        # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)     # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1.0                    # fp32 exact < 2^24
    slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)   # (B, S*k)
    keep = slot < c

    x_rep = jnp.repeat(x, k, axis=1)                          # (B, S*k, d)
    expert_in = jax.vmap(_dispatch_row, in_axes=(0, 0, 0, 0, None, None))(
        x_rep, flat_e, slot, keep, e, c
    )                                                         # (B, E, C, d)
    # EP anchor: expert dim over the model axis (the scatter above
    # becomes the all-to-all dispatch); falls back to ffn-dim TP inside
    # the einsums when E doesn't divide (grok's 8 experts on tp=16).
    # NOTE deliberately NOT strict: in FSDP/ZeRO-3 mode (batch owns the
    # model axis) forcing EP here makes XLA SPMD replicate the dispatch
    # instead of emitting an all-to-all (measured 47 -> 542 GiB/dev on
    # qwen3 train — EXPERIMENTS.md §Perf); the graceful degradation
    # (ZeRO weight-gather per MoE layer) is the better SPMD-expressible
    # layout, and a hand-written shard_map EP dispatch is the documented
    # path beyond it.
    expert_in = hint(expert_in, "dp", "model", None, None)

    h = jnp.einsum("becd,edf->becf", expert_in, p["w1"].astype(expert_in.dtype))
    g = jnp.einsum("becd,edf->becf", expert_in, p["w3"].astype(expert_in.dtype))
    # (f-dim TP in the fallback case propagates from the weight specs)
    h = hint(jax.nn.silu(h.astype(jnp.float32)).astype(ACT_DTYPE) * g.astype(ACT_DTYPE),
             "dp", "model", None, None)
    out_e = hint(jnp.einsum("becf,efd->becd", h, p["w2"].astype(h.dtype)),
                 "dp", "model", None, None)

    y_rep = jax.vmap(_combine_row)(out_e, flat_e, slot, keep)  # (B, S*k, d)
    y = (y_rep.reshape(b, s, k, d) * gates[..., None].astype(y_rep.dtype)).sum(axis=2)

    # GShard load-balance loss: E * Σ_e f_e * P_e
    frac = jnp.mean(onehot.reshape(b, s, k, e).sum(2), axis=(0, 1))  # tokens/expert
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_prob)
    return y.astype(x.dtype), aux
