"""Core transformer layers: RMSNorm, RoPE, GQA attention (flash-style
chunked), SwiGLU — pure JAX, scan/remat-friendly, with the paper's BNN
quantization available on every projection (``quant="bnn"``).

Conventions: activations bf16, accumulations/normalizations fp32,
params fp32. All attention shapes are (B, S, H, D).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import bnn
from repro.distributed.hints import hint
from repro.models.config import ModelConfig

Array = jax.Array
Params = dict[str, Any]

ACT_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, d_in: int, d_out: int, bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (1.0 / math.sqrt(d_in))
    p: Params = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    """RMSNorm with a hand-written VJP.

    Why custom: the autodiff residual of the naive version is the fp32
    upcast of x — and JAX saves that fp32 copy per layer *in addition
    to* the bf16 carry under scan (measured: a second (L, B, S, d) fp32
    residual stack, 10 GiB/device on qwen2-72b train). This VJP saves
    only the bf16 x and recomputes the fp32 statistics in the backward.
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _rms_norm_fwd(x: Array, scale: Array, eps: float):
    return rms_norm(x, scale, eps), (x, scale)


def _rms_norm_bwd(eps: float, res, g):
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xf * inv
    d_scale = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    gs = gf * scale.astype(jnp.float32)
    # d/dx of x*inv: inv * (gs - xhat * mean(gs * xhat))
    dx = inv * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), d_scale.astype(scale.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def infer_engine(cfg: ModelConfig, plan=None):
    """Resolve ``cfg.bnn_engine`` into an execution backend for the
    binarized projections of the *inference* paths (prefill/decode).

    Returns ``None`` for the reference backend: the plain matmul below
    is both the reference numerics and the only differentiable (STE)
    path, so training always goes through it.

    ``plan`` (a ``repro.mapping.allocator.MappingPlan``) binds the
    ``tiled`` backend to a compiled layer->tile placement; without one
    the engine places each projection on the fly under
    ``cfg.mapping_policy``. Other backends ignore the plan (their layout
    is implicit in the backend itself).
    """
    if cfg.quant != "bnn" or cfg.bnn_engine in ("", "reference"):
        return None
    from repro.core import engine as engine_lib

    if cfg.bnn_engine == "tiled":
        return engine_lib.get_engine(
            "tiled", plan=plan, policy=cfg.mapping_policy or "tacitmap"
        )
    return engine_lib.get_engine(cfg.bnn_engine)


def _require_latent(p: Params, w, engine) -> None:
    """Programmed projections carry only the engine artifact: reaching a
    path that needs the latent weights is a caller error — fail with the
    reason instead of a NoneType crash deep inside a scan."""
    if w is None:
        prepared = p.get("prepared")
        programmed_for = getattr(prepared, "engine", "<unknown>")
        raise ValueError(
            f"projection was programmed for engine {programmed_for!r} "
            "(lm.program_weights replaced the latent 'w' with 'prepared'/"
            "'alpha'); run it through that engine with quant='bnn', or use "
            f"the original un-programmed params (engine passed: {engine!r})"
        )


def dense(p: Params, x: Array, quant: str = "none", engine=None) -> Array:
    """Linear layer; ``quant="bnn"`` routes through the paper's BitLinear:
    sign-binarized weights/activations (STE in training) with a
    per-tensor weight scale and a per-token activation scale — first/last
    layers of a model never use it (§II-B).

    The activation scale is per-token (mean |x| along the feature axis)
    so every batch row's output depends only on that row: continuous
    batching and the serving engine's K-group gather (which may repeat
    rows in ragged tails) are then semantically invisible. A per-tensor
    activation scale would couple rows through the batch mean.

    ``engine`` (a ``repro.core.engine.Engine``) executes the ±1 matmul
    through any registered backend — e.g. the packed XNOR+popcount
    Pallas kernel. Engines are bit-exact vs the plain matmul but not
    differentiable; inference callers resolve one via ``infer_engine``.

    Two-phase execution: when ``p`` carries a programmed projection
    (``p["prepared"]``/``p["alpha"]`` from ``lm.program_weights`` — the
    crossbar-programming phase) and an engine is bound, the weight-side
    transforms are skipped entirely and only activations stream.
    Otherwise the engine's per-instance ``WeightCache`` memoizes the
    programming on the latent param's identity (concrete arrays only —
    tracers prepare inline, exactly the pre-PR-4 graph).
    """
    w = p.get("w")  # absent on programmed projections (prepared replaces it)
    if quant == "bnn":
        pw = p.get("prepared") if engine is not None else None
        if pw is not None and getattr(engine, "supports_fused_dense", False):
            # Fused decode-tick path: binarize + bit-pack + XNOR +
            # popcount + Eq. 1 affine + α/β rescale in ONE kernel launch
            # (kernels/fused_decode.py) — the raw activation block
            # crosses HBM exactly once. Bit-exact vs the unfused chain
            # below; engines advertise it via ``supports_fused_dense``.
            out = engine.fused_dense(x, pw, p["alpha"]).astype(ACT_DTYPE)
        else:
            beta = jnp.mean(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True)
            xb = bnn.binarize_ste(x.astype(jnp.float32))
            if pw is not None:
                alpha = p["alpha"]
                dot = engine.binary_vmm(xb, pw).astype(jnp.float32)
            else:
                _require_latent(p, w, engine)
                alpha = jnp.mean(jnp.abs(w)).astype(jnp.float32)
                if engine is None:
                    dot = xb @ bnn.binarize_ste(w)
                elif hasattr(engine, "prepare_cached"):
                    # lazy: binarization runs only on a weight-cache miss
                    wx = engine.prepare_cached(lambda: bnn.binarize_ste(w), key=w)
                    dot = engine.binary_vmm(xb, wx).astype(jnp.float32)
                else:
                    dot = engine.binary_vmm(xb, bnn.binarize_ste(w)).astype(jnp.float32)
            out = (dot * (alpha * beta)).astype(ACT_DTYPE)
    else:
        _require_latent(p, w, engine)
        out = jnp.matmul(x, w.astype(x.dtype))
    if "b" in p:
        out = out + p["b"].astype(out.dtype)
    return out


def fused_qkv_dense(p_attn: Params, x: Array, cfg: ModelConfig, quant: str, engine):
    """Shared-activation QKV fusion: one fused kernel over the
    concatenated ``[q|k|v]`` prepared weights instead of three.

    q/k/v all consume the same attention input, so the unfused path
    binarizes and bit-packs that block three times. When
    ``lm.program_weights`` has attached the derived ``qkv`` artifact
    (the three sign matrices concatenated along the output axis before
    packing) and the engine supports fused dense, the input streams
    through ONE kernel launch and the output splits at the static head
    boundaries. Column j of the fused kernel depends only on weight
    column j, so the split halves are bit-identical to three separate
    calls. Returns (q, k, v) pre-reshape activations, or ``None`` when
    the fused artifact/capability is absent (callers fall back to three
    ``dense`` calls).
    """
    fused = p_attn.get("qkv")
    if (
        quant != "bnn"
        or fused is None
        or engine is None
        or not getattr(engine, "supports_fused_dense", False)
    ):
        return None
    out = engine.fused_dense(x, fused["prepared"], fused["alpha"]).astype(ACT_DTYPE)
    nq = cfg.n_heads * cfg.hd
    nkv = cfg.n_kv_heads * cfg.hd
    parts = (out[..., :nq], out[..., nq : nq + nkv], out[..., nq + nkv :])
    outs = []
    for name, o in zip(("q", "k", "v"), parts):
        b = p_attn[name].get("b")
        outs.append(o + b.astype(o.dtype) if b is not None else o)
    return tuple(outs)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x (B, S, H, D), positions (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_init(key: jax.Array, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "q": dense_init(ks[0], d, cfg.n_heads * hd, cfg.qkv_bias),
        "k": dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "v": dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "o": dense_init(ks[3], cfg.n_heads * hd, d),
    }


def _flash_body(q, kc, vc, qpos, kpos, carry, scale, causal, head_map):
    """One KV-chunk step of the streaming-softmax attention.

    q (B,Sq,H,D); kc/vc (B,C,KV,D); carry = (m, l, acc) with
    m,l (B,H,Sq) and acc (B,H,Sq,D). GQA is handled by gathering each
    head's KV *per chunk* (``head_map`` (H,) -> kv index): the gathered
    (B,C,H,D) chunk is tiny, and — unlike a (KV, G) reshape of the head
    dim — every tensor here keeps a plain H axis, which shards cleanly
    over the model axis under SPMD (H % tp == 0 covers the big archs).
    """
    m, l, acc = carry
    kh = jnp.take(kc, head_map, axis=2)  # (B,C,H,D)
    vh = jnp.take(vc, head_map, axis=2)
    s = jnp.einsum("bqhd,bchd->bhqc", q.astype(jnp.float32), kh.astype(jnp.float32)) * scale
    if causal:
        mask = kpos[None, :] <= qpos[:, None]  # (Sq, C)
        s = jnp.where(mask[None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    if causal:
        p = jnp.where(mask[None, None], p, 0.0)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    # p in bf16 for the AV contraction: halves the probability-tensor
    # HBM traffic (the dominant memory-roofline component at 32k
    # prefill) and feeds the MXU natively; l/m corrections stay fp32.
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhqc,bchd->bhqd", p.astype(jnp.bfloat16), vh.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def multi_head_attention(
    q: Array,
    k: Array,
    v: Array,
    q_positions: Array,
    kv_positions: Array,
    *,
    causal: bool,
    chunk: int,
    impl: str = "jnp",
) -> Array:
    """Flash-style chunked attention: O(S·C) live memory, fp32 softmax.

    q (B, Sq, H, D); k/v (B, Skv, KV, D); positions (S,)-shaped (shared
    across batch). Returns (B, Sq, H, D) in q.dtype.

    ``impl="pallas"`` routes through the fused VMEM-resident kernel
    (kernels/flash_attention.py) — contiguous positions only (the model
    paths always are); the jnp path remains the lowering-anywhere
    reference.
    """
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), causal=causal
        )
        return out.swapaxes(1, 2)
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    head_map = jnp.arange(h, dtype=jnp.int32) // g  # head -> kv head

    n_chunks = math.ceil(skv / chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=2**30)
        causal = True  # padded keys must be masked out via positions

    kcs = k.reshape(b, n_chunks, chunk, kvh, d).swapaxes(0, 1)
    vcs = v.reshape(b, n_chunks, chunk, kvh, d).swapaxes(0, 1)
    pcs = kv_positions.reshape(n_chunks, chunk)

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)

    @jax.checkpoint
    def step(carry, xs):
        # checkpointed (flash-style): backward recomputes this chunk's
        # scores/probabilities from (q, kc, vc, m, l) instead of saving
        # the (B, H, Sq, C) probability + mask tensors per chunk.
        kc, vc, kpos = xs
        return _flash_body(q, kc, vc, q_positions, kpos, carry, scale, causal, head_map), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kcs, vcs, pcs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, cache_len: Array
) -> Array:
    """Single-token attention against a (B, T, KV, D) cache.

    ``cache_len`` masks positions >= current length. q (B, 1, H, D).
    GQA via the grouped einsum (no repeat: the cache is the big operand
    and stays KV-shaped; T shards over the model axis and the softmax
    reductions psum — sequence-parallel decode).
    """
    b, _, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = s / math.sqrt(d)
    mask = jnp.arange(t)[None, :] < cache_len[:, None]  # (B, T)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention_block(
    p: Params,
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    quant: str = "none",
    engine=None,
) -> tuple[Array, tuple[Array, Array]]:
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    b, s, _ = x.shape
    hd = cfg.hd
    qkv = fused_qkv_dense(p, x, cfg, quant, engine)
    if qkv is None:
        qkv = (
            dense(p["q"], x, quant, engine),
            dense(p["k"], x, quant, engine),
            dense(p["v"], x, quant, engine),
        )
    # hints pin head-parallel attention over the model axis (dropped
    # per-dim when indivisible — e.g. tinyllama's 4 KV heads on tp=16)
    q = hint(qkv[0].reshape(b, s, cfg.n_heads, hd), "dp", None, "model", None)
    k = hint(qkv[1].reshape(b, s, cfg.n_kv_heads, hd), "dp", None, "model", None)
    v = hint(qkv[2].reshape(b, s, cfg.n_kv_heads, hd), "dp", None, "model", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = multi_head_attention(
        q, k, v, positions, positions, causal=causal, chunk=cfg.attn_chunk,
        impl=cfg.attn_impl,
    )
    out = hint(out, "dp", None, "model", None)
    out = dense(p["o"], out.reshape(b, s, cfg.n_heads * hd), quant, engine)
    return out, (k, v)


def attention_continue(
    p: Params,
    x: Array,
    positions: Array,
    prefix_k: Array,
    prefix_v: Array,
    cfg: ModelConfig,
    *,
    quant: str = "none",
    engine=None,
) -> tuple[Array, tuple[Array, Array]]:
    """Prefill continuation over a grafted KV prefix (prefix caching).

    ``x`` holds the suffix positions ``positions`` (absolute, starting
    at the prefix length); ``prefix_k``/``prefix_v`` are the cached
    rows for positions ``[0, start)`` taken from an earlier prefill of
    the same token prefix. Returns (out, (k, v)) where k/v cover only
    the suffix — the caller concatenates them after the prefix rows.

    Bit-exactness with a from-scratch prefill is load-bearing (the
    serving prefix-graft invariant) and holds for two reasons:

    * prefill KV rows are prompt-length-invariant — causal masking in
      :func:`multi_head_attention` zeroes future contributions *exactly*
      (``p = where(mask, p, 0)``), so a shared prefix's cached rows are
      bit-identical whatever followed it in the donor prompt;
    * the suffix runs through the SAME streaming-softmax graph a full
      prefill uses (not :func:`decode_attention`, whose
      normalize-then-contract order rounds differently in bf16).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    qkv = fused_qkv_dense(p, x, cfg, quant, engine)
    if qkv is None:
        qkv = (
            dense(p["q"], x, quant, engine),
            dense(p["k"], x, quant, engine),
            dense(p["v"], x, quant, engine),
        )
    q = hint(qkv[0].reshape(b, s, cfg.n_heads, hd), "dp", None, "model", None)
    k = hint(qkv[1].reshape(b, s, cfg.n_kv_heads, hd), "dp", None, "model", None)
    v = hint(qkv[2].reshape(b, s, cfg.n_kv_heads, hd), "dp", None, "model", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # rope returns the input dtype, so cached bf16 prefix rows and the
    # fresh suffix rows concatenate without a lossy cast
    k_full = jnp.concatenate([prefix_k.astype(k.dtype), k], axis=1)
    v_full = jnp.concatenate([prefix_v.astype(v.dtype), v], axis=1)
    kv_positions = jnp.arange(k_full.shape[1])
    out = multi_head_attention(
        q, k_full, v_full, positions, kv_positions, causal=True,
        chunk=cfg.attn_chunk, impl=cfg.attn_impl,
    )
    out = hint(out, "dp", None, "model", None)
    out = dense(p["o"], out.reshape(b, s, cfg.n_heads * hd), quant, engine)
    return out, (k, v)


def cross_attention_block(
    p: Params,
    x: Array,
    kv: tuple[Array, Array],
    positions: Array,
    cfg: ModelConfig,
    quant: str = "none",
    engine=None,
) -> Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    hd = cfg.hd
    k, v = kv
    q = dense(p["q"], x, quant, engine).reshape(b, s, cfg.n_heads, hd)
    src_pos = jnp.arange(k.shape[1])
    out = multi_head_attention(
        q, k, v, positions, src_pos, causal=False, chunk=cfg.attn_chunk,
        impl=cfg.attn_impl,
    )
    return dense(p["o"], out.reshape(b, s, cfg.n_heads * hd), quant, engine)


def attention_decode_step(
    p: Params,
    x: Array,
    pos: Array,
    cache_k: Array,
    cache_v: Array,
    cfg: ModelConfig,
    quant: str = "none",
    engine=None,
) -> tuple[Array, Array, Array]:
    """One-token step. x (B, 1, d); pos scalar int32 OR (B,) per-slot
    positions (continuous batching); caches (B, T, KV, D).

    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    hd = cfg.hd
    qkv = fused_qkv_dense(p, x, cfg, quant, engine)
    if qkv is None:
        qkv = (
            dense(p["q"], x, quant, engine),
            dense(p["k"], x, quant, engine),
            dense(p["v"], x, quant, engine),
        )
    q = hint(qkv[0].reshape(b, 1, cfg.n_heads, hd), "dp", None, "model", None)
    k = qkv[1].reshape(b, 1, cfg.n_kv_heads, hd)
    v = qkv[2].reshape(b, 1, cfg.n_kv_heads, hd)
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    posb = pos_vec[:, None]
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    rows = jnp.arange(b)
    cache_k = cache_k.at[rows, pos_vec].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[rows, pos_vec].set(v[:, 0].astype(cache_v.dtype))
    out = decode_attention(q, cache_k, cache_v, pos_vec + 1)
    out = dense(p["o"], out.reshape(b, 1, cfg.n_heads * hd), quant, engine)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def ffn_init(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": dense_init(ks[0], d, f),
        "w3": dense_init(ks[1], d, f),
        "w2": dense_init(ks[2], f, d),
    }


def ffn(p: Params, x: Array, quant: str = "none", engine=None) -> Array:
    h = jax.nn.silu(dense(p["w1"], x, quant, engine).astype(jnp.float32)).astype(x.dtype)
    h = hint(h * dense(p["w3"], x, quant, engine), "dp", None, "model")
    return dense(p["w2"], h, quant, engine)
