"""Mamba-2 (SSD — state-space duality) mixer, TPU-native.

The SSD formulation is chosen deliberately (DESIGN.md §3): the chunked
algorithm turns the selective-scan recurrence into *matmuls* — intra-
chunk (Q x Q) attention-like blocks and inter-chunk state carries — so
the MXU does the heavy lifting, vs. the GPU kernel's warp-level scan.
Chunks map onto the 128-lane register file (Q=128/256); all decays are
computed in fp32.

Sharding note: the input projections are deliberately UNFUSED (z / x /
B / C / dt as separate weights) so each output dim shards cleanly over
the model axis — a fused ``in_proj`` would make the z/x/B/C slice
boundaries cross shard boundaries and force XLA to reshard (all-gather)
every layer. With the unfused layout, x/z/dt shard on d_inner (head-
parallel SSD), B/C (tiny, ``groups*state`` wide) replicate, and
``out_proj`` contracts over the sharded d_inner with one psum — the
Megatron pattern, adapted to SSM.

Shapes: x (B, S, H, P); dt (B, S, H); A (H,); B/C (B, S, G, N) with
heads grouped G | H (multi-value attention analogy from the paper).

Used by ``mamba2-2.7b`` (pure SSM stack) and Jamba's mamba layers.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.hints import hint
from repro.models.config import ModelConfig
from repro.models.layers import ACT_DTYPE, dense_init, rms_norm

Array = jax.Array
Params = dict[str, Any]

SSD_CHUNK = 256


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def mamba_init(key: jax.Array, cfg: ModelConfig) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        # unfused projections (see module docstring for why)
        "z_proj": dense_init(ks[0], d, di),
        "x_proj": dense_init(ks[1], d, di),
        "b_proj": dense_init(ks[2], d, g * n),
        "c_proj": dense_init(ks[3], d, g * n),
        "dt_proj": dense_init(ks[4], d, h),
        # depthwise causal conv, split to match the unfused channels
        "conv_x": jax.random.normal(ks[5], (cfg.ssm_conv, di), jnp.float32)
        * (1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jax.random.normal(ks[6], (cfg.ssm_conv, g * n), jnp.float32)
        * (1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_c": jax.random.normal(ks[7], (cfg.ssm_conv, g * n), jnp.float32)
        * (1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_bias_x": jnp.zeros((di,), jnp.float32),
        "conv_bias_b": jnp.zeros((g * n,), jnp.float32),
        "conv_bias_c": jnp.zeros((g * n,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 1e-2, jnp.float32))),  # softplus^-1
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv1d(x: Array, w: Array, b: Array) -> Array:
    """(B, S, C) depthwise causal conv, kernel (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # small static K (4): unrolled shifts beat conv_general here
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i]
    return (out + b).astype(x.dtype)


def conv_step(state: Array, xt: Array, w: Array, b: Array) -> tuple[Array, Array]:
    """Decode: state (B, K-1, C), xt (B, C) -> (new_state, yt)."""
    window = jnp.concatenate([state, xt[:, None, :]], axis=1)  # (B, K, C)
    yt = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w) + b
    return window[:, 1:, :], yt.astype(xt.dtype)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: Array, dt: Array, a: Array, b_mat: Array, c_mat: Array, chunk: int = SSD_CHUNK
) -> tuple[Array, Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, q, g, n)
    cc = c_mat.reshape(bsz, nc, q, g, n)

    da = dtc * a  # (B,nc,Q,H), a < 0
    cum = jnp.cumsum(da, axis=2)

    # --- intra-chunk (diagonal blocks): attention-like QxQ matmuls ------
    ci = cum.transpose(0, 1, 3, 2)  # (B,nc,H,Q)
    l_mat = jnp.exp(ci[..., :, None] - ci[..., None, :])
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri, l_mat, 0.0)
    cb = jnp.einsum("bnqgs,bnkgs->bngqk", cc.astype(jnp.float32), bc.astype(jnp.float32))
    cb = jnp.repeat(cb, rep, axis=2)  # groups -> heads (B,nc,H,Q,Q)
    m = cb * l_mat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bnhqk,bnkhp->bnqhp", m, xc.astype(jnp.float32))

    # --- chunk end-states ------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    xw = xc.astype(jnp.float32) * (dtc * decay_to_end)[..., None]
    bh = jnp.repeat(bc, rep, axis=3)  # (B,nc,Q,H,N)
    states = jnp.einsum("bnkhs,bnkhp->bnhsp", bh.astype(jnp.float32), xw)

    # --- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def scan_fn(hprev, inp):
        st, dec = inp
        return st + hprev * dec[..., None, None], hprev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    final, hprevs = jax.lax.scan(
        scan_fn, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    hprevs = hprevs.swapaxes(0, 1)  # (B,nc,H,N,P)

    # --- off-diagonal contribution ---------------------------------------
    ch = jnp.repeat(cc, rep, axis=3)  # (B,nc,Q,H,N)
    y_off = jnp.einsum("bnqhs,bnhsp->bnqhp", ch.astype(jnp.float32), hprevs)
    y_off = y_off * jnp.exp(cum)[..., None]

    y = (y_diag + y_off).reshape(bsz, nc * q, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_step(
    state: Array, xt: Array, dtt: Array, a: Array, bt: Array, ct: Array
) -> tuple[Array, Array]:
    """One decode step. state (B,H,N,P); xt (B,H,P); dtt (B,H);
    bt/ct (B,G,N). Returns (new_state, yt (B,H,P))."""
    h, g = xt.shape[1], bt.shape[1]
    rep = h // g
    decay = jnp.exp(dtt.astype(jnp.float32) * a)  # (B,H)
    bh = jnp.repeat(bt, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    upd = jnp.einsum("bhs,bhp->bhsp", bh, xt.astype(jnp.float32) * dtt[..., None])
    new_state = state * decay[..., None, None] + upd
    ch = jnp.repeat(ct, rep, axis=1).astype(jnp.float32)
    yt = jnp.einsum("bhs,bhsp->bhp", ch, new_state)
    return new_state, yt.astype(xt.dtype)


# ---------------------------------------------------------------------------
# Full mixer block
# ---------------------------------------------------------------------------


def _project(p: Params, u: Array, name: str) -> Array:
    return jnp.matmul(u, p[name]["w"].astype(u.dtype))


def mamba_block(p: Params, u: Array, cfg: ModelConfig) -> tuple[Array, dict]:
    """Full-sequence mamba2 mixer. u (B, S, d) -> (out, cache_state)."""
    bsz, s, _ = u.shape
    z = hint(_project(p, u, "z_proj"), "dp", None, "model")
    x_pre = hint(_project(p, u, "x_proj"), "dp", None, "model")
    b_pre = _project(p, u, "b_proj")
    c_pre = _project(p, u, "c_proj")
    dt = hint(_project(p, u, "dt_proj"), "dp", None, "model")
    xc = causal_conv1d(x_pre, p["conv_x"], p["conv_bias_x"])
    bcv = causal_conv1d(b_pre, p["conv_b"], p["conv_bias_b"])
    ccv = causal_conv1d(c_pre, p["conv_c"], p["conv_bias_c"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(ACT_DTYPE)
    bcv = jax.nn.silu(bcv.astype(jnp.float32)).astype(ACT_DTYPE)
    ccv = jax.nn.silu(ccv.astype(jnp.float32)).astype(ACT_DTYPE)
    x = hint(xc.reshape(bsz, s, cfg.ssm_heads, cfg.ssm_head_dim), "dp", None, "model", None)
    b_mat = bcv.reshape(bsz, s, cfg.ssm_groups, cfg.ssm_state)
    c_mat = ccv.reshape(bsz, s, cfg.ssm_groups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(x, dt, a, b_mat, c_mat)
    y = hint(y, "dp", None, "model", None)
    y = y + x.astype(jnp.float32).astype(y.dtype) * p["D"][:, None].astype(y.dtype)
    y = y.reshape(bsz, s, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)  # gated
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.matmul(y, p["out_proj"]["w"].astype(y.dtype))
    # decode-ready cache: last (K-1) PRE-conv channel values per stream
    tail = cfg.ssm_conv - 1

    def _tail(t: Array) -> Array:
        tt = t[:, -tail:, :]
        pad_t = tail - tt.shape[1]
        if pad_t > 0:
            tt = jnp.pad(tt, ((0, 0), (pad_t, 0), (0, 0)))
        return tt.astype(ACT_DTYPE)

    cache = {
        "conv_x": _tail(x_pre),
        "conv_b": _tail(b_pre),
        "conv_c": _tail(c_pre),
        "ssm": final.astype(jnp.float32),
    }
    return out, cache


def mamba_step(p: Params, ut: Array, cache: dict, cfg: ModelConfig) -> tuple[Array, dict]:
    """One-token mamba2 step. ut (B, 1, d); cache {conv_*, ssm}."""
    bsz = ut.shape[0]
    u = ut[:, 0, :]
    z = jnp.matmul(u, p["z_proj"]["w"].astype(u.dtype))
    x_pre = jnp.matmul(u, p["x_proj"]["w"].astype(u.dtype))
    b_pre = jnp.matmul(u, p["b_proj"]["w"].astype(u.dtype))
    c_pre = jnp.matmul(u, p["c_proj"]["w"].astype(u.dtype))
    dt = jnp.matmul(u, p["dt_proj"]["w"].astype(u.dtype))
    cx, xt = conv_step(cache["conv_x"], x_pre, p["conv_x"], p["conv_bias_x"])
    cb, bt = conv_step(cache["conv_b"], b_pre, p["conv_b"], p["conv_bias_b"])
    cc, ct = conv_step(cache["conv_c"], c_pre, p["conv_c"], p["conv_bias_c"])
    xt = jax.nn.silu(xt.astype(jnp.float32)).astype(ACT_DTYPE)
    bt = jax.nn.silu(bt.astype(jnp.float32)).astype(ACT_DTYPE)
    ct = jax.nn.silu(ct.astype(jnp.float32)).astype(ACT_DTYPE)
    x = xt.reshape(bsz, cfg.ssm_heads, cfg.ssm_head_dim)
    b_mat = bt.reshape(bsz, cfg.ssm_groups, cfg.ssm_state)
    c_mat = ct.reshape(bsz, cfg.ssm_groups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    new_ssm, y = ssd_step(cache["ssm"], x, dt, a, b_mat, c_mat)
    y = y + x.astype(y.dtype) * p["D"][:, None].astype(y.dtype)
    y = y.reshape(bsz, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.matmul(y, p["out_proj"]["w"].astype(y.dtype))[:, None, :]
    return out, {"conv_x": cx, "conv_b": cb, "conv_c": cc, "ssm": new_ssm}
