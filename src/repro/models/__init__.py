"""LM-family model zoo sharing one scan/remat spine (see lm.py).

  config.py   ModelConfig schema + ShapeConfig cells + layer patterns
  layers.py   RMSNorm / RoPE / GQA flash attention / SwiGLU (+ BNN quant)
  moe.py      top-k capacity-bounded Mixture-of-Experts
  ssm.py      Mamba-2 SSD mixer (chunked matmul scan + decode step)
  lm.py       decoder-only spine: dense / MoE / SSM / hybrid via patterns
  encdec.py   encoder-decoder (seamless-m4t style) with cross-attention
"""

from repro.models import config, encdec, layers, lm, moe, ssm
