"""Decoder-only LM assembled from the layer pattern (dense / MoE / SSM /
hybrid all share this spine).

Scan-over-repeats with stacked per-slot parameters keeps the HLO O(1)
in depth (an 80-layer qwen2 lowers as one scanned block), and
``jax.checkpoint`` on the scan body gives per-layer activation
rematerialization. The softmax loss is sequence-chunked so the full
(B, S, V) logits tensor never materializes (a 152k vocab at 1M tokens
would otherwise dominate memory).

Inference consumers should reach ``prefill`` / ``decode_step`` /
``program_weights`` through the one-call hardware-compilation API
rather than threading engines by hand::

    # was: eng = GroupedEngine(get_engine(name), k);
    #      params, _ = program_weights(params, cfg, eng);
    #      lm.prefill(params, tokens, cfg, engine=eng); ...
    cm = repro.compiler.compile(cfg, params, HardwareTarget(engine=name))
    logits, caches = cm.prefill(tokens)
    logits, caches = cm.decode_step(tok, pos, caches)
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import bnn
from repro.distributed.hints import hint
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    ACT_DTYPE,
    attention_block,
    attention_continue,
    attention_decode_step,
    attn_init,
    dense,
    ffn,
    ffn_init,
    infer_engine,
    rms_norm,
)

Array = jax.Array
Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _has_ffn(kind, cfg: ModelConfig) -> bool:
    """mamba2-style stacks set d_ff=0: the block is mixer-only."""
    return kind.moe or cfg.d_ff > 0


def _init_slot(key: jax.Array, kind, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind.mixer == "attn":
        p["attn"] = attn_init(k1, cfg)
    else:
        p["mamba"] = ssm_lib.mamba_init(k1, cfg)
    if _has_ffn(kind, cfg):
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        if kind.moe:
            p["moe"] = moe_lib.moe_init(k2, cfg)
        else:
            p["ffn"] = ffn_init(k2, cfg)
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, len(cfg.pattern) + 3)
    vp = cfg.padded_vocab  # tables padded for vocab-parallel sharding
    params: Params = {
        "embed": jax.random.normal(keys[0], (vp, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(keys[1], (cfg.d_model, vp), jnp.float32) * (
            1.0 / math.sqrt(cfg.d_model)
        )
    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        slot_keys = jax.random.split(keys[3 + i - 1], cfg.n_repeats)
        blocks[f"slot{i}"] = jax.vmap(lambda k: _init_slot(k, kind, cfg))(slot_keys)
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# Forward (train) — scan over repeats, remat per repeat
# ---------------------------------------------------------------------------


def _apply_repeat(h: Array, slot_params: Params, positions: Array, cfg: ModelConfig):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.pattern):
        sp = slot_params[f"slot{i}"]
        hn = rms_norm(h, sp["norm1"], cfg.norm_eps)
        if kind.mixer == "attn":
            mix, _ = attention_block(sp["attn"], hn, positions, cfg, quant=cfg.quant)
        else:
            mix, _ = ssm_lib.mamba_block(sp["mamba"], hn, cfg)
        h = h + mix
        if _has_ffn(kind, cfg):
            hn = rms_norm(h, sp["norm2"], cfg.norm_eps)
            if kind.moe:
                f, a = _moe(sp["moe"], hn, cfg)
                aux = aux + a
            else:
                f = ffn(sp["ffn"], hn, cfg.quant)
            h = h + f
    return h, aux


def _moe(p: Params, hn: Array, cfg: ModelConfig):
    """MoE with selectable dispatch (ModelConfig.moe_impl)."""
    if cfg.moe_impl == "ep_shard_map":
        from repro.distributed.ep import ep_moe_ffn
        from repro.distributed.hints import current_mesh

        mesh = current_mesh()
        if (
            mesh is not None
            and "model" in mesh.shape
            and cfg.moe_experts % mesh.shape["model"] == 0
            and (hn.shape[0] * hn.shape[1]) % mesh.shape["model"] == 0
        ):
            return ep_moe_ffn(p, hn, cfg, mesh)
    return moe_lib.moe_ffn(p, hn, cfg)


def backbone(params: Params, embeds: Array, positions: Array, cfg: ModelConfig):
    """(B, S, d) -> (hidden (B, S, d), moe_aux scalar)."""
    from repro.models.scan import remat_scan

    h = hint(embeds.astype(ACT_DTYPE), "dp", None, None)

    def body(carry, slot_p):
        h, aux = carry
        h = hint(h, "dp", None, None)  # re-pin batch sharding in the remat replay
        h2, a = _apply_repeat(h, slot_p, positions, cfg)
        return (hint(h2, "dp", None, None), aux + a)

    carry0 = (h, jnp.zeros((), jnp.float32))
    if cfg.remat:
        # remat_scan: per-layer recompute with a SINGLE bf16 residual
        # stack (scan+checkpoint writes an extra fp32 stack — see
        # models/scan.py)
        h, aux = remat_scan(body, carry0, params["blocks"])
    else:
        (h, aux), _ = jax.lax.scan(lambda c, x: (body(c, x), None), carry0, params["blocks"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux


def embed_tokens(params: Params, tokens: Array) -> Array:
    return params["embed"][tokens]


def _head_weights(params: Params, cfg: ModelConfig) -> Array:
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def _mask_padded_vocab(logits: Array, cfg: ModelConfig) -> Array:
    """-inf on the padding columns (see ModelConfig.padded_vocab)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    valid = jnp.arange(logits.shape[-1]) < cfg.vocab_size
    return jnp.where(valid, logits, -1e30)


def lm_loss(params: Params, hidden: Array, targets: Array, cfg: ModelConfig) -> Array:
    """Sequence-chunked softmax cross-entropy. targets < 0 are masked."""
    w = _head_weights(params, cfg)
    b, s, d = hidden.shape
    ck = min(cfg.loss_chunk, s)
    pad = (-s) % ck
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = hidden.shape[1] // ck
    hs = hidden.reshape(b, nc, ck, d).swapaxes(0, 1)
    ts = targets.reshape(b, nc, ck).swapaxes(0, 1)

    @jax.checkpoint
    def step(acc, xs):
        # checkpointed: the backward recomputes this chunk's logits
        # instead of saving a (B, ck, V) fp32 tensor per chunk — without
        # this the loss scan alone materializes the full (B, S, V)
        # logits (tens of GiB/device at 150k vocabs).
        hc, tc = xs
        # vocab-parallel loss: batch over the pure-DP axes only; the
        # model axis belongs to the vocab dim of w/logits here
        hc = hint(hc, "dp_strict", None, None)
        logits = jnp.einsum("bsd,dv->bsv", hc.astype(jnp.float32), w)
        logits = hint(_mask_padded_vocab(logits, cfg), "dp_strict", None, "model_strict")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        mask = (tc >= 0).astype(jnp.float32)
        n_tok, tot = acc
        return (n_tok + mask.sum(), tot + ((lse - ll) * mask).sum()), None

    (n_tok, total), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hs, ts))
    return total / jnp.maximum(n_tok, 1.0)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig, aux_coef: float = 0.01) -> Array:
    """Next-token loss over a {tokens, (optional) extra_embeds} batch."""
    tokens = batch["tokens"]
    embeds = embed_tokens(params, tokens)
    if "extra_embeds" in batch:  # modality frontend stub (VLM)
        embeds = jnp.concatenate([batch["extra_embeds"].astype(embeds.dtype), embeds], axis=1)
    positions = jnp.arange(embeds.shape[1])
    hidden, aux = backbone(params, embeds, positions, cfg)
    n_extra = embeds.shape[1] - tokens.shape[1]
    hidden = hidden[:, n_extra:, :]
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.full((tokens.shape[0], 1), -1, tokens.dtype)], axis=1
    )
    return lm_loss(params, hidden, targets, cfg) + aux_coef * aux


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------

# The binarized projection tensors of the LM spine (== the mapping IR's
# coverage): these are the weights a crossbar holds resident.
BINARIZED_PROJECTIONS = {"attn": ("q", "k", "v", "o"), "ffn": ("w1", "w3", "w2")}


def _fused_qkv_artifact(attn: Params, base) -> Params | None:
    """Derived shared-activation QKV artifact for fused-dense engines.

    q/k/v all consume the same attention input; engines that fuse the
    whole BitLinear into one kernel (``supports_fused_dense``) can then
    run ONE launch over the three sign matrices concatenated along the
    output axis (``layers.fused_qkv_dense`` splits at the static head
    boundaries). Packing is column-independent, so the concatenated
    artifact is exactly the three per-projection artifacts side by side
    — bit-identical outputs. The per-column scale vector repeats each
    projection's scalar ``mean|w|`` across its n columns. Derived (not
    counted in ``n_programmed``): the per-projection artifacts still
    exist and serve every non-fused path.
    """
    if not all(k in attn for k in ("q", "k", "v")):
        return None
    wq, wk, wv = (attn[k]["w"] for k in ("q", "k", "v"))
    prepared, alphas = [], []
    for i in range(wq.shape[0]):
        parts = (wq[i], wk[i], wv[i])
        prepared.append(
            base.prepare(bnn.binarize_ste(jnp.concatenate(parts, axis=1)))
        )
        alphas.append(
            jnp.concatenate(
                [
                    jnp.broadcast_to(
                        jnp.mean(jnp.abs(wi)).astype(jnp.float32), (wi.shape[1],)
                    )
                    for wi in parts
                ]
            )
        )
    return {
        "prepared": jax.tree.map(lambda *xs: jnp.stack(xs), *prepared),
        "alpha": jnp.stack(alphas),
    }


def program_weights(params: Params, cfg: ModelConfig, engine) -> tuple[Params, int]:
    """Crossbar-programming phase: compile every binarized projection
    into ``engine``'s resident form ONCE, before serving starts.

    Walks the stacked block params and attaches a
    :class:`repro.core.engine.PreparedWeights` (plus the precomputed
    per-tensor weight scale) alongside each attn q/k/v/o and FFN
    w1/w3/w2 projection — exactly the transforms ``layers.dense``
    applies per call, hoisted to bind time, so prefill/decode traces
    carry zero weight-side work (the paper's stationary-weight premise:
    program the PCM once, stream only activations). Per-repeat slices
    are programmed individually and stacked, so ``lax.scan`` slices the
    artifact back per layer bit-identically.

    Returns ``(programmed_params, n_programmed)`` where ``n_programmed``
    counts projection *instances* (stacked repeats each count). The
    input pytree is not mutated. No-op (0 programmed) unless
    ``cfg.quant == "bnn"`` and an engine is bound.
    """
    if cfg.quant != "bnn" or engine is None or "blocks" not in params:
        return params, 0
    base = getattr(engine, "base", engine)  # unwrap a GroupedEngine
    if not hasattr(base, "prepare"):
        # a minimal third-party backend without the two-phase contract:
        # serve it raw (same fallback as layers.dense / model._programmed)
        return params, 0
    n_programmed = 0
    blocks = {}
    for slot_name, slot in params["blocks"].items():
        new_slot = dict(slot)
        for part, projs in BINARIZED_PROJECTIONS.items():
            if part not in slot:
                continue
            sub = dict(slot[part])
            for proj_name in projs:
                if proj_name not in sub:
                    continue
                proj = dict(sub[proj_name])
                w = proj.pop("w")  # (L, m, n): stacked over scan repeats
                prepared, alphas = [], []
                for i in range(w.shape[0]):
                    wi = w[i]
                    prepared.append(base.prepare(bnn.binarize_ste(wi)))
                    alphas.append(jnp.mean(jnp.abs(wi)).astype(jnp.float32))
                # the latent weights are NOT carried along: the
                # programmed artifact fully replaces them on the serving
                # path (like the hardware, which holds only cell states),
                # and dropping them halves the per-tick weight bytes the
                # decode scan slices
                proj["prepared"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *prepared
                )
                proj["alpha"] = jnp.stack(alphas)
                sub[proj_name] = proj
                n_programmed += int(w.shape[0])
            if part == "attn" and getattr(base, "supports_fused_dense", False):
                qkv = _fused_qkv_artifact(slot["attn"], base)
                if qkv is not None:
                    sub["qkv"] = qkv
            new_slot[part] = sub
        blocks[slot_name] = new_slot
    return dict(params, blocks=blocks), n_programmed


def _apply_repeat_prefill(
    h: Array, slot_params: Params, positions: Array, cfg: ModelConfig, engine=None
):
    # binarized projections run on cfg.bnn_engine unless the caller
    # passes an engine (e.g. the serving engine's K-group adapter)
    eng = engine if engine is not None else infer_engine(cfg)
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        sp = slot_params[f"slot{i}"]
        hn = rms_norm(h, sp["norm1"], cfg.norm_eps)
        if kind.mixer == "attn":
            mix, (k, v) = attention_block(
                sp["attn"], hn, positions, cfg, quant=cfg.quant, engine=eng
            )
            caches[f"slot{i}"] = {"k": k.astype(ACT_DTYPE), "v": v.astype(ACT_DTYPE)}
        else:
            mix, st = ssm_lib.mamba_block(sp["mamba"], hn, cfg)
            caches[f"slot{i}"] = st
        h = h + mix
        if _has_ffn(kind, cfg):
            hn = rms_norm(h, sp["norm2"], cfg.norm_eps)
            if kind.moe:
                f, _ = moe_lib.moe_ffn(sp["moe"], hn, cfg)
            else:
                f = ffn(sp["ffn"], hn, cfg.quant, eng)
            h = h + f
    return h, caches


def prefill(
    params: Params,
    tokens: Array,
    cfg: ModelConfig,
    extra_embeds: Array | None = None,
    engine=None,
):
    """Forward pass that also returns stacked per-layer caches and the
    last-position logits. Cache seq capacity == prompt length (callers
    pad to their serving window). ``extra_embeds`` (B, L, d) prepends
    modality-frontend embeddings (VLM prefill). ``engine`` overrides
    ``cfg.bnn_engine`` for the binarized projections (serving passes its
    K-group ``GroupedEngine`` here)."""
    embeds = embed_tokens(params, tokens)
    if extra_embeds is not None:
        embeds = jnp.concatenate([extra_embeds.astype(embeds.dtype), embeds], axis=1)
    positions = jnp.arange(embeds.shape[1])
    h = embeds.astype(ACT_DTYPE)

    def body(h, slot_p):
        h2, caches = _apply_repeat_prefill(h, slot_p, positions, cfg, engine)
        return h2, caches

    h, caches = jax.lax.scan(body, h, params["blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = _head_weights(params, cfg)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :].astype(jnp.float32), w)
    return _mask_padded_vocab(logits, cfg), caches


class PrefixContinuationError(ValueError):
    """``prefill_continue`` was asked to continue a stack it cannot
    slice at a prefix boundary (SSM/hybrid mixers carry recurrent state,
    not per-position KV rows) or was given inconsistent caches."""


def prefill_continue(
    params: Params,
    tokens: Array,
    prefix_caches: Params,
    cfg: ModelConfig,
    engine=None,
):
    """Prefill only the suffix of a prompt whose prefix KV is cached.

    ``tokens`` (B, S) are the prompt positions AFTER the shared prefix;
    ``prefix_caches`` is a prefill-shaped cache pytree (per-layer
    ``{"k"/"v": (R, B, Lp, KV, D)}``) covering positions ``[0, Lp)`` of
    the SAME token prefix — typically sliced from an earlier prompt's
    :func:`prefill` caches. Returns ``(logits, caches)`` exactly like
    :func:`prefill` over the full prompt: last-position logits and
    full-prompt-shaped caches (prefix rows concatenated back in), so a
    serving slot graft is indistinguishable from a from-scratch prefill.

    Bit-exactness vs the full prefill (the serving prefix-graft
    invariant) follows from :func:`~repro.models.layers
    .attention_continue`'s two properties: cached prefix rows are
    prompt-length-invariant, and the suffix runs through the prefill
    attention graph. Attention-only stacks only — an SSM mixer's
    recurrent state cannot be cut at a token boundary — and the decoder
    LM path only (no ``extra_embeds``: VLM prompts prepend frontend
    embeddings whose positions a token-hash prefix cannot name).
    """
    bad = [
        f"slot{i}" for i, kind in enumerate(cfg.pattern) if kind.mixer != "attn"
    ]
    if bad:
        raise PrefixContinuationError(
            f"prefix continuation needs per-position KV rows; {cfg.name} "
            f"has non-attention mixer(s) at {', '.join(bad)} whose "
            "recurrent state cannot be sliced at a prefix boundary"
        )
    start = next(iter(prefix_caches.values()))["k"].shape[2]
    embeds = embed_tokens(params, tokens)
    positions = jnp.arange(start, start + tokens.shape[1])
    h = embeds.astype(ACT_DTYPE)
    eng = engine if engine is not None else infer_engine(cfg)

    def body(h, xs):
        slot_p, pre_r = xs
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            sp = slot_p[f"slot{i}"]
            pk, pv = pre_r[f"slot{i}"]["k"], pre_r[f"slot{i}"]["v"]
            hn = rms_norm(h, sp["norm1"], cfg.norm_eps)
            mix, (k, v) = attention_continue(
                sp["attn"], hn, positions, pk, pv, cfg, quant=cfg.quant,
                engine=eng,
            )
            caches[f"slot{i}"] = {
                "k": jnp.concatenate([pk, k.astype(ACT_DTYPE)], axis=1),
                "v": jnp.concatenate([pv, v.astype(ACT_DTYPE)], axis=1),
            }
            h = h + mix
            if _has_ffn(kind, cfg):
                hn = rms_norm(h, sp["norm2"], cfg.norm_eps)
                if kind.moe:
                    f, _ = moe_lib.moe_ffn(sp["moe"], hn, cfg)
                else:
                    f = ffn(sp["ffn"], hn, cfg.quant, eng)
                h = h + f
        return h, caches

    h, caches = jax.lax.scan(body, h, (params["blocks"], prefix_caches))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = _head_weights(params, cfg)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :].astype(jnp.float32), w)
    return _mask_padded_vocab(logits, cfg), caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=ACT_DTYPE) -> Params:
    """Zero-initialized decode cache pytree (stacked over repeats)."""
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        r = cfg.n_repeats
        if kind.mixer == "attn":
            shape = (r, batch, max_len, cfg.n_kv_heads, cfg.hd)
            caches[f"slot{i}"] = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        else:
            tail, gn = cfg.ssm_conv - 1, cfg.ssm_groups * cfg.ssm_state
            caches[f"slot{i}"] = {
                "conv_x": jnp.zeros((r, batch, tail, cfg.d_inner), dtype),
                "conv_b": jnp.zeros((r, batch, tail, gn), dtype),
                "conv_c": jnp.zeros((r, batch, tail, gn), dtype),
                "ssm": jnp.zeros(
                    (r, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
                ),
            }
    return caches


def decode_step(
    params: Params, token: Array, pos: Array, caches: Params, cfg: ModelConfig, engine=None
):
    """One serving step: token (B,) int32, pos scalar int32 or (B,)
    per-slot positions, caches from ``init_cache``/``prefill``. Returns
    (logits (B, V), new_caches). ``engine`` overrides ``cfg.bnn_engine``
    (the serving engine passes its K-group ``GroupedEngine``)."""
    embeds = embed_tokens(params, token[:, None])  # (B, 1, d)
    h = embeds.astype(ACT_DTYPE)
    eng = engine if engine is not None else infer_engine(cfg)

    def body(h, xs):
        slot_p, cache_r = xs
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            sp, cp = slot_p[f"slot{i}"], cache_r[f"slot{i}"]
            hn = rms_norm(h, sp["norm1"], cfg.norm_eps)
            if kind.mixer == "attn":
                mix, nk, nv = attention_decode_step(
                    sp["attn"], hn, pos, cp["k"], cp["v"], cfg, quant=cfg.quant,
                    engine=eng,
                )
                new_cache[f"slot{i}"] = {"k": nk, "v": nv}
            else:
                mix, st = ssm_lib.mamba_step(sp["mamba"], hn, cp, cfg)
                new_cache[f"slot{i}"] = st
            h = h + mix
            if _has_ffn(kind, cfg):
                hn = rms_norm(h, sp["norm2"], cfg.norm_eps)
                if kind.moe:
                    f, _ = moe_lib.moe_ffn(sp["moe"], hn, cfg)
                else:
                    f = ffn(sp["ffn"], hn, cfg.quant, eng)
                h = h + f
        return h, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["blocks"], caches))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = _head_weights(params, cfg)
    logits = jnp.einsum("bd,dv->bv", h[:, 0, :].astype(jnp.float32), w)
    return _mask_padded_vocab(logits, cfg), new_caches
