"""remat_scan — scan-over-layers with an explicit bf16 residual policy.

Why this exists (measured on qwen2-72b train_4k, 256 chips):
``jax.lax.scan(jax.checkpoint(body))`` materializes the per-layer carry
residual stack in **fp32 regardless of the carry dtype**, *in addition
to* a bf16 stack — 3x the optimal residual memory (10 GiB fp32 + 5 GiB
bf16 per device where 5 GiB suffices). A minimal repro (pure bf16
matmul body) shows the fp32 stack is written by scan's linearization
itself, not by any op inside the body (tests/test_remat_scan.py).

``remat_scan(body, carry, xs)`` is a drop-in for that pattern with a
hand-written VJP:

* forward: one scan, stacking the layer-INPUT carries in their own
  dtype (bf16 stays bf16) — the only O(L x B x S x d) buffer;
* backward: a reverse scan; each step recomputes its layer from the
  saved carry (jax.vjp = remat) and transposes — identical semantics to
  jax.checkpoint, minus the duplicated fp32 stack.

body: (carry, x) -> carry (same pytree structure/dtypes). Per-layer
outputs (ys) are deliberately unsupported — the training spine
accumulates scalars in the carry instead.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

Carry = Any


def remat_scan(body: Callable[[Carry, Any], Carry], carry: Carry, xs: Any) -> Carry:
    @jax.custom_vjp
    def run(carry, xs):
        out, _ = jax.lax.scan(lambda c, x: (body(c, x), None), carry, xs)
        return out

    def fwd(carry, xs):
        def step(c, x):
            return body(c, x), c  # save the INPUT carry, own dtype

        out, stack = jax.lax.scan(step, carry, xs)
        return out, (stack, xs)

    def bwd(res, g):
        stack, xs = res

        def step(gc, inp):
            c_in, x = inp
            # barrier: without it XLA hoists the body's fp32 upcast out
            # of the loop as convert(WHOLE stack) — re-introducing the
            # fp32 stack this function exists to avoid
            c_in = jax.lax.optimization_barrier(c_in)
            _, vjp = jax.vjp(body, c_in, x)
            dc, dx = vjp(gc)
            return dc, dx

        g0, dxs = jax.lax.scan(step, g, (stack, xs), reverse=True)
        return g0, dxs

    run.defvjp(fwd, bwd)
    return run(carry, xs)
