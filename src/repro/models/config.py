"""Model / shape configuration for the assigned architecture pool.

One frozen dataclass covers all ten families (dense / MoE / SSM /
hybrid / enc-dec / VLM / audio). Exact per-arch numbers live in
``repro/configs/<id>.py``; this module defines the schema and the
layer-pattern machinery that lets heterogeneous stacks (Jamba's
attn:mamba 1:7 with interleaved MoE) compile as a scan over repeats.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class LayerKind:
    """One slot in the repeating layer pattern."""

    mixer: Literal["attn", "mamba"] = "attn"
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_groups: int = 1
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # layer pattern (period P; n_layers % P == 0)
    pattern: tuple[LayerKind, ...] = (LayerKind(),)
    # enc-dec
    n_encoder_layers: int = 0        # >0 => enc-dec model
    # modality frontend stub (input_specs provides embeddings)
    frontend: Literal["none", "vision", "audio"] = "none"
    frontend_len: int = 256          # patches / frames per sample
    # quantization: "none" or "bnn" (the paper's technique as a feature)
    quant: Literal["none", "bnn"] = "none"
    # execution backend for binarized projections at inference time: any
    # name registered in repro.core.engine ("reference" keeps the plain
    # differentiable matmul; "packed" routes through the Pallas
    # XNOR+popcount kernel). Training always uses "reference".
    bnn_engine: str = "reference"
    # layer->tile placement policy for the "tiled" engine (see
    # repro.mapping.POLICIES). Consumers that hold a compiled
    # MappingPlan pass it alongside the config (plans are arrays-free
    # but not config-hashable); the policy string here is what the
    # engine falls back to for on-the-fly placement.
    mapping_policy: str = "tacitmap"
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    loss_chunk: int = 256            # sequence chunking for the softmax loss
    attn_chunk: int = 512            # flash-style KV chunk (jnp impl)
    # "jnp": scan-based flash (lowers everywhere, scores hit HBM).
    # "pallas": fused kernel, scores stay in VMEM (TPU; interpret on CPU)
    attn_impl: Literal["jnp", "pallas"] = "jnp"
    # "pjit": SPMD-inferred MoE (EP when the layout allows, ZeRO gather
    # otherwise). "ep_shard_map": hand-written all_to_all dispatch —
    # expert weights never move (distributed/ep.py); requires
    # E % model_axis == 0 and an active hints mesh.
    moe_impl: Literal["pjit", "ep_shard_map"] = "pjit"
    remat: bool = True

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(f"{self.name}: n_layers {self.n_layers} not divisible by pattern {len(self.pattern)}")

    # -- derived ------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables pad the vocab to a multiple of 256 so
        vocab-parallel sharding always divides any production mesh axis
        (an odd vocab like seamless' 256206 otherwise forces a
        replicated-V loss: full-vocab fp32 head grads psum'd per chunk —
        measured as a 3 s/step collective term, EXPERIMENTS.md §Perf).
        Logits beyond ``vocab_size`` are masked to -inf everywhere."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def has_attn(self) -> bool:
        return any(k.mixer == "attn" for k in self.pattern) or self.is_encdec

    @property
    def has_mamba(self) -> bool:
        return any(k.mixer == "mamba" for k in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k+ context? (SSM/hybrid families.)"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs and reports)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_kind = {}
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        ffn_dense = 3 * d * self.d_ff
        ffn_moe = self.moe_experts * 3 * d * self.d_ff + d * self.moe_experts
        mamba = (
            d * (2 * self.d_inner + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads)
            + self.d_inner * d
            + (self.d_inner + 2 * self.ssm_groups * self.ssm_state) * self.ssm_conv
            + 3 * self.ssm_heads
        )
        total = emb
        for kind in self.pattern:
            mix = attn if kind.mixer == "attn" else mamba
            ff = ffn_moe if kind.moe else ffn_dense
            per_kind[kind] = mix + ff + 2 * d
            total += self.n_repeats * (mix + ff + 2 * d)
        if self.is_encdec:  # encoder layers: self-attn + dense ffn; decoder adds cross-attn
            total += self.n_encoder_layers * (attn + ffn_dense + 2 * d)
            total += self.n_layers * attn  # cross-attention blocks
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of experts)."""
        if self.moe_experts == 0:
            return self.param_count()
        d = self.d_model
        full_ffn = self.moe_experts * 3 * d * self.d_ff
        active_ffn = self.moe_top_k * 3 * d * self.d_ff
        n_moe_layers = sum(1 for k in self.pattern) and sum(
            self.n_repeats for k in self.pattern if k.moe
        )
        return self.param_count() - n_moe_layers * (full_ffn - active_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell's input geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Implements the brief's skip rules; returns (runs?, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: O(s^2) attention at 524k skipped (DESIGN.md §5)"
    return True, ""
