"""repro — TacitMap + EinsteinBarrier (BNN data mapping on PCM-based
integrated photonics) rebuilt as a production JAX/TPU framework.

Subpackages:
  core         the paper's contribution (mappings, WDM, cost models, BNNs)
  kernels      Pallas TPU kernels (packed XNOR matmul, WDM MMM, BitLinear)
  models       LM-family architectures (dense / MoE / SSM / hybrid / enc-dec)
  configs      the 10 assigned architecture configs + shapes + BNN configs
  data         deterministic synthetic pipelines (restart-safe)
  optim        AdamW (+ factored / quantized moments) and schedules
  checkpoint   atomic, async, reshardable checkpoints
  distributed  partitioner, pipeline parallelism, gradient compression
  train        fault-tolerant training loop
  launch       production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "1.0.0"
