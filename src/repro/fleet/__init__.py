"""Fleet serving: N replicas of one compiled target behind a
KV-prefix-affinity router.

The single-replica stack (PR 7/9) ends at one
:class:`~repro.serving.ServingEngine` with a scheduler and a health
monitor. This package scales that contract out without changing it:

* :class:`~repro.fleet.replica.Replica` — one ``CompiledModel.serve()``
  plus per-replica identity, load score and snapshot trust watermark.
* :class:`~repro.fleet.router.FleetRouter` — token-block hash chains
  over a two-tier (fleet-global / replica-local) prefix index; policies
  ``prefix`` | ``least-loaded`` | ``round-robin``.
* :class:`~repro.fleet.pool.FleetEngine` — the client-facing pool:
  same ``submit``/``step``/``drain``/``stream`` loop, plus prefix
  grafting on affinity hits and failover off degraded replicas.

Everything here is semantically invisible: FINISHED generations are
byte-identical to solo single-replica runs for every policy, replica
count and engine — including grafted admissions and mid-serve failover.
"""

from repro.fleet.pool import FleetEngine, FleetRequestState, FleetStats
from repro.fleet.replica import Replica
from repro.fleet.router import (
    DEFAULT_BLOCK,
    ROUTING_POLICIES,
    FleetRouter,
    PrefixEntry,
    PrefixIndex,
    RouteDecision,
    RoutingConfigError,
    chain_hashes,
)

__all__ = [
    "DEFAULT_BLOCK",
    "ROUTING_POLICIES",
    "FleetEngine",
    "FleetRequestState",
    "FleetRouter",
    "FleetStats",
    "PrefixEntry",
    "PrefixIndex",
    "Replica",
    "RouteDecision",
    "RoutingConfigError",
    "chain_hashes",
]
