"""KV-prefix-affinity request router: token-block hash chains, a
two-tier fleet-global / replica-local prefix index, and the three
routing policies (``prefix``, ``least-loaded``, ``round-robin``).

The rtp-llm ``flexlb`` shape: prompts are cut into fixed token blocks
and each block carries a *chained* hash (block ``i``'s digest covers
blocks ``0..i``), so one dict lookup on the longest chain finds every
replica whose prefix library contains that exact token prefix. Routing
then scores candidates by longest prefix match first, load second.

Two tiers:

* **Fleet-global table** (:attr:`FleetRouter._global`): chain hash ->
  the set of replica ids holding an entry with that prefix. One lookup
  names the candidate replicas; entries leave the table when their
  replica evicts them (LRU) or degrades.
* **Replica-local library** (:class:`PrefixIndex`): a bounded-LRU map
  from chain hashes to :class:`PrefixEntry` — the donor prompt's
  tokens plus its prefill-cache rows (batch-squeezed, device-resident).
  Hashes only *select* candidates; the actual graft length is an exact
  element-wise token comparison against the stored prompt, so a hash
  collision can never corrupt a generation (it just wastes a lookup).

The router is pure host-side bookkeeping — it never touches the model.
Correctness (routed == solo, bit-exact) is owned by the
``prefill_continue`` invariant; the router only decides *where* a
request runs and *how much* prefix it may skip (always strictly less
than the prompt, so the first emitted token is computed fresh).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import numpy as np

from repro import obs

ROUTING_POLICIES = ("prefix", "least-loaded", "round-robin")

DEFAULT_BLOCK = 16


class RoutingConfigError(ValueError):
    """An inconsistent router configuration (unknown policy, bad block
    size or capacity)."""


def chain_hashes(tokens: np.ndarray, block_size: int) -> tuple[bytes, ...]:
    """Chained digests of the prompt's full token blocks.

    Entry ``i`` hashes block ``i``'s tokens together with entry
    ``i-1``'s digest, so it names the exact token prefix of length
    ``(i + 1) * block_size`` — matching chains mean matching prefixes
    (up to hash collision, which the index re-verifies token-wise).
    A trailing partial block contributes no hash.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: list[bytes] = []
    digest = b""
    for b in range(len(toks) // block_size):
        block = toks[b * block_size: (b + 1) * block_size]
        digest = hashlib.blake2b(
            digest + block.tobytes(), digest_size=16
        ).digest()
        out.append(digest)
    return tuple(out)


@dataclasses.dataclass
class PrefixEntry:
    """One replica-local prefix-library entry: a donor prompt's tokens,
    its prefill-cache rows, and its hash chain."""

    tokens: np.ndarray            # (prompt_len,) int32, host copy
    rows: Any                     # batch-squeezed cache pytree (device)
    hashes: tuple[bytes, ...]     # chain_hashes(tokens, block_size)
    stamp: int = 0                # LRU clock at last touch

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


class PrefixIndex:
    """Bounded-LRU replica-local prefix library.

    ``insert`` registers a prompt's cache rows under every prefix of
    its hash chain (longest entry wins a contested hash); ``match``
    returns the entry sharing the longest *exact* token prefix with a
    query prompt. Capacity is in entries — each holds one prompt's KV
    rows, so the device-memory bound is ``capacity x max prompt KV``.
    """

    def __init__(self, block_size: int = DEFAULT_BLOCK, capacity: int = 32):
        if block_size < 1:
            raise RoutingConfigError(
                f"block_size must be >= 1 token, got {block_size}"
            )
        if capacity < 1:
            raise RoutingConfigError(
                f"capacity must be >= 1 entry, got {capacity}"
            )
        self.block_size = int(block_size)
        self.capacity = int(capacity)
        self._by_hash: dict[bytes, PrefixEntry] = {}
        self._entries: list[PrefixEntry] = []
        self._clock = 0

    def __len__(self) -> int:
        return len(self._entries)

    def _touch(self, entry: PrefixEntry) -> None:
        self._clock += 1
        entry.stamp = self._clock

    def insert(self, tokens: np.ndarray, rows: Any) -> PrefixEntry | None:
        """Register a prefilled prompt; returns the new entry (or None
        when the prompt is shorter than one block). Evicted LRU entries'
        hashes are released unless a surviving entry also covers them."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return None
        entry = PrefixEntry(
            tokens=np.array(tokens, np.int32, copy=True), rows=rows,
            hashes=hashes,
        )
        self._touch(entry)
        self._entries.append(entry)
        for h in hashes:
            cur = self._by_hash.get(h)
            # longest chain wins: a longer donor prompt serves every
            # shorter match the displaced entry could
            if cur is None or len(cur.hashes) <= len(entry.hashes):
                self._by_hash[h] = entry
        while len(self._entries) > self.capacity:
            self._evict_lru()
        return entry

    def _evict_lru(self) -> PrefixEntry:
        victim = min(self._entries, key=lambda e: e.stamp)
        self._entries.remove(victim)
        for h in victim.hashes:
            if self._by_hash.get(h) is victim:
                del self._by_hash[h]
                for other in self._entries:
                    if h in other.hashes:
                        self._by_hash[h] = other
                        break
        return victim

    def match(self, tokens: np.ndarray) -> tuple[PrefixEntry | None, int]:
        """The entry sharing the longest exact token prefix with
        ``tokens`` and that prefix's length (0 on no block-level hit).

        Hashes select the candidate (longest chain first); the returned
        length is the element-wise common prefix with the stored prompt,
        so it may extend past the last matched block boundary and can
        never exceed what the tokens actually share.
        """
        toks = np.asarray(tokens, np.int32)
        query = chain_hashes(toks, self.block_size)
        for i in range(len(query) - 1, -1, -1):
            entry = self._by_hash.get(query[i])
            if entry is None:
                continue
            n = min(len(entry.tokens), len(toks))
            eq = entry.tokens[:n] == toks[:n]
            common = int(n if eq.all() else np.argmin(eq))
            if common >= self.block_size:
                self._touch(entry)
                return entry, common
        return None, 0

    def hashes(self) -> set[bytes]:
        return set(self._by_hash)


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one request goes and what prefix it may skip."""

    replica: int
    policy: str
    matched_tokens: int = 0       # exact shared-prefix length found
    graft_length: int = 0         # tokens the admission will skip
    entry: PrefixEntry | None = None   # donor entry backing the graft


class FleetRouter:
    """Two-tier prefix index + the routing policies over N replicas.

    ``observe_prefill(rid, tokens, rows)`` feeds a replica's prefix
    library (wired to ``ServingEngine.prefill_observer`` by the pool);
    ``route(tokens, loads)`` picks the replica for a prompt given the
    per-replica load scores of the currently healthy replicas;
    ``forget_replica(rid)`` drops a degraded replica's entries from the
    global table so no new request routes toward its dead library.
    """

    def __init__(
        self,
        replica_ids,
        *,
        policy: str = "prefix",
        block_size: int = DEFAULT_BLOCK,
        capacity: int = 32,
    ):
        if policy not in ROUTING_POLICIES:
            raise RoutingConfigError(
                f"unknown routing policy {policy!r}; "
                f"known: {', '.join(ROUTING_POLICIES)}"
            )
        self.policy = policy
        self.block_size = int(block_size)
        self.indexes: dict[int, PrefixIndex] = {
            rid: PrefixIndex(block_size, capacity) for rid in replica_ids
        }
        if not self.indexes:
            raise RoutingConfigError("a fleet router needs >= 1 replica")
        self._global: dict[bytes, set[int]] = {}
        self._rr = 0
        self.decisions = 0
        self.prefix_hits = 0
        self.hit_tokens = 0

    # -- index maintenance ---------------------------------------------------

    def observe_prefill(self, rid: int, tokens: np.ndarray, rows: Any) -> None:
        index = self.indexes[rid]
        before = index.hashes()
        entry = index.insert(tokens, rows)
        if entry is None:
            return
        for h in entry.hashes:
            self._global.setdefault(h, set()).add(rid)
        for h in before - index.hashes():
            owners = self._global.get(h)
            if owners is not None:
                owners.discard(rid)
                if not owners:
                    del self._global[h]

    def forget_replica(self, rid: int) -> None:
        """Drop a degraded replica from the global table (its local
        library stays allocated but unreachable for routing)."""
        for h, owners in list(self._global.items()):
            owners.discard(rid)
            if not owners:
                del self._global[h]

    # -- routing -------------------------------------------------------------

    def route(
        self, tokens: np.ndarray, loads: dict[int, float]
    ) -> RouteDecision:
        """Pick a replica for a prompt. ``loads`` maps each HEALTHY
        replica id to its load score (lower = freer); degraded replicas
        are simply absent from it."""
        if not loads:
            raise RoutingConfigError("no healthy replica to route to")
        self.decisions += 1
        if self.policy == "round-robin":
            order = sorted(loads)
            rid = order[self._rr % len(order)]
            self._rr += 1
            return RouteDecision(replica=rid, policy=self.policy)
        if self.policy == "least-loaded":
            rid = min(sorted(loads), key=lambda r: loads[r])
            return RouteDecision(replica=rid, policy=self.policy)
        return self._route_prefix(np.asarray(tokens, np.int32), loads)

    def _route_prefix(
        self, tokens: np.ndarray, loads: dict[int, float]
    ) -> RouteDecision:
        query = chain_hashes(tokens, self.block_size)
        candidates: set[int] = set()
        for i in range(len(query) - 1, -1, -1):
            owners = self._global.get(query[i])
            if owners:
                candidates = {r for r in owners if r in loads}
                if candidates:
                    break
        best: tuple[int, PrefixEntry] | None = None
        best_len = 0
        for rid in sorted(candidates):
            entry, matched = self.indexes[rid].match(tokens)
            if entry is None:
                continue
            if matched > best_len or (
                matched == best_len
                and best is not None
                and loads[rid] < loads[best[0]]
            ):
                best = (rid, entry)
                best_len = matched
        if best is None:
            rid = min(sorted(loads), key=lambda r: loads[r])
            return RouteDecision(
                replica=rid, policy=self.policy, matched_tokens=0
            )
        rid, entry = best
        # the last prompt position always prefills fresh (its logits
        # seed the first emitted token), so cap the graft below the
        # prompt; a full-prompt match still skips all but one token
        graft_len = min(best_len, len(tokens) - 1, entry.prompt_len)
        if graft_len < 1:
            rid = min(sorted(loads), key=lambda r: loads[r])
            return RouteDecision(
                replica=rid, policy=self.policy, matched_tokens=best_len
            )
        self.prefix_hits += 1
        self.hit_tokens += graft_len
        obs.count(
            "repro_fleet_prefix_hits_total", 1,
            "routing decisions that found a usable shared prefix",
        )
        return RouteDecision(
            replica=rid, policy=self.policy, matched_tokens=best_len,
            graft_length=graft_len, entry=entry,
        )
