"""``FleetEngine``: N replicas of one compiled target behind the
prefix-affinity router, with the same ``submit``/``step``/``drain``/
``stream`` client loop as a single :class:`~repro.serving.ServingEngine`.

Every submission is routed once (longest prefix match then load, per
the configured policy); every ``step()`` fans one scheduling tick
across the replicas that have work. Two fleet-only behaviours sit on
top of the single-replica contract:

* **Prefix grafting** — on a prefix-affinity hit the admitted request
  carries a :class:`~repro.serving.scheduler.PrefixGraft` of the
  matching library entry's KV rows, so the replica prefills only the
  suffix (bit-identical to the full prefill, by the
  ``prefill_continue`` invariant). Each replica's prefill feeds its
  library back through ``prefill_observer``.
* **Failover** — when a replica degrades
  (:class:`~repro.serving.scheduler.DegradedServiceError` territory),
  its FAILED requests are re-admitted on healthy replicas instead of
  surfacing the failure: requests holding a preemption snapshot at or
  below the degraded replica's clean-tick watermark resume from the
  snapshot (the cross-pool portability primitive); everything else
  re-prefills and regenerates the same tokens from scratch. Only when
  no healthy replica can take a request does its FAILED state surface.

The PR 7 invariant one level up, tested in tests/test_fleet.py and
gated in ``benchmarks/fleet.py``: for every routing policy x replica
count x engine, every FINISHED request's generation is byte-identical
to running it alone on one replica — routing, grafting and failover
are semantically invisible.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.fleet.replica import Replica
from repro.fleet.router import DEFAULT_BLOCK, FleetRouter, RoutingConfigError
from repro.serving.engine import ServingStats
from repro.serving.scheduler import (
    DegradedServiceError,
    PrefixGraft,
    Request,
    RequestRejectedError,
    RequestState,
    RequestStatus,
    SchedulerConfig,
    SchedulerExhaustedError,
)


@dataclasses.dataclass(frozen=True)
class FleetStats:
    """One frozen snapshot of the fleet's counters: routing, grafting
    and failover totals plus every replica's nested ServingStats."""

    n_replicas: int
    routing: str
    submitted: int
    finished: int
    rejected: int
    expired: int
    failed: int                 # FAILED states that could NOT fail over
    failovers: int              # requests re-admitted off a degraded replica
    salvaged: int               # failovers resumed from a trusted snapshot
    prefix_hits: int            # routing decisions that grafted a prefix
    prefix_hit_rate: float      # hits / submissions
    grafted_tokens: int         # prompt tokens elided fleet-wide
    prefill_tokens: int         # prompt tokens actually prefilled fleet-wide
    ticks: int                  # decode ticks summed over replicas
    decoded: int                # slot-tokens decoded fleet-wide
    healthy_replicas: int
    replicas: tuple[ServingStats, ...]


class FleetRequestState:
    """The client's view of one fleet request — stable across failover.

    Failover re-admits the request on another replica, producing a new
    underlying :class:`RequestState`; this proxy rebinds to it, so the
    object ``submit`` returned keeps reporting live progress. All
    RequestState attributes (``status``, ``generated``, ``done``,
    ``terminal``, ...) delegate to the current binding.
    """

    def __init__(self, request: Request, state: RequestState, replica: int):
        self.request = request
        self.replica = replica       # replica currently holding it
        self.failovers = 0
        self._st = state

    def _rebind(self, state: RequestState, replica: int) -> None:
        self._st = state
        self.replica = replica
        self.failovers += 1

    @property
    def state(self) -> RequestState:
        """The current underlying per-replica state."""
        return self._st

    def __getattr__(self, name):
        # delegate everything RequestState exposes (status, generated,
        # done, terminal, rid, latency_ticks, ...)
        return getattr(self.__dict__["_st"], name)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"<FleetRequestState rid={self._st.rid} "
            f"status={self._st.status.value} replica={self.replica} "
            f"failovers={self.failovers}>"
        )


class FleetEngine:
    """N :class:`~repro.fleet.replica.Replica` s behind one router.

    Build from one compiled target (``FleetEngine.build(cfg, params,
    target, n_replicas=...)`` compiles and programs each replica's own
    copy — the program-once premise, once per replica) or pass
    pre-built replicas (heterogeneous fleets: e.g. one fault-injected
    replica among clean ones, for failover tests).
    """

    def __init__(
        self,
        replicas: list[Replica],
        *,
        routing: str = "prefix",
        block_size: int = DEFAULT_BLOCK,
        prefix_capacity: int = 32,
    ):
        if not replicas:
            raise RoutingConfigError("a fleet needs >= 1 replica")
        rids = [r.rid for r in replicas]
        if len(set(rids)) != len(rids):
            raise RoutingConfigError(f"duplicate replica ids: {sorted(rids)}")
        self.replicas: dict[int, Replica] = {
            r.rid: r for r in sorted(replicas, key=lambda r: r.rid)
        }
        self.router = FleetRouter(
            self.replicas, policy=routing,
            block_size=block_size, capacity=prefix_capacity,
        )
        self.routing = routing
        # the prefix library only feeds (and is only consulted by) the
        # prefix policy, and only on stacks continuation can slice
        self._graft_ok = routing == "prefix" and all(
            r.serving.supports_prefix_graft for r in replicas
        )
        for r in replicas:
            if self._graft_ok:
                r.serving.prefill_observer = (
                    lambda st, rows, rid=r.rid: self.router.observe_prefill(
                        rid, st.request.prompt, rows
                    )
                )
            r.serving.on_degrade = (
                lambda reason, rid=r.rid: self._on_replica_degrade(rid, reason)
            )
        self._states: list[FleetRequestState] = []
        self._by_state: dict[int, FleetRequestState] = {}   # id(RequestState)
        self._counts = {
            "submitted": 0, "finished": 0, "rejected": 0, "expired": 0,
            "failed": 0, "failovers": 0, "salvaged": 0,
        }

    @classmethod
    def build(
        cls,
        cfg,
        params,
        target,
        *,
        n_replicas: int = 2,
        max_batch: int = 4,
        max_len: int = 256,
        scheduler: SchedulerConfig | None = None,
        routing: str = "prefix",
        block_size: int = DEFAULT_BLOCK,
        prefix_capacity: int = 32,
    ) -> "FleetEngine":
        """Compile + program ``n_replicas`` copies of one target and
        stand the fleet up around them."""
        from repro import compiler as compiler_lib

        if n_replicas < 1:
            raise RoutingConfigError(
                f"n_replicas must be >= 1, got {n_replicas}"
            )
        replicas = [
            Replica(
                rid,
                compiler_lib.compile(cfg, params, target),
                max_batch=max_batch, max_len=max_len, scheduler=scheduler,
            )
            for rid in range(n_replicas)
        ]
        return cls(
            replicas, routing=routing, block_size=block_size,
            prefix_capacity=prefix_capacity,
        )

    # -- health --------------------------------------------------------------

    def _healthy(self) -> list[Replica]:
        return [r for r in self.replicas.values() if r.healthy]

    def _on_replica_degrade(self, rid: int, reason: str) -> None:
        self.router.forget_replica(rid)
        obs.event(
            "fleet.degrade", track="fleet", replica=rid, reason=reason,
        )
        obs.gauge_set(
            "repro_fleet_replicas_healthy", len(self._healthy()),
            "replicas accepting work now",
        )

    # -- client API ----------------------------------------------------------

    def submit(self, request: Request) -> FleetRequestState:
        """Route and enqueue one request; returns its fleet state
        (possibly REJECTED — e.g. every replica degraded)."""
        self._counts["submitted"] += 1
        healthy = self._healthy()
        if not healthy:
            # let the lowest-rid replica's scheduler reject it with the
            # named degraded reason — same surface as a solo engine
            rep = next(iter(self.replicas.values()))
            st = rep.submit(request)
            return self._track(request, st, rep.rid)
        loads = {r.rid: r.load_score() for r in healthy}
        decision = self.router.route(request.prompt, loads)
        routed = request
        if self._graft_ok and decision.graft_length > 0:
            routed = dataclasses.replace(
                request,
                prefix=PrefixGraft(
                    length=decision.graft_length, rows=decision.entry.rows
                ),
            )
        obs.event(
            "fleet.route", track="fleet", rid=request.rid,
            replica=decision.replica, policy=decision.policy,
            matched_tokens=decision.matched_tokens,
            graft_length=decision.graft_length,
        )
        obs.count(
            "repro_fleet_routed_total", 1, "requests routed",
            policy=decision.policy, replica=decision.replica,
        )
        st = self.replicas[decision.replica].submit(routed)
        return self._track(request, st, decision.replica)

    def _track(
        self, request: Request, st: RequestState, rid: int
    ) -> FleetRequestState:
        fst = FleetRequestState(request, st, rid)
        self._states.append(fst)
        if st.terminal:
            self._count_terminal(st)
        else:
            self._by_state[id(st)] = fst
        return fst

    def _count_terminal(self, st: RequestState) -> None:
        key = {
            RequestStatus.FINISHED: "finished",
            RequestStatus.REJECTED: "rejected",
            RequestStatus.EXPIRED: "expired",
            RequestStatus.FAILED: "failed",
        }.get(st.status)
        if key is not None:
            self._counts[key] += 1

    def step(self) -> list[FleetRequestState]:
        """One fleet tick: every replica with work runs one scheduling
        tick; FAILED states of degraded replicas fail over to healthy
        ones. Returns the fleet states that became terminal."""
        out: list[FleetRequestState] = []
        for rid in sorted(self.replicas):
            rep = self.replicas[rid]
            if not rep.pending():
                continue
            for st in rep.step():
                fst = self._by_state.pop(id(st), None)
                if fst is None:
                    continue
                if st.status is RequestStatus.FAILED and self._failover(fst):
                    continue
                self._count_terminal(st)
                out.append(fst)
        return out

    def _failover(self, fst: FleetRequestState) -> bool:
        """Re-admit a FAILED request on a healthy replica. True when it
        was adopted (the request stays in flight); False surfaces the
        failure (no healthy replica, or none would admit it)."""
        failed_st = fst.state
        source = self.replicas[fst.replica]
        snap = failed_st.snapshot
        trusted = snap is not None and source.trusts(snap)
        healthy = self._healthy()
        if trusted:
            # resume from the clean-watermark snapshot: carried tokens +
            # restored KV rows, on the freest healthy replica
            candidates = sorted(healthy, key=lambda r: (r.load_score(), r.rid))
            for rep in candidates:
                st = rep.adopt(
                    fst.request, generated=failed_st.generated, snapshot=snap
                )
                if st.status is not RequestStatus.REJECTED:
                    self._record_failover(fst, st, rep.rid, salvaged=True)
                    return True
            return False
        if not healthy:
            return False
        # restart from scratch — re-route (the prompt's prefix may live
        # in a healthy replica's library) and regenerate; determinism
        # makes the regenerated tokens identical to the lost ones
        loads = {r.rid: r.load_score() for r in healthy}
        decision = self.router.route(fst.request.prompt, loads)
        routed = fst.request
        if self._graft_ok and decision.graft_length > 0:
            routed = dataclasses.replace(
                fst.request,
                prefix=PrefixGraft(
                    length=decision.graft_length, rows=decision.entry.rows
                ),
            )
        st = self.replicas[decision.replica].submit(routed)
        if st.status is RequestStatus.REJECTED:
            return False
        self._record_failover(fst, st, decision.replica, salvaged=False)
        return True

    def _record_failover(
        self, fst: FleetRequestState, st: RequestState, rid: int,
        salvaged: bool,
    ) -> None:
        obs.event(
            "fleet.failover", track="fleet", rid=fst.request.rid,
            source=fst.replica, target=rid, salvaged=salvaged,
        )
        obs.count(
            "repro_fleet_failovers_total", 1,
            "requests re-admitted off a degraded replica",
        )
        fst._rebind(st, rid)
        self._counts["failovers"] += 1
        if salvaged:
            self._counts["salvaged"] += 1
        if st.terminal:
            self._count_terminal(st)
        else:
            self._by_state[id(st)] = fst

    def idle(self) -> bool:
        return not any(r.pending() for r in self.replicas.values())

    def drain(self, max_ticks: int = 10_000) -> list[FleetRequestState]:
        """Step until every replica is idle; raises
        :class:`SchedulerExhaustedError` on tick exhaustion."""
        if max_ticks < 1:
            raise ValueError(
                f"max_ticks must be >= 1 (the drain safety bound), "
                f"got {max_ticks}"
            )
        out: list[FleetRequestState] = []
        for _ in range(max_ticks):
            if self.idle():
                return out
            out += self.step()
        if self.idle():
            return out
        stuck = {
            rid: [st.rid for st in r.scheduler.waiting]
            + [st.rid for st in r.scheduler.running.values()]
            for rid, r in self.replicas.items() if r.pending()
        }
        raise SchedulerExhaustedError(
            f"fleet did not drain after {max_ticks} ticks; undrained "
            f"request ids per replica: {stuck}"
        )

    def stream(self, request: Request):
        """Submit and iterate the request's tokens as they decode.

        The whole fleet makes progress under the hood. Raises
        :class:`RequestRejectedError` on admission rejection and
        :class:`DegradedServiceError` only when the request FAILED with
        no healthy replica to fail over to. Failover mid-stream is
        seamless: a snapshot resume continues the token sequence; a
        from-scratch restart regenerates the identical prefix before
        new tokens appear.
        """
        fst = self.submit(request)
        if fst.status is RequestStatus.REJECTED:
            raise RequestRejectedError(
                f"request {request.rid} rejected: {fst.reject_reason}"
            )
        sent = 0
        while not fst.terminal:
            self.step()
            while sent < len(fst.generated):
                yield fst.generated[sent]
                sent += 1
        if fst.status is RequestStatus.FAILED:
            raise DegradedServiceError(
                f"request {request.rid} failed: {fst.fail_reason}"
            )
        while sent < len(fst.generated):
            yield fst.generated[sent]
            sent += 1

    # -- reporting -----------------------------------------------------------

    def stats(self) -> FleetStats:
        c = self._counts
        per_replica = tuple(
            self.replicas[rid].stats() for rid in sorted(self.replicas)
        )
        return FleetStats(
            n_replicas=len(self.replicas),
            routing=self.routing,
            submitted=c["submitted"],
            finished=c["finished"],
            rejected=c["rejected"],
            expired=c["expired"],
            failed=c["failed"],
            failovers=c["failovers"],
            salvaged=c["salvaged"],
            prefix_hits=self.router.prefix_hits,
            prefix_hit_rate=(
                self.router.prefix_hits / c["submitted"]
                if c["submitted"] else 0.0
            ),
            grafted_tokens=sum(s.grafted_tokens for s in per_replica),
            prefill_tokens=sum(s.prefill_tokens for s in per_replica),
            ticks=sum(s.ticks for s in per_replica),
            decoded=sum(s.decoded for s in per_replica),
            healthy_replicas=len(self._healthy()),
            replicas=per_replica,
        )

    def price(self, n_active: int = 16):
        """Fleet pricing: replicas x the single target's
        :meth:`~repro.compiler.CompiledModel.price` through the
        costmodel seam (every replica programs its own crossbars; they
        tick in parallel)."""
        from repro.core import costmodel

        base = next(iter(self.replicas.values())).compiled.price(n_active)
        return costmodel.fleet_price(
            base, len(self.replicas), n_active=n_active
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"<FleetEngine {len(self.replicas)} replica(s) "
            f"routing={self.routing} healthy={len(self._healthy())}>"
        )
