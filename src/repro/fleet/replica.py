"""One fleet replica: a compiled target's serving engine + scheduler +
health monitor, wrapped with the identity, load score and clean-tick
watermark the router and pool consume.

The program-once CIM premise makes a replica cheap to reason about:
its crossbars were written once in ``compile()`` and only requests
move. Each replica owns its OWN :class:`~repro.compiler.CompiledModel`
— its own programmed artifacts, jit caches and (when the target
injects faults) its own :class:`~repro.faults.monitor.HealthMonitor` —
so one replica's fault remap or degradation never perturbs another.
"""

from __future__ import annotations

from repro.serving.scheduler import (
    Request,
    RequestState,
    SchedulerConfig,
    SlotSnapshot,
)


class Replica:
    """``CompiledModel.serve()`` + per-replica identity and health."""

    def __init__(
        self,
        rid: int,
        compiled,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        scheduler: SchedulerConfig | None = None,
    ):
        self.rid = int(rid)
        self.compiled = compiled
        self.serving = compiled.serve(
            max_batch=max_batch, max_len=max_len, scheduler=scheduler
        )

    # -- health --------------------------------------------------------------

    @property
    def scheduler(self):
        return self.serving.scheduler

    @property
    def healthy(self) -> bool:
        """False once this replica's service degraded (fault tolerance
        out of moves) — it then rejects all new work."""
        return self.scheduler.degraded_reason is None

    @property
    def degraded_reason(self) -> str | None:
        return self.scheduler.degraded_reason

    def trusts(self, snap: SlotSnapshot) -> bool:
        """Is a snapshot taken on THIS replica bit-trusted after its
        degradation? Trusted iff taken at or before the health
        monitor's last probe-clean tick (no persistent corruption
        existed then). A replica without fault injection never
        corrupts, so every snapshot is trusted."""
        if self.serving.health is None:
            return True
        return snap.tick <= self.serving.health.last_clean_tick

    # -- load ----------------------------------------------------------------

    def load_score(self) -> float:
        """The router's load signal, lower = freer. Committed KV tokens
        plus slot-capacity-weighted queue depth dominate (absolute
        occupancy now); the mean TTFT and end-to-end latency gauges
        break ties toward historically faster replicas."""
        s = self.scheduler
        occupancy = s.kv_committed() + len(s.waiting) * self.serving.slot_capacity
        st = s.stats()
        return occupancy + st.ticks_to_first_token + st.request_latency_ticks

    def pending(self) -> bool:
        """Work left: queued/running requests, or terminal states a
        mid-tick degrade parked for the next ``step()``."""
        return not self.scheduler.idle() or self.scheduler.pending_terminal()

    # -- thin serving delegates ----------------------------------------------

    def submit(self, request: Request) -> RequestState:
        return self.serving.submit(request)

    def adopt(self, request: Request, *, generated=(), snapshot=None):
        return self.scheduler.adopt(
            request, generated=generated, snapshot=snapshot
        )

    def step(self) -> list[RequestState]:
        return self.serving.step()

    def stats(self):
        return self.serving.stats()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "healthy" if self.healthy else "degraded"
        return (
            f"<Replica {self.rid} {self.compiled.target.engine} {state} "
            f"running={len(self.scheduler.running)} "
            f"waiting={len(self.scheduler.waiting)}>"
        )
