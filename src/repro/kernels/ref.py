"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert allclose against
these references (bit-exact for the integer paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def xnor_matmul_ref(a_signs: Array, w_signs: Array) -> Array:
    """±1 binary matmul — ground truth for the packed XNOR+popcount kernel.

    (B, m) x (m, n) -> (B, n), integer-valued.
    """
    return jnp.matmul(a_signs.astype(jnp.float32), w_signs.astype(jnp.float32)).astype(jnp.int32)


def hamming_matmul_ref(a_bits: Array, w_bits: Array) -> Array:
    """Σ_k popcount(a_k XOR w_k) over the contraction — what the packed
    kernel accumulates internally. (B, m){0,1} x (m, n){0,1} -> (B, n)."""
    diff = jnp.not_equal(a_bits[..., :, None, :], w_bits.T[None, :, :]).astype(jnp.int32)
    return diff.sum(-1)


def wdm_mmm_ref(groups: Array, w: Array) -> Array:
    """WDM MMM oracle: (G, K, m) x (m, n) -> (G, K, n), fp32 accumulation."""
    return jnp.einsum(
        "gkm,mn->gkn", groups.astype(jnp.float32), w.astype(jnp.float32)
    )


def bitlinear_ref(x: Array, w_signs: Array, alpha: Array) -> Array:
    """Fused binarize->matmul->rescale oracle.

    out = (sign(x) @ w_signs) * alpha, sign(0) := +1, fp32 result.
    """
    xs = jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32)
    return (xs @ w_signs.astype(jnp.float32)) * alpha[None, :]


def attention_ref(q: Array, k: Array, v: Array, causal: bool = True) -> Array:
    """Dense softmax attention. q (B,H,Sq,D); k/v (B,KV,Skv,D), KV | H."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    kh = jnp.repeat(k, g, axis=1)
    vh = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kh.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vh.astype(jnp.float32)).astype(q.dtype)
