"""Version shims + shared helpers for the Pallas TPU API surface.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across JAX releases; the kernels in this package run
on both spellings via this alias.
"""

from __future__ import annotations

import functools

import jax.experimental.pallas.tpu as pltpu


@functools.cache
def default_interpret() -> bool:
    """True when the default JAX backend is CPU.

    The Pallas kernels target TPU; off-TPU they run in interpret mode so
    the whole suite is testable anywhere. The backend probe touches the
    platform registry, so it is memoized here once per process instead
    of being re-evaluated on every kernel call (it was previously inlined
    in each wrapper).
    """
    import jax

    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``interpret=None`` means "interpret iff running on CPU"."""
    return default_interpret() if interpret is None else bool(interpret)

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # fail at import, not at the first kernel call
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is not supported by the "
        "Pallas kernels in repro.kernels"
    )
