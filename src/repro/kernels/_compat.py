"""Version shims for the Pallas TPU API surface.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across JAX releases; the kernels in this package run
on both spellings via this alias.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # fail at import, not at the first kernel call
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is not supported by the "
        "Pallas kernels in repro.kernels"
    )
