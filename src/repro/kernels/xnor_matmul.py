"""Packed XNOR+popcount matmul — the TPU-native TacitMap crossbar step.

The paper stores 1 bit per oPCM cell; the TPU translation of that
density is *bit-packing*: 32 binary weights/activations per int32 lane,
XOR + population_count on the VPU, int32 accumulation. HBM traffic
drops 32x vs fp32 (16x vs bf16) — the memory-roofline equivalent of the
crossbar's "weights live where the compute is".

Identity (Eq. 1 of the paper, word-packed): for ±1 vectors encoded as
{0,1} bits packed into words,

    dot±1(a, w) = m - 2 * Σ_words popcount(a_word XOR w_word)

The kernel computes the Hamming term; the `ops.py` wrapper applies the
affine correction. Pad bits are ZERO in both operands, so they XOR to
zero and drop out of the sum (tests cover ragged m).

Kernel geometry
---------------
grid = (M/bm, N/bn, KW/bkw); each step loads an int32 block of packed
activations (bm, bkw) and packed weights (bkw, bn) into VMEM and
accumulates the (bm, bn) int32 Hamming block with an unrolled
outer-product loop over the bkw word columns (static unroll — TPU VPU
friendly, no dynamic vreg indexing). The contraction grid dimension is
marked "arbitrary" so XLA keeps the accumulation in VMEM across steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams, resolve_interpret

Array = jax.Array

# Block sizes: (bm, bn) int32 accumulator = 128*128*4 B = 64 KiB in VMEM;
# packed operand blocks are a few KiB. Comfortably under ~16 MiB VMEM.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BKW = 16  # 16 words = 512 bits of contraction per step


def _hamming_kernel(a_ref, w_ref, o_ref, *, bkw: int):
    """o += Σ_k popcount(a[:, k] ^ w[k, :]) — one grid step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (bm, bkw) int32
    w = w_ref[...]  # (bkw, bn) int32
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for k in range(bkw):  # static unroll: VPU outer products
        x = jax.lax.bitwise_xor(a[:, k][:, None], w[k, :][None, :])
        acc = acc + jax.lax.population_count(x)
    o_ref[...] += acc


def hamming_matmul_packed(
    a_packed: Array,
    w_packed: Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    interpret: bool | None = None,
) -> Array:
    """(B, KW) int32 x (KW, N) int32 -> (B, N) int32 Hamming sums.

    Operands must be pre-padded to multiples of the block sizes (the
    ``ops`` wrapper does this; zero pad-words are harmless).
    """
    interpret = resolve_interpret(interpret)
    B, KW = a_packed.shape
    KW2, N = w_packed.shape
    # Named errors, not asserts: asserts vanish under ``python -O`` and a
    # mismatched word count would silently corrupt the Hamming sums.
    if KW != KW2:
        raise ValueError(
            f"packed word-count mismatch: activations carry {KW} int32 words "
            f"but weights carry {KW2}"
        )
    if B % bm or N % bn or KW % bkw:
        raise ValueError(
            f"operands must be pre-padded to block multiples: shape "
            f"({B}, {KW}) x ({KW}, {N}) vs blocks bm={bm}, bn={bn}, bkw={bkw}"
        )

    grid = (B // bm, N // bn, KW // bkw)
    kernel = functools.partial(_hamming_kernel, bkw=bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkw, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_packed, w_packed)
