"""WDM MMM kernel — EinsteinBarrier's K-wavelength step on the MXU.

The paper's WDM turns a VMM into an MMM: K input vectors share one pass
through the crossbar (Fig. 5-(b)). The MXU analogue: the K wavelengths
are the *sublane-batched rows* of a (K, m) @ (m, n) matmul — one systolic
pass serves all K rows, exactly the "same weights, K simultaneous
inputs" structure. ±1 values are carried in bf16 (exactly representable;
fp32 accumulation keeps integer exactness for m < 2^24).

Kernel geometry: grid (B/bb, N/bn, M/bm) over a (B, m) lhs where
B = G*K flattened wavelength groups; fp32 (bb, bn) accumulator block in
VMEM; contraction dimension marked "arbitrary".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams, resolve_interpret

Array = jax.Array

DEFAULT_BB = 128   # wavelength-batch rows per block (G*K flattened)
DEFAULT_BN = 128
DEFAULT_BM = 512   # contraction slice


def _mmm_kernel(a_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def mmm(
    lhs: Array,
    rhs: Array,
    *,
    bb: int = DEFAULT_BB,
    bn: int = DEFAULT_BN,
    bm: int = DEFAULT_BM,
    interpret: bool | None = None,
) -> Array:
    """(B, M) x (M, N) -> (B, N) fp32, MXU-blocked.

    Operands must be pre-padded to block multiples (ops wrapper).
    """
    interpret = resolve_interpret(interpret)
    B, M = lhs.shape
    M2, N = rhs.shape
    if M != M2:
        raise ValueError(f"contraction mismatch: lhs has {M} cols, rhs {M2} rows")
    if B % bb or N % bn or M % bm:
        raise ValueError(
            f"operands must be pre-padded to block multiples: shape "
            f"({B}, {M}) x ({M}, {N}) vs blocks bb={bb}, bn={bn}, bm={bm}"
        )
    grid = (B // bb, N // bn, M // bm)
    return pl.pallas_call(
        _mmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lhs, rhs)
