"""Fused BNN decode-tick kernel: binarize + pack + XNOR + scale in one pass.

The paper's speed claim is that the crossbar collapses a whole
matrix–matrix step into one in-memory activation: weights never move and
activations stream through exactly once (PAPER.md, Eq. 1). The unfused
TPU translation leaves that on the table — every decode tick runs
binarize -> ``pack_bits`` -> ``hamming_matmul_packed`` -> affine
correction -> per-token rescale as *separate* XLA ops, with the raw
activation block crossing HBM between each. This kernel is the fused
read path: raw fp activations in, scaled BitLinear output out, one
``pallas_call``.

Per grid step the kernel

1. loads a raw fp32 activation block (bm, bkw*32) into VMEM,
2. binarizes in-register (``x >= 0`` -> bit 1, matching
   ``bnn.binarize_ste`` — zero maps to +1) and bit-packs 32 lanes per
   int32 word exactly like ``ops.pack_bits``,
3. XORs against the prepared weight words and accumulates popcounts
   straight into the live fp32 output block (same unrolled
   outer-product loop as ``xnor_matmul.py``; the block index map drops
   the contraction dim, so the block stays resident across k steps —
   popcount partials are small integers, exactly representable in fp32),
4. on the last contraction step rewrites the block in place with the
   Eq. 1 affine correction ``dot = m - 2 * hamming`` and the BitLinear
   rescale ``out = dot * (alpha * beta)`` — ``alpha * beta`` is
   multiplied FIRST, reproducing ``models.layers.dense``'s f32
   association so the fused path stays bit-exact against the reference
   engine.

No VMEM scratch is used: the Hamming count lives in the output block
itself and activation words are re-packed per output-column block.  The
re-pack is a handful of VPU ops against a block already in VMEM, while a
scratch accumulator forces the interpreter (CPU CI) to thread carried
state through every grid step — measured ~3x slower per launch.

Grid = (B/bm, N/bn, KW/bkw) where B is all leading dims flattened — the
serving engine's stacked (G, K, m) grouped activations run as one launch
with B = G*K. Pad discipline: the ops wrapper pads activation FEATURES
with -1.0 (binarizes to bit 0) and weights with zero words, so pad bits
XOR to zero and drop out of the Hamming sum; ``m`` carries the true
contraction length for the affine correction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams, resolve_interpret

Array = jax.Array

WORD = 32

# Same budget as xnor_matmul: the fp32 activation block dominates at
# (bm, bkw*32) * 4 B = 256 KiB; int32 scratch accumulator is 64 KiB.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BKW = 16


def _fused_kernel(x_ref, w_ref, alpha_ref, beta_ref, o_ref, *, bkw: int, m: int):
    """One grid step of the fused binarize-pack-popcount-scale pass."""
    kblk = pl.program_id(2)

    @pl.when(kblk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # binarize + bit-pack in VMEM: (bm, bkw*32) fp32 -> (bm, bkw) int32
    # words, bit i of word j = element 32j+i (ops.pack_bits layout).
    x = x_ref[...]
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(bits.shape[0], bkw, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    words = jax.lax.bitcast_convert_type(
        jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32), jnp.int32
    )

    w = w_ref[...]  # (bkw, bn) int32 prepared weight words
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for k in range(bkw):  # static unroll: VPU outer products
        xw = jax.lax.bitwise_xor(words[:, k][:, None], w[k, :][None, :])
        acc = acc + jax.lax.population_count(xw)
    # Hamming partials are integers < bkw*32 * bm-blocks <= m << 2^24,
    # so the fp32 running sum in the output block is exact.
    o_ref[...] += acc.astype(jnp.float32)

    # last contraction step: affine correction + BitLinear rescale,
    # rewriting the accumulated Hamming count in place.
    @pl.when(kblk == pl.num_programs(2) - 1)
    def _finish():
        dot = m - 2.0 * o_ref[...]  # exact: integer-valued fp32
        # (alpha * beta) FIRST — same f32 association as layers.dense.
        o_ref[...] = dot * (alpha_ref[...] * beta_ref[...])


def fused_bnn_matmul_kernel(
    x: Array,
    w_packed: Array,
    alpha: Array,
    beta: Array,
    *,
    m: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    interpret: bool | None = None,
) -> Array:
    """(B, KW*32) fp32 x (KW, N) words x (1, N) x (B, 1) -> (B, N) fp32.

    Operands must be pre-padded to block multiples (the ``ops`` wrapper
    does this; activation pad columns must binarize to bit 0, i.e. be
    negative). ``m`` is the true contraction length for Eq. 1.
    """
    interpret = resolve_interpret(interpret)
    B, MP = x.shape
    KW, N = w_packed.shape
    if MP != KW * WORD:
        raise ValueError(
            f"activation block carries {MP} features but weights carry "
            f"{KW} words = {KW * WORD} bits"
        )
    if alpha.shape != (1, N) or beta.shape != (B, 1):
        raise ValueError(
            f"scale shapes must be alpha (1, {N}) / beta ({B}, 1), got "
            f"{alpha.shape} / {beta.shape}"
        )
    if B % bm or N % bn or KW % bkw:
        raise ValueError(
            f"operands must be pre-padded to block multiples: shape "
            f"({B}, {MP}) x ({KW}, {N}) vs blocks bm={bm}, bn={bn}, bkw={bkw}"
        )

    grid = (B // bm, N // bn, KW // bkw)
    kernel = functools.partial(_fused_kernel, bkw=bkw, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw * WORD), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkw, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_packed, alpha, beta)
