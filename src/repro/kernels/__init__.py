"""Pallas TPU kernels for the compute hot-spots.

  xnor_matmul.py       packed XNOR+popcount matmul (TacitMap's crossbar
                       step, bit-packed for the TPU memory hierarchy)
  wdm_mmm.py           K-wavelength MMM on the MXU (EinsteinBarrier's WDM)
  bitlinear.py         fused binarize -> ±1 matmul -> rescale (deploy)
  flash_attention.py   fused online-softmax attention (scores stay in
                       VMEM — the dominant memory-roofline term in the
                       dry-run, see EXPERIMENTS.md §Perf)
  ops.py               jit'd public wrappers (packing, padding, Eq. 1)
  ref.py               pure-jnp oracles

Kernels target TPU (BlockSpec VMEM tiling, MXU-aligned blocks) and are
validated on CPU with interpret=True.
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
