"""Fused BitLinear kernel: binarize -> ±1 matmul -> per-column rescale.

This is the deployable form of the paper's technique inside an LM layer
(DESIGN.md §4): activations are sign-binarized *inside* the kernel (no
fp activation round-trip to HBM), multiplied against pre-binarized ±1
weights on the MXU, and rescaled by the per-output-channel fp scale in
the same VMEM residency. One kernel = binarize + XNOR-popcount-matmul +
dequant, the fusion a crossbar gets for free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams, resolve_interpret

Array = jax.Array

DEFAULT_BB = 128
DEFAULT_BN = 128
DEFAULT_BM = 512


def _bitlinear_kernel(x_ref, w_ref, alpha_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    xs = jnp.where(x >= 0, 1.0, -1.0).astype(jnp.bfloat16)  # in-kernel binarize
    o_ref[...] += jnp.dot(xs, w_ref[...], preferred_element_type=jnp.float32)

    # rescale once, after the last contraction step
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _scale():
        o_ref[...] *= alpha_ref[...]


def bitlinear(
    x: Array,
    w_signs: Array,
    alpha: Array,
    *,
    bb: int = DEFAULT_BB,
    bn: int = DEFAULT_BN,
    bm: int = DEFAULT_BM,
    interpret: bool | None = None,
) -> Array:
    """(B, M) fp x (M, N) ±1 x (N,) scale -> (B, N) fp32.

    Operands pre-padded to block multiples; pad columns of ``x`` must be
    >= 0 or exactly 0 — they binarize to +1 and hit zero pad *rows* of
    ``w`` (the ops wrapper pads w with zeros), contributing 0.
    """
    interpret = resolve_interpret(interpret)
    B, M = x.shape
    M2, N = w_signs.shape
    if M != M2:
        raise ValueError(f"contraction mismatch: x has {M} cols, w {M2} rows")
    if B % bb or N % bn or M % bm:
        raise ValueError(
            f"operands must be pre-padded to block multiples: shape "
            f"({B}, {M}) x ({M}, {N}) vs blocks bb={bb}, bn={bn}, bm={bm}"
        )
    grid = (B // bb, N // bn, M // bm)
    return pl.pallas_call(
        _bitlinear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_signs.astype(jnp.bfloat16), alpha.reshape(1, -1).astype(jnp.float32))
