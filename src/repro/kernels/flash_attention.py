"""Fused flash attention (forward) — Pallas TPU kernel.

Why: the roofline table (EXPERIMENTS.md §Roofline) shows the memory
term dominating nearly every cell, and the per-computation byte
attribution puts the bulk of it in attention score/probability
materialization — the jnp flash path writes (B, H, Sq, C) fp32 score
blocks to HBM on every KV chunk (~2 GB per chunk-step on qwen2-72b
train). This kernel keeps the entire online-softmax state (scores,
probabilities, m/l accumulators) in VMEM: HBM traffic drops to the
q/k/v/o tensors themselves.

Geometry
--------
grid = (B, H, Sq/bq, Skv/bk) — the KV dimension is the innermost
(sequential) axis; (m, l, acc) live in VMEM scratch across its steps.
GQA costs nothing: the K/V BlockSpec index_map divides the head index
by the group size, so grouped heads read the same KV block without any
materialized repeat.

Causal masking positions each block with absolute offsets; blocks
entirely above the diagonal still run (simplicity > skip logic here —
the scheduler-level win of skipping is an optimization documented in
EXPERIMENTS.md §Perf).

Validated in interpret mode against ``ref.attention_ref`` across shape/
dtype/GQA sweeps (tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams, resolve_interpret

Array = jax.Array

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, n_k: int,
                  diag_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    if causal:
        # queries are the LAST sq positions when Skv > Sq (decode-style)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + diag_offset
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    if causal:  # fully-masked rows: keep p exactly zero
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> Array:
    """q (B, H, Sq, D); k/v (B, KV, Skv, D) with KV | H. -> (B, H, Sq, D).

    Scores/probabilities never leave VMEM. Sq/Skv are padded to block
    multiples internally (padded keys are masked by position).
    """
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    interpret = resolve_interpret(interpret)

    bq_ = min(bq, max(sq, 8))
    bk_ = min(bk, max(skv, 8))
    pad_q = (-sq) % bq_
    pad_k = (-skv) % bk_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, skv_p = q.shape[2], k.shape[2]
    n_k = skv_p // bk_

    # padded keys must never win the softmax: causal masking handles the
    # tail when causal; for non-causal, mask via an explicit bias would
    # be needed — callers pad KV themselves in that case (asserted):
    if not causal and pad_k:
        raise ValueError("non-causal flash_attention requires Skv % bk == 0")

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal or bool(pad_k),
        bq=bq_, bk=bk_, n_k=n_k, diag_offset=(skv - sq) if causal else 0,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, sq_p // bq_, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b_, h_, qi, ki, g_=g: (b_, h_ // g_, ki, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b_, h_, qi, ki, g_=g: (b_, h_ // g_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
