"""Public jit'd wrappers around the Pallas kernels.

Handles bit-packing, padding to block multiples, the Eq. 1 affine
correction, and shape restoration — callers pass ordinary arrays.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import _compat
from repro.kernels import bitlinear as _bitlinear_kernel
from repro.kernels import fused_decode as _fused_kernel
from repro.kernels import wdm_mmm as _wdm_kernel
from repro.kernels import xnor_matmul as _xnor_kernel

Array = jax.Array

WORD = 32


# ---------------------------------------------------------------------------
# Bit packing
# ---------------------------------------------------------------------------


def pack_bits(bits: Array, axis: int = -1) -> Array:
    """Pack {0,1} values into int32 words along ``axis`` (zero-padded).

    (..., m) -> (..., ceil(m/32)); bit i of word j is element 32*j + i.
    """
    bits = jnp.moveaxis(bits, axis, -1)
    m = bits.shape[-1]
    kw = math.ceil(m / WORD)
    pad = kw * WORD - m
    b = jnp.pad(bits.astype(jnp.uint32), [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = b.reshape(*b.shape[:-1], kw, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    words = jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)  # disjoint bits: sum == or
    return jnp.moveaxis(jax.lax.bitcast_convert_type(words, jnp.int32), -1, axis)


def pack_signs(signs: Array, axis: int = -1) -> Array:
    """Pack ±1 values (bit = 1 for +1) into int32 words."""
    return pack_bits((signs > 0).astype(jnp.uint32), axis)


def pack_weights(w_signs: Array) -> Array:
    """Program ±1 weights for the packed kernel: (m, n) -> (ceil(m/32), n).

    int32 words packed along the contraction axis (bit = 1 for +1, zero
    pad bits). This is the packed backend's one-time "crossbar
    programming" step — callers that hold weights resident (the
    prepared-weights path, ``Engine.prepare``) pay it once and then
    stream only activations through :func:`xnor_matmul_packed_weights`.
    """
    return pack_bits((w_signs > 0).astype(jnp.uint32), axis=0)


def pad_packed_weights(
    w_packed: Array,
    *,
    bkw: int = _xnor_kernel.DEFAULT_BKW,
    bn: int = _xnor_kernel.DEFAULT_BN,
) -> Array:
    """Pre-pad packed weight words to kernel block multiples at *program*
    time: (KW, n) -> (ceil(KW/bkw)*bkw, ceil(n/bn)*bn), zero pad words.

    The execute-phase wrappers re-pad every call; ``_pad_to`` is a no-op
    on already-aligned operands, so paying the padding once here removes
    the per-tick ``jnp.pad`` of the (large) weight side from the decode
    graph. Zero pad words XOR to zero against zero activation pad bits
    and drop out of the Hamming sum, and the wrappers slice with the
    *logical* ``m``/``n``, so results are bit-identical either way.
    """
    return _pad_to(_pad_to(w_packed, bkw, 0), bn, 1)


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _row_block(requested: int, size: int, unit: int = 8) -> int:
    """Shrink a row-block size for operands smaller than one block.

    The kernels require dims to be block multiples, so small/ragged
    operands (the model zoo's odd layer widths, single-vector batches)
    are padded UP — but padding a 6-row batch to a 128-row block wastes
    ~20x the kernel work. Rows are the TPU sublane dim, so any multiple
    of the sublane tile (8 for int32/fp32 operands, 16 for bf16) is a
    legal block: clamp to the operand size rounded up to ``unit``.
    Lane-dim blocks (n, packed words) stay as requested — sub-128 lane
    tiles are where Mosaic layouts get inefficient.
    """
    return min(requested, max(unit, -(-size // unit) * unit))


# ---------------------------------------------------------------------------
# XNOR matmul (packed popcount path)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("m", "n", "bm", "bn", "bkw", "interpret"))
def xnor_matmul_packed_weights(
    a_signs: Array,
    w_packed: Array,
    *,
    m: int,
    n: int,
    bm: int = _xnor_kernel.DEFAULT_BM,
    bn: int = _xnor_kernel.DEFAULT_BN,
    bkw: int = _xnor_kernel.DEFAULT_BKW,
    interpret: bool | None = None,
) -> Array:
    """±1 binary matmul against pre-packed weights (:func:`pack_weights`).

    (..., m) x (ceil(m/32), n) words -> (..., n) int32. ``m``/``n`` are
    the *logical* weight dims (static): the word padding carries zero
    bits, and the Eq. 1 affine correction ``dot = m - 2 * hamming``
    needs the true contraction length. Only the activation side packs
    per call — this is the execute phase of the two-phase contract.
    """
    lead = a_signs.shape[:-1]
    a2 = a_signs.reshape(-1, m)
    ap = pack_bits((a2 > 0).astype(jnp.uint32))
    bm_eff = _row_block(bm, a2.shape[0])
    ap = _pad_to(_pad_to(ap, bm_eff, 0), bkw, 1)
    wp = _pad_to(_pad_to(w_packed, bkw, 0), bn, 1)
    ham = _xnor_kernel.hamming_matmul_packed(ap, wp, bm=bm_eff, bn=bn, bkw=bkw, interpret=interpret)
    out = m - 2 * ham[: a2.shape[0], :n]
    return out.reshape(*lead, n)


def xnor_matmul(
    a_signs: Array,
    w_signs: Array,
    *,
    bm: int = _xnor_kernel.DEFAULT_BM,
    bn: int = _xnor_kernel.DEFAULT_BN,
    bkw: int = _xnor_kernel.DEFAULT_BKW,
    interpret: bool | None = None,
) -> Array:
    """±1 binary matmul via the packed XNOR+popcount Pallas kernel.

    (..., m) x (m, n) -> (..., n) int32. Bit-exact vs the ±1 matmul:
    dot = m - 2 * hamming. Packs the weights then delegates to
    :func:`xnor_matmul_packed_weights` — one execution path, so the raw
    and prepared-weight routes are bit-identical by construction.
    """
    return xnor_matmul_packed_weights(
        a_signs,
        pack_weights(w_signs),
        m=int(a_signs.shape[-1]),
        n=int(w_signs.shape[1]),
        bm=bm,
        bn=bn,
        bkw=bkw,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused BNN decode tick (binarize + pack + XNOR + popcount + scale)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("m", "n", "bm", "bn", "bkw", "interpret"))
def fused_bnn_matmul(
    x: Array,
    w_packed: Array,
    alpha: Array,
    *,
    m: int,
    n: int,
    bm: int = _fused_kernel.DEFAULT_BM,
    bn: int = _fused_kernel.DEFAULT_BN,
    bkw: int = _fused_kernel.DEFAULT_BKW,
    interpret: bool | None = None,
) -> Array:
    """Whole fused BitLinear against prepared weights, one kernel launch.

    (..., m) raw fp x (ceil(m/32), n) words x alpha -> (..., n) fp32 of
    ``(binarize(x) @ w±1) * (alpha * beta)`` with ``beta = mean|x|`` per
    row — the full ``models.layers.dense`` BNN seam fused into a single
    ``pallas_call`` (binarize, bit-pack, XNOR+popcount, Eq. 1 affine
    correction and rescale all happen in VMEM; the raw activation block
    crosses HBM exactly once). Leading dims flatten, so the serving
    engine's stacked (G, K, m) grouped activations are one launch.

    ``alpha`` is a scalar (one per-tensor scale) or an (n,) vector (the
    concatenated [q|k|v] fused projection). ``beta`` is computed here
    with the same f32 expression as ``dense`` so the fused path is
    bit-exact vs the unfused reference. Activation feature padding uses
    -1.0: pad columns binarize to bit 0 and drop out of the Hamming sum
    against the zero weight pad words.
    """
    lead = x.shape[:-1]
    beta = jnp.mean(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True)
    x2 = x.reshape(-1, m).astype(jnp.float32)
    rows = x2.shape[0]
    beta2 = beta.reshape(rows, 1)
    alpha2 = jnp.broadcast_to(
        jnp.asarray(alpha, jnp.float32).reshape(-1), (n,)
    ).reshape(1, n)

    kw = math.ceil(m / WORD)
    # ``w_packed`` may arrive pre-padded to block multiples (the packed
    # engine's ``prepad`` programming layout) — treat its stored word
    # rows as the contraction extent; extra rows are zero pad words the
    # -1.0 activation pads cancel against.
    kw_w = w_packed.shape[0]
    if kw_w < kw:
        raise ValueError(
            f"prepared weights carry {kw_w} words but m={m} needs {kw}"
        )
    # Block-size policy. Blocking exists for VMEM locality; the CPU
    # interpreter (CI) has no VMEM and instead pays a large fixed cost
    # PER GRID STEP, so there the fastest launch is a single-step grid
    # covering the whole operand (capped at 128 words to bound the
    # statically unrolled popcount loop). Compiled TPU keeps the real
    # block tiling: words are the sublane dim of the weight block, so
    # blocks stay multiples of 8 (lanes 8*32=256 stay 128-aligned).
    if _compat.resolve_interpret(interpret) and kw_w <= 128:
        bm_eff, bn, bkw = rows, w_packed.shape[1], kw_w
    else:
        bm_eff = _row_block(bm, rows)
        # Clamp the contraction word-block to the operand: the fused
        # kernel binarizes + packs its activation block IN-kernel, so
        # every padded word costs 32 fp32 pad columns of packing work
        # per grid step — far pricier than the zero pad-words of the
        # packed-operand kernel. A narrow model (kw=2 vs the default
        # bkw=16) would otherwise spend 8x the packing on dead columns.
        bkw = min(bkw, max(8, -(-kw_w // 8) * 8))
    kw_pad = -(-kw_w // bkw) * bkw
    # feature pads binarize to bit 0 (negative); pad rows are sliced away
    x2 = jnp.pad(
        x2, [(0, (-rows) % bm_eff), (0, kw_pad * WORD - m)], constant_values=-1.0
    )
    wp = _pad_to(_pad_to(w_packed, bkw, 0), bn, 1)
    out = _fused_kernel.fused_bnn_matmul_kernel(
        x2,
        wp,
        _pad_to(alpha2, bn, 1),
        _pad_to(beta2, bm_eff, 0),
        m=m,
        bm=bm_eff,
        bn=bn,
        bkw=bkw,
        interpret=interpret,
    )
    return out[:rows, :n].reshape(*lead, n)


# ---------------------------------------------------------------------------
# WDM MMM
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bb", "bn", "bm", "interpret"))
def wdm_mmm(
    groups: Array,
    w: Array,
    *,
    bb: int = _wdm_kernel.DEFAULT_BB,
    bn: int = _wdm_kernel.DEFAULT_BN,
    bm: int = _wdm_kernel.DEFAULT_BM,
    interpret: bool | None = None,
) -> Array:
    """(G, K, m) x (m, n) -> (G, K, n): K wavelengths per systolic pass."""
    g, k, m = groups.shape
    lhs = groups.reshape(g * k, m).astype(jnp.bfloat16)
    bb = _row_block(bb, g * k, unit=16)  # bf16 sublane tile
    lhs = _pad_to(_pad_to(lhs, bb, 0), bm, 1)
    rhs = _pad_to(_pad_to(w.astype(jnp.bfloat16), bm, 0), bn, 1)
    out = _wdm_kernel.mmm(lhs, rhs, bb=bb, bn=bn, bm=bm, interpret=interpret)
    return out[: g * k, : w.shape[1]].reshape(g, k, w.shape[1])


# ---------------------------------------------------------------------------
# BitLinear (fused binarize + matmul + rescale)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bb", "bn", "bm", "interpret"))
def bitlinear(
    x: Array,
    w_signs: Array,
    alpha: Array,
    *,
    bb: int = _bitlinear_kernel.DEFAULT_BB,
    bn: int = _bitlinear_kernel.DEFAULT_BN,
    bm: int = _bitlinear_kernel.DEFAULT_BM,
    interpret: bool | None = None,
) -> Array:
    """(..., m) fp x (m, n) ±1 x (n,) -> (..., n) fp32 fused BitLinear."""
    m = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, m)
    bb = _row_block(bb, x2.shape[0], unit=16 if x.dtype == jnp.bfloat16 else 8)
    x2 = _pad_to(_pad_to(x2, bb, 0), bm, 1)
    # pad weight ROWS with zeros: pad x columns binarize to +1 and hit
    # zero rows -> contribute nothing (see kernel docstring)
    wp = _pad_to(_pad_to(w_signs, bm, 0), bn, 1)
    ap = _pad_to(alpha, bn, 0)
    out = _bitlinear_kernel.bitlinear(x2, wp, ap, bb=bb, bn=bn, bm=bm, interpret=interpret)
    n = w_signs.shape[1]
    rows = math.prod(lead) if lead else 1
    return out[:rows, :n].reshape(*lead, n)
