"""The training loop, written for the 1000-node failure model.

Fault-tolerance invariants (each one is tested in tests/test_train.py):

1. **Resume == never-failed.** All loop state is (params, opt_state,
   step); data is a pure function of (seed, step) (repro.data). Killing
   the process anywhere and restarting from the latest checkpoint
   reproduces the exact same parameter trajectory.
2. **Checkpoints are atomic and async** (repro.checkpoint): a crash
   mid-write can't corrupt the restore point; writes overlap compute.
3. **Preemption-safe**: SIGTERM sets a flag; the loop checkpoints at
   the next step boundary and exits cleanly (simulated in tests by
   calling the handler directly).
4. **Fault injection**: ``fault_hook(step)`` can raise to simulate node
   loss; the driver-level retry (``train`` with ``max_restarts``)
   demonstrates restart-recovery inside one process. NaN-loss steps are
   skipped (params/opt untouched) and counted — the standard large-run
   guard against data poison / transient numerics.
5. **Elastic**: restore reshards onto whatever mesh the restarted job
   has (checkpoint stores host arrays; shardings come from the current
   partitioner), so a job can come back on fewer/more chips.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import lm_batch
from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    async_checkpoint: bool = True
    max_restarts: int = 2


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, loop: TrainLoopConfig):
    """Returns (params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    loss_fn = encdec_lib.loss_fn if cfg.is_encdec else lm_lib.loss_fn

    def step_fn(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)
        lr = cosine_schedule(
            step,
            peak_lr=loop.peak_lr,
            warmup_steps=loop.warmup_steps,
            total_steps=loop.total_steps,
        )
        new_params, new_opt = adamw_update(grads, params, opt_state, lr, opt_cfg)
        # NaN guard: skip the update entirely on non-finite loss
        ok = jnp.isfinite(loss)
        params_out = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_params, params)
        opt_out = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
        metrics = {"loss": loss, "lr": lr, "skipped": (~ok).astype(jnp.int32)}
        return params_out, opt_out, metrics

    return step_fn


class _Preemption:
    """SIGTERM -> checkpoint-and-exit at the next step boundary."""

    def __init__(self):
        self.requested = False
        self._old = None

    def install(self):
        def handler(signum, frame):
            self.requested = True

        try:
            self._old = signal.signal(signal.SIGTERM, handler)
        except ValueError:  # non-main thread (tests)
            pass
        return self

    def uninstall(self):
        if self._old is not None:
            signal.signal(signal.SIGTERM, self._old)


def train(
    cfg: ModelConfig,
    loop: TrainLoopConfig,
    opt_cfg: OptConfig | None = None,
    *,
    fault_hook: Callable[[int], None] | None = None,
    log: Callable[[str], None] = print,
) -> dict[str, Any]:
    """Run (or resume) training; returns summary with final params.

    ``fault_hook(step)`` may raise RuntimeError to simulate a node
    failure — the loop restarts from the latest checkpoint up to
    ``loop.max_restarts`` times (in production the scheduler restarts
    the job; in-process restart exercises the same code path).
    """
    opt_cfg = opt_cfg or OptConfig()
    mgr = CheckpointManager(loop.checkpoint_dir, keep=loop.keep)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, loop), donate_argnums=(0, 1))
    preempt = _Preemption().install()

    restarts = 0
    losses: list[float] = []
    try:
        while True:
            try:
                params = (
                    encdec_lib.init_params(jax.random.key(loop.seed), cfg)
                    if cfg.is_encdec
                    else lm_lib.init_params(jax.random.key(loop.seed), cfg)
                )
                opt_state = adamw_init(params, opt_cfg)
                start = 0
                if mgr.latest_step() is not None:
                    (params, opt_state), extra = mgr.restore((params, opt_state))
                    start = int(extra["step"]) + 1
                    log(f"[train] resumed from step {start - 1}")

                t0 = time.time()
                for step in range(start, loop.total_steps):
                    if fault_hook is not None:
                        fault_hook(step)
                    batch = lm_batch(
                        cfg, loop.batch_size, loop.seq_len, seed=loop.seed, step=step
                    )
                    params, opt_state, metrics = step_fn(
                        params, opt_state, batch, jnp.asarray(step)
                    )
                    losses.append(float(metrics["loss"]))
                    boundary = (step + 1) % loop.checkpoint_every == 0
                    if boundary or preempt.requested or step == loop.total_steps - 1:
                        save = mgr.save_async if loop.async_checkpoint else mgr.save
                        save(step, (params, opt_state), {"step": step})
                    if preempt.requested:
                        mgr.wait()
                        log(f"[train] preempted at step {step}; checkpointed")
                        return {
                            "params": params,
                            "final_step": step,
                            "losses": losses,
                            "preempted": True,
                            "restarts": restarts,
                        }
                mgr.wait()
                return {
                    "params": params,
                    "final_step": loop.total_steps - 1,
                    "losses": losses,
                    "preempted": False,
                    "restarts": restarts,
                    "steps_per_s": (loop.total_steps - start) / max(time.time() - t0, 1e-9),
                }
            except RuntimeError as e:  # injected node failure
                restarts += 1
                if restarts > loop.max_restarts:
                    raise
                log(f"[train] fault at restart {restarts}: {e}; resuming from checkpoint")
                mgr.wait()
    finally:
        preempt.uninstall()
