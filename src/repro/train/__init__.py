"""Fault-tolerant training loop (checkpoint/restart, preemption-safe,
deterministic restart-safe data)."""

from repro.train.loop import TrainLoopConfig, make_train_step, train
