"""Shared step builders: the jit-able train / prefill / decode entry
points with their sharding pytrees, used by dryrun.py, train.py, and
serve.py. Everything here is shape-only-safe (eval_shape + partitioner
rules) so the dry-run can build 512-device programs without allocating.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data import make_input_specs
from repro.distributed import (
    batch_specs,
    cache_specs,
    infer_specs,
    named_shardings,
    opt_state_specs,
)
from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import OptConfig, adamw_init
from repro.train.loop import TrainLoopConfig, make_train_step


def default_opt_cfg(cfg: ModelConfig) -> OptConfig:
    """Factored second moment for 100B+ models: the difference between
    optimizer state fitting a 256-chip pod or not (DESIGN.md §6)."""
    return OptConfig(factored=cfg.param_count() > 100e9)


def param_shapes(cfg: ModelConfig, *, compute_dtype: bool = True) -> Any:
    """Param ShapeDtypeStructs. ``compute_dtype=True`` (production) holds
    matrices in bf16 — the fp32 master lives in the optimizer state
    (OptConfig.master_weights), so ZeRO-3 weight all-gathers and serve
    arguments move/hold half the bytes. 1-D params (norm scales, biases,
    SSM A/D/dt) stay fp32 for numerics."""
    init = encdec_lib.init_params if cfg.is_encdec else lm_lib.init_params
    tree = jax.eval_shape(lambda: init(jax.random.key(0), cfg))
    if not compute_dtype:
        return tree

    def cast(l):
        # stacked-per-layer tensors have a leading repeat dim: a "matrix"
        # is anything with >= 2 trailing non-repeat dims -> ndim >= 2
        dt = jnp.bfloat16 if (l.ndim >= 2 and l.dtype == jnp.float32) else l.dtype
        return jax.ShapeDtypeStruct(l.shape, dt)

    return jax.tree.map(cast, tree)


def opt_shapes(params_sds: Any, opt_cfg: OptConfig) -> Any:
    # params as an eval_shape ARG (not a closure) so leaves are tracers
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)


# ---------------------------------------------------------------------------
# Cell assembly: (fn, arg SDS tuple, in/out shardings, donate)
# ---------------------------------------------------------------------------


def train_wants_fsdp(cfg: ModelConfig, shape: ShapeConfig, mesh) -> bool:
    """ZeRO-3 batch sharding is chosen on two criteria:

    **Memory criterion**: DP-only residual-stream carries (per-layer
    remat saves) over 4 GiB/dev would blow the 16 GiB v5e budget:
    carry = B*S/dp * d_model * 2B * layers.

    A traffic criterion ("switch when napkin weight-gather bytes <
    TP-psum bytes") was tried and REFUTED by measurement: on
    qwen1.5-0.5b train_4k the collective term went 1.37 s -> 1.75 s
    (+27%) — XLA's ZeRO gather pattern under remat re-gathers far more
    than the 3x-params napkin model (EXPERIMENTS.md §Perf, cell 2 #4).
    """
    from repro.distributed.partitioner import data_axes, fsdp_batch_axes

    if not fsdp_batch_axes(shape.global_batch, mesh):
        return False
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    layers = cfg.n_layers + cfg.n_encoder_layers
    carry = shape.global_batch * shape.seq_len / dp * cfg.d_model * 2 * layers
    return carry > 4 * 2**30


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Everything needed to jit/lower one (arch x shape) cell."""
    from repro.distributed.partitioner import fsdp_batch_axes

    specs = make_input_specs(cfg, shape)
    p_sds = param_shapes(cfg)
    fsdp = shape.kind == "train" and train_wants_fsdp(cfg, shape, mesh)
    p_spec = infer_specs(p_sds, mesh, fsdp=fsdp)
    p_sh = named_shardings(p_spec, mesh)

    if shape.kind == "train":
        opt_cfg = default_opt_cfg(cfg)
        o_sds = opt_shapes(p_sds, opt_cfg)
        o_spec = opt_state_specs(p_spec, o_sds)
        o_sh = named_shardings(o_spec, mesh)
        b_sh = named_shardings(batch_specs(specs, mesh, fsdp=fsdp), mesh)
        loop = TrainLoopConfig(total_steps=10_000, warmup_steps=100)
        fn = make_train_step(cfg, opt_cfg, loop)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        rep = NamedSharding(mesh, P())
        return {
            "fn": fn,
            "args": (p_sds, o_sds, specs, step_sds),
            "in_shardings": (p_sh, o_sh, b_sh, rep),
            "out_shardings": (p_sh, o_sh, None),
            "donate_argnums": (0, 1),
            "hint_kw": (
                {"batch_axes": fsdp_batch_axes(shape.global_batch, mesh), "tp": False}
                if fsdp
                else {}
            ),
        }

    if shape.kind == "prefill":
        b_sh = named_shardings(batch_specs(specs, mesh), mesh)
        if cfg.is_encdec:
            def fn(params, batch):
                return encdec_lib.prefill(params, batch["src_embeds"], batch["tokens"], cfg)
        elif cfg.frontend == "vision":
            def fn(params, batch):
                return lm_lib.prefill(params, batch["tokens"], cfg, batch["extra_embeds"])
        else:
            def fn(params, batch):
                return lm_lib.prefill(params, batch["tokens"], cfg)
        # out: logits data-sharded over batch; caches SP-sharded
        cache_sds = jax.eval_shape(fn, p_sds, specs)[1]
        c_sh = named_shardings(cache_specs(cache_sds, mesh), mesh)
        logits_sh = named_shardings(
            batch_specs(jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.float32), mesh),
            mesh,
        )
        return {
            "fn": fn,
            "args": (p_sds, specs),
            "in_shardings": (p_sh, b_sh),
            "out_shardings": (logits_sh, c_sh),
            "donate_argnums": (),
        }

    # decode: one token against a seq_len-deep cache
    decode = encdec_lib.decode_step if cfg.is_encdec else lm_lib.decode_step

    def fn(params, token, pos, caches):
        return decode(params, token, pos, caches, cfg)

    tok_sh = named_shardings(batch_specs(specs["token"], mesh), mesh)
    c_sh = named_shardings(cache_specs(specs["caches"], mesh), mesh)
    rep = NamedSharding(mesh, P())
    logits_sh = named_shardings(
        batch_specs(jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.float32), mesh), mesh
    )
    return {
        "fn": fn,
        "args": (p_sds, specs["token"], specs["pos"], specs["caches"]),
        "in_shardings": (p_sh, tok_sh, rep, c_sh),
        "out_shardings": (logits_sh, c_sh),
        "donate_argnums": (3,),
    }


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """jit(...).lower(...) for one cell — the dry-run's core call."""
    from repro.distributed.hints import activation_hints

    cell = build_cell(cfg, shape, mesh)
    jitted = jax.jit(
        cell["fn"],
        in_shardings=cell["in_shardings"],
        out_shardings=cell["out_shardings"],
        donate_argnums=cell["donate_argnums"],
    )
    with mesh, activation_hints(mesh, **cell.get("hint_kw", {})):
        lowered = jitted.lower(*cell["args"])
    return lowered
