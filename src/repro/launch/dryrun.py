import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory / cost / collective
analysis. THE FIRST TWO LINES ABOVE MUST STAY FIRST — jax locks the
device count at first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # (2,16,16) mesh only
    PYTHONPATH=src python -m repro.launch.dryrun --out runs/dryrun

Each cell's record is cached as JSON under --out; reruns skip completed
cells (delete the file to force). benchmarks/roofline.py reads these.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data import token_count
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models.config import SHAPES, shape_applicable

MODEL_FLOP_FACTOR = 6  # 6·N·D training FLOPs per token (2 fwd + 4 bwd)


def model_flops(cfg, shape) -> float:
    """6·N_active·D analytic FLOPs for the cell (serve: 2·N·D)."""
    n = cfg.active_param_count()
    toks = token_count(shape)
    factor = 6 if shape.kind == "train" else 2
    return factor * n * toks


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, quant: str = "none") -> dict:
    cfg = get_config(arch, quant=quant)
    shape = SHAPES[shape_name]
    runs, why = shape_applicable(cfg, shape)
    if not runs:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = hlo_analysis.analyze_compiled(compiled, n_chips)
    mf = model_flops(cfg, shape)
    rec.update(
        arch=arch,
        shape=shape_name,
        multi_pod=multi_pod,
        n_chips=n_chips,
        status="ok",
        quant=quant,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        model_flops=mf,
        useful_flop_ratio=(mf / rec["flops"] if rec["flops"] else None),
        tokens=token_count(shape),
    )
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", action="append", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true", help="only the (2,16,16) mesh")
    ap.add_argument("--single-pod", action="store_true", help="only the (16,16) mesh")
    ap.add_argument("--quant", default="none", choices=["none", "bnn"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = args.arch or list(ARCH_IDS)
    shapes = args.shape or list(SHAPES)
    if args.multi_pod and not args.single_pod:
        pods = [True]
    elif args.single_pod and not args.multi_pod:
        pods = [False]
    else:
        pods = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                if args.quant != "none":
                    tag += f"__{args.quant}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached")
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, multi_pod=mp, quant=args.quant)
                except Exception as e:  # a failure here is a bug in our sharding
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape_name, "multi_pod": mp,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[dryrun] {tag}: FAILED {type(e).__name__}: {e}", flush=True)
                    if args.fail_fast:
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=1)
                        return 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    mem = rec["memory"]["peak_per_device_bytes"] / 2**30
                    print(
                        f"[dryrun] {tag}: ok  flops={rec['flops']:.3e} "
                        f"mem/dev={mem:.2f}GiB coll={rec['collectives']['operand_bytes']:.3e}B "
                        f"dominant={r['dominant']} "
                        f"(c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s x={r['collective_s']:.2e}s) "
                        f"compile={rec['compile_s']}s",
                        flush=True,
                    )
                elif rec["status"] == "skipped":
                    print(f"[dryrun] {tag}: skipped ({rec['reason']})", flush=True)
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
