"""Batched serving driver: prefill a batch of prompts, then decode with
a fixed-capacity KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen 16

    # serve the binarized projections through the packed Pallas kernel:
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --engine packed

    # the scheduler-fronted request path: 12 staggered requests through
    # admission control + deadline policy, reported as typed stats:
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 12 --sched-policy deadline --kv-reserve 0.1

Uses the same decode_step the dry-run lowers for the ``decode_*``
cells, so serving on the production mesh is the identical program.

Execution is driven through the one-call hardware-compilation API
(``repro.compiler``): the shared target flags (``--engine``,
``--group-size``, ``--mapping-policy``, ``--tile-budget``,
``--raw-weights`` — installed by ``compiler.add_target_args``) build ONE
:class:`~repro.compiler.HardwareTarget`, and
``compile(cfg, params, target)`` runs plan compilation, engine
resolution and the one-time crossbar-programming phase in the canonical
order. What used to be five separately-threaded knobs::

    eng = get_engine(args.engine, plan=plan, policy=policy)
    cfg = replace(cfg, quant="bnn", bnn_engine=args.engine)
    k = resolve_group_size(eng, args.group_size, args.batch, plan=plan)
    grouped = GroupedEngine(eng, k)
    params, n = lm_lib.program_weights(params, cfg, grouped)

is now::

    compiled = compiler.compile(cfg, params, target_from_args(args))

``--mapping-policy`` (with ``--engine tiled``) compiles the arch's
binarized projections into an explicit layer->tile MappingPlan and
prints the placement summary + cost-model pricing
(``compiled.describe()``):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --smoke --engine tiled --mapping-policy greedy
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def _finish_obs(tel, args, se=None) -> None:
    """Export the telemetry session's artifacts (and, when a serving
    engine ran traced decode ticks, print the measured-vs-modeled
    pricing cross-check). No-op when telemetry is off."""
    if tel is None:
        return
    from repro import obs

    if se is not None and tel.tracer.spans("decode_tick"):
        print("[obs] measured-vs-modeled decode-tick pricing:")
        print(obs.format_report(obs.crosscheck_serving(se, tracer=tel.tracer)))
    tel.write(trace_out=args.trace_out, metrics_out=args.metrics_out)
    if args.trace_out:
        print(f"[obs] wrote Chrome trace (chrome://tracing / Perfetto) -> "
              f"{args.trace_out}")
    if args.metrics_out:
        print(f"[obs] wrote Prometheus-style metrics snapshot -> "
              f"{args.metrics_out}")
    obs.stop()


def _serve_requests(compiled, args, tel=None) -> int:
    """The scheduler-fronted path: N requests with staggered prompt
    lengths through ``submit``/``drain``, reported as typed stats."""
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.data import lm_batch
    from repro.serving import Request

    max_len = args.prompt_len + args.gen
    se = compiled.serve(
        max_batch=args.batch,
        max_len=max_len,
        scheduler=compiler_lib.scheduler_from_args(args),
    )
    tokens = lm_batch(compiled.cfg, args.requests, args.prompt_len,
                      seed=args.seed)["tokens"]
    # the synthetic arrival trace (staggered prompt lengths) draws from
    # its own seed so load patterns reproduce independently of model
    # init; it falls back to --seed when unset
    trace_seed = args.request_seed if args.request_seed is not None else args.seed
    rng = np.random.default_rng(trace_seed)
    states = []
    t0 = time.time()
    for i in range(args.requests):
        # staggered prompt lengths: the scheduler's budget math and
        # K-group planner see a ragged, realistic mix
        plen = int(rng.integers(max(1, args.prompt_len // 2), args.prompt_len + 1))
        states.append(se.submit(Request(
            rid=i,
            prompt=np.asarray(tokens[i, :plen], np.int32),
            max_new_tokens=args.gen,
        )))
    se.drain()
    wall = time.time() - t0

    st = se.stats()
    sch = st.scheduler
    toks = sum(len(s.generated) for s in states)
    print(f"[serve] scheduler: policy={sch.policy} admission={sch.admission} "
          f"K={se.group_k} pool={args.batch}x{max_len} "
          f"(kv budget {sch.kv_budget}, usable {sch.kv_usable})")
    print(f"[serve] drained {args.requests} request(s) in {wall*1e3:.1f} ms "
          f"({toks / max(wall, 1e-9):.1f} tok/s): finished={sch.finished} "
          f"rejected={sch.rejected} expired={sch.expired} "
          f"preempted={sch.preempted} resumed={sch.resumed}")
    print(f"[serve] ticks={st.ticks} decoded={st.decoded} "
          f"mmm_groups={st.mmm_groups} pad_lanes={st.pad_lanes} "
          f"prefills={st.prefills} evictions={st.evictions}")
    print(f"[serve] ttft={sch.ticks_to_first_token:.2f} ticks, "
          f"admission wait={sch.admission_wait_ticks:.2f} ticks, "
          f"max queue depth={sch.max_queue_depth}")
    done = [s for s in states if s.done]
    if done:
        head = done[0]
        print(f"[serve] rid={head.rid} generated[:8] = {head.generated[:8]}")
    _finish_obs(tel, args, se=se)
    return 0


def _serve_fleet(cfg, params, target, args, tel=None) -> int:
    """The fleet path (``--replicas > 1``): N identically-compiled
    replicas behind the prefix-affinity router, driven by the same
    staggered synthetic request trace as the single-replica scheduler
    path (one arrival per fleet tick, so the prefix library is live
    for later arrivals)."""
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.data import lm_batch
    from repro.fleet import FleetEngine
    from repro.serving import Request

    max_len = args.prompt_len + args.gen
    fleet = FleetEngine.build(
        cfg, params, target,
        n_replicas=args.replicas,
        max_batch=args.batch,
        max_len=max_len,
        scheduler=compiler_lib.scheduler_from_args(args),
        routing=args.routing,
        block_size=args.prefix_block,
    )
    tokens = lm_batch(cfg, args.requests, args.prompt_len,
                      seed=args.seed)["tokens"]
    trace_seed = args.request_seed if args.request_seed is not None else args.seed
    rng = np.random.default_rng(trace_seed)
    states = []
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(max(1, args.prompt_len // 2), args.prompt_len + 1))
        states.append(fleet.submit(Request(
            rid=i,
            prompt=np.asarray(tokens[i, :plen], np.int32),
            max_new_tokens=args.gen,
        )))
        fleet.step()
    fleet.drain()
    wall = time.time() - t0

    s = fleet.stats()
    toks = sum(len(st.generated) for st in states)
    print(f"[fleet] {s.n_replicas} replica(s) x {args.batch} slot(s), "
          f"routing={s.routing} (block={args.prefix_block})")
    print(f"[fleet] drained {args.requests} request(s) in {wall*1e3:.1f} ms "
          f"({toks / max(wall, 1e-9):.1f} tok/s): finished={s.finished} "
          f"rejected={s.rejected} expired={s.expired} failed={s.failed}")
    print(f"[fleet] prefix hits={s.prefix_hits} "
          f"(rate {s.prefix_hit_rate:.0%}), grafted={s.grafted_tokens} "
          f"prefilled={s.prefill_tokens} prompt tokens; "
          f"failovers={s.failovers} (salvaged={s.salvaged}), "
          f"healthy={s.healthy_replicas}/{s.n_replicas}")
    per = ", ".join(
        f"r{i}: {r.ticks}t/{r.decoded}d" for i, r in enumerate(s.replicas)
    )
    print(f"[fleet] per-replica ticks/decoded: {per}")
    print(fleet.price(n_active=args.batch).summary())
    done = [st for st in states if st.done]
    if done:
        head = done[0]
        print(f"[fleet] rid={head.rid} replica={head.replica} "
              f"generated[:8] = {head.generated[:8]}")
    _finish_obs(tel, args)
    return 0


def main(argv: list[str] | None = None) -> int:
    from repro import compiler as compiler_lib

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--requests",
        type=int,
        default=0,
        metavar="N",
        help="drive the request scheduler with N independent requests "
        "(staggered prompt lengths, admission control, typed stats) "
        "instead of the lockstep batch loop",
    )
    ap.add_argument(
        "--request-seed",
        type=int,
        default=None,
        metavar="SEED",
        help="seed of the synthetic request trace (staggered prompt "
        "lengths) alone, so arrival patterns reproduce independently "
        "of model init; defaults to --seed",
    )
    # the shared hardware-target surface (engine / K / mapping / prepare)
    compiler_lib.add_target_args(ap)
    # the serve-time scheduler surface (policy / admission / KV reserve)
    compiler_lib.add_scheduler_args(ap)
    # the fleet surface (--replicas / --routing / --prefix-block)
    compiler_lib.add_fleet_args(ap)
    # the telemetry surface (--trace-out / --metrics-out)
    compiler_lib.add_obs_args(ap)
    args = ap.parse_args(argv)
    try:
        target = compiler_lib.target_from_args(args)
    except compiler_lib.TargetError as e:
        ap.error(str(e))
    if target.engine == "reference" and target.group_size:
        # (the serving engine's BatchPlanner can group the plain-jnp
        # path; this batch driver only groups through a backend)
        ap.error("--group-size requires a non-reference --engine")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.data import lm_batch
    from repro.models import encdec as encdec_lib
    from repro.models import lm as lm_lib

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if target.engine == "tiled" and target.mapping_policy is None:
        # `--engine tiled` always executes per an explicit compiled plan
        # here; the policy falls back to the arch config's default
        target = dataclasses.replace(target, mapping_policy=cfg.mapping_policy)
    if cfg.is_encdec:
        # the compiler pipeline covers the decoder-only LM projection
        # stack; enc-dec archs bind the backend via cfg.bnn_engine and
        # reject the decoder-only-serving knobs
        if target.group_size:
            ap.error("--group-size applies to the decoder-only serving path")
        if target.wants_plan:
            ap.error("--mapping-policy/--tile-budget place weights for the "
                     "decoder-only LM projection stack")
        if not target.prepare_weights:
            ap.error("--raw-weights toggles the decoder-only compile "
                     "pipeline's programming phase; the enc-dec path never "
                     "programs weights")
        if target.engine != "reference":
            cfg = dataclasses.replace(cfg, quant="bnn", bnn_engine=target.engine)
            from repro.core import engine as engine_lib

            eng = engine_lib.get_engine(target.engine)
            print(f"[serve] engine={eng.name} ({eng.info.description})")

    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1:
        if cfg.is_encdec:
            ap.error("--replicas drives the decoder-only fleet path")
        if not args.requests:
            ap.error("--replicas > 1 serves the request path; pass --requests N")

    # the telemetry session must be live BEFORE compile() so the
    # pipeline-stage spans (validate/map/resolve/program) are captured
    tel = compiler_lib.obs_from_args(args)

    max_len = args.prompt_len + args.gen
    key = jax.random.key(args.seed)
    params = (
        encdec_lib.init_params(key, cfg) if cfg.is_encdec else lm_lib.init_params(key, cfg)
    )
    if args.replicas > 1:
        # each replica compiles and programs its own copy of the target
        # inside FleetEngine.build — skip the solo compile entirely
        return _serve_fleet(cfg, params, target, args, tel=tel)
    compiled = None
    if not cfg.is_encdec:
        # the one-call pipeline: map (plan) -> resolve (engine) ->
        # program (one-time PCM write); raises named TargetErrors on
        # inconsistent combinations instead of dropping knobs
        try:
            compiled = compiler_lib.compile(cfg, params, target)
        except compiler_lib.TargetError as e:
            ap.error(str(e))
        cfg, params = compiled.cfg, compiled.params
        if compiled.engine is not None:
            print(f"[serve] engine={compiled.engine.name} "
                  f"({compiled.engine.info.description})")
            if compiled.plan is not None:
                print(compiled.describe())
            k = compiled.group_size_for(args.batch)
            print(f"[serve] K-group batching: K={k}, "
                  f"{-(-args.batch // k)} group(s)/tick over batch={args.batch}, "
                  f"idle lanes/tick={-(-args.batch // k) * k - args.batch}")
            if compiled.programmed:
                print(f"[serve] programmed {compiled.programmed} binarized "
                      f"projection instance(s) into {target.engine} resident "
                      f"form ({compiled.program_s * 1e3:.1f} ms, one-time PCM write)")
    if args.requests:
        # scheduler-fronted request path: N independent requests with
        # staggered prompt lengths through submit/drain + typed stats
        if cfg.is_encdec:
            ap.error("--requests drives the decoder-only scheduler path")
        return _serve_requests(compiled, args, tel=tel)

    batch = lm_batch(cfg, args.batch, args.prompt_len, seed=args.seed)
    tokens = batch["tokens"]

    t0 = time.time()
    if cfg.is_encdec:
        logits, pre_caches = jax.jit(
            lambda p, s, t: encdec_lib.prefill(p, s, t, cfg)
        )(params, batch["src_embeds"], tokens)
        caches = encdec_lib.init_cache(cfg, args.batch, max_len, cfg.frontend_len)
        # copy prompt KV into the serving-capacity cache
        caches = dict(
            caches,
            cross_k=pre_caches["cross_k"],
            cross_v=pre_caches["cross_v"],
            self_k=caches["self_k"].at[:, :, : args.prompt_len].set(pre_caches["self_k"]),
            self_v=caches["self_v"].at[:, :, : args.prompt_len].set(pre_caches["self_v"]),
        )
        decode = jax.jit(lambda p, t, pos, c: encdec_lib.decode_step(p, t, pos, c, cfg))

        def decode_step(tok, pos, caches):
            return decode(params, tok, pos, caches)
    else:
        logits, pre_caches = compiled.prefill(tokens, batch.get("extra_embeds"))
        caches = compiled.graft_prefill_caches(
            compiled.init_cache(args.batch, max_len), pre_caches
        )
        decode_step = compiled.decode_step
    # fence the phase: JAX dispatch is async, so without block_until_ready
    # this would time the enqueue, not the prefill + cache graft
    jax.block_until_ready((logits, caches))
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    # positions continue after the prompt (+ any frontend prefix)
    base = args.prompt_len + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    for i in range(args.gen - 1):
        logits, caches = decode_step(tok, jnp.asarray(base + i, jnp.int32), caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    # fence tokens AND the final cache state — the decode phase isn't
    # done until its last KV write lands
    jax.block_until_ready((out[-1], caches))
    t_decode = time.time() - t0

    gen = jnp.stack(out, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"quant={cfg.quant} engine={cfg.bnn_engine}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode {args.gen - 1} steps "
          f"{t_decode*1e3:.1f} ms ({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    if compiled is not None and compiled.engine is not None and args.gen > 1:
        k = compiled.group_size_for(args.batch)
        ticks = args.gen - 1
        groups = ticks * -(-args.batch // k)
        slot_steps = ticks * args.batch
        print(f"[serve] batched path: K={k}, 1 binary_mmm call/projection/tick, "
              f"{groups} K-groups over {ticks} ticks "
              f"(vs {slot_steps} slot-at-a-time steps, {slot_steps / groups:.1f}x fewer)")
    print(f"[serve] generated[0,:8] = {gen[0, :8].tolist()}")
    _finish_obs(tel, args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
