"""Batched serving driver: prefill a batch of prompts, then decode with
a fixed-capacity KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen 16

    # serve the binarized projections through the packed Pallas kernel:
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --engine packed

Uses the same decode_step the dry-run lowers for the ``decode_*``
cells, so serving on the production mesh is the identical program.
``--engine`` picks any backend registered in ``repro.core.engine``; a
non-reference engine implies ``quant="bnn"`` (the backends execute the
binarized ±1 projections — there is nothing for them to run in an fp
model).

``--group-size`` sets the WDM-style K-group width: every decode tick's
binarized projections go down as ONE ``binary_mmm`` call of
ceil(batch/K) stacked K-groups (0 = auto: a compiled mapping plan's WDM
capacity first, then native-MMM engines' wavelength count, else one
vmap'd group spanning the batch).

``--mapping-policy`` (with ``--engine tiled``) compiles the arch's
binarized projections into an explicit layer->tile MappingPlan
(``repro.mapping``), prints the placement summary + cost-model pricing,
and executes the ±1 matmuls per that placement:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --smoke --engine tiled --mapping-policy greedy
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def main(argv: list[str] | None = None) -> int:
    from repro.core import engine as engine_lib
    from repro.mapping import POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--engine",
        default="reference",
        # argparse-time validation: a typo'd backend fails here with the
        # registered names listed, not deep in engine construction
        choices=engine_lib.list_engines(),
        help="execution backend for binarized projections "
        "(registered in repro.core.engine)",
    )
    ap.add_argument(
        "--group-size",
        type=int,
        default=0,
        help="WDM K-group width for batched decode (0 = auto from the "
        "mapping plan / engine's preferred_group_size / batch)",
    )
    ap.add_argument(
        "--mapping-policy",
        default=None,
        choices=POLICIES,
        help="compile a layer->tile MappingPlan under this allocator "
        "policy and execute per it (requires --engine tiled)",
    )
    args = ap.parse_args(argv)
    if args.mapping_policy is not None and args.engine != "tiled":
        ap.error("--mapping-policy places weights for the plan-driven "
                 "'tiled' engine; pass --engine tiled with it")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.data import lm_batch
    from repro.models import encdec as encdec_lib
    from repro.models import lm as lm_lib

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    grouped = None
    plan = None
    if args.engine != "reference":
        engine_kw = {}
        if args.engine == "tiled":
            from repro.core import costmodel
            from repro.mapping import compile_plan, report

            policy = args.mapping_policy or cfg.mapping_policy
            cfg = dataclasses.replace(cfg, mapping_policy=policy)
            if cfg.is_encdec:
                ap.error("--engine tiled: mapping plans cover the "
                         "decoder-only LM projection stack")
            plan = compile_plan(cfg, policy=policy)
            cost = costmodel.price_plan(plan)
            print(report.summarize(plan))
            print(f"[serve] plan priced on {cost.design}: "
                  f"{cost.latency_s * 1e6:.2f} us/inf, "
                  f"{cost.energy_j * 1e6:.3f} uJ/inf")
            engine_kw = {"plan": plan, "policy": policy}
        eng = engine_lib.get_engine(args.engine, **engine_kw)
        cfg = dataclasses.replace(cfg, quant="bnn", bnn_engine=args.engine)
        print(f"[serve] engine={eng.name} ({eng.info.description})")
        if cfg.is_encdec:
            if args.group_size:
                ap.error("--group-size applies to the decoder-only serving path")
        else:
            k = engine_lib.resolve_group_size(eng, args.group_size, args.batch, plan=plan)
            grouped = engine_lib.GroupedEngine(eng, k)
            print(f"[serve] K-group batching: K={k}, "
                  f"{-(-args.batch // k)} group(s)/tick over batch={args.batch}, "
                  f"idle lanes/tick={-(-args.batch // k) * k - args.batch}")
    elif args.group_size:
        ap.error("--group-size requires a non-reference --engine")
    max_len = args.prompt_len + args.gen
    key = jax.random.key(args.seed)
    params = (
        encdec_lib.init_params(key, cfg) if cfg.is_encdec else lm_lib.init_params(key, cfg)
    )
    if grouped is not None:
        # crossbar programming phase: compile the binarized projections
        # into the backend's resident form once; the decode loop below
        # then streams only activations (PR 4 two-phase contract)
        t0 = time.time()
        params, n_programmed = lm_lib.program_weights(params, cfg, grouped)
        print(f"[serve] programmed {n_programmed} binarized projection "
              f"instance(s) into {args.engine} resident form "
              f"({(time.time() - t0) * 1e3:.1f} ms, one-time PCM write)")
    batch = lm_batch(cfg, args.batch, args.prompt_len, seed=args.seed)
    tokens = batch["tokens"]

    t0 = time.time()
    if cfg.is_encdec:
        logits, pre_caches = jax.jit(
            lambda p, s, t: encdec_lib.prefill(p, s, t, cfg)
        )(params, batch["src_embeds"], tokens)
        caches = encdec_lib.init_cache(cfg, args.batch, max_len, cfg.frontend_len)
        # copy prompt KV into the serving-capacity cache
        caches = dict(
            caches,
            cross_k=pre_caches["cross_k"],
            cross_v=pre_caches["cross_v"],
            self_k=caches["self_k"].at[:, :, : args.prompt_len].set(pre_caches["self_k"]),
            self_v=caches["self_v"].at[:, :, : args.prompt_len].set(pre_caches["self_v"]),
        )
        decode = jax.jit(lambda p, t, pos, c: encdec_lib.decode_step(p, t, pos, c, cfg))
    else:
        extra = batch.get("extra_embeds")
        logits, pre_caches = jax.jit(
            lambda p, t, e: lm_lib.prefill(p, t, cfg, e, engine=grouped)
        )(params, tokens, extra)
        caches = lm_lib.init_cache(cfg, args.batch, max_len)

        def graft(dst, src):
            if dst.ndim == 5 and dst.shape[2] >= src.shape[2]:  # attn (L,B,T,KV,D)
                return dst.at[:, :, : src.shape[2]].set(src.astype(dst.dtype))
            return src.astype(dst.dtype)  # ssm states carry over directly

        caches = jax.tree.map(graft, caches, pre_caches)
        decode = jax.jit(
            lambda p, t, pos, c: lm_lib.decode_step(p, t, pos, c, cfg, engine=grouped)
        )
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    # positions continue after the prompt (+ any frontend prefix)
    base = args.prompt_len + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    for i in range(args.gen - 1):
        logits, caches = decode(params, tok, jnp.asarray(base + i, jnp.int32), caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    gen = jnp.stack(out, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"quant={cfg.quant} engine={cfg.bnn_engine}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode {args.gen - 1} steps "
          f"{t_decode*1e3:.1f} ms ({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    if grouped is not None and args.gen > 1:
        ticks = args.gen - 1
        groups = ticks * -(-args.batch // grouped.k)
        slot_steps = ticks * args.batch
        print(f"[serve] batched path: K={grouped.k}, 1 binary_mmm call/projection/tick, "
              f"{groups} K-groups over {ticks} ticks "
              f"(vs {slot_steps} slot-at-a-time steps, {slot_steps / groups:.1f}x fewer)")
    print(f"[serve] generated[0,:8] = {gen[0, :8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
