"""Post-compile HLO analysis: trip-count-aware FLOPs, HBM bytes,
collective bytes, and the three-term roofline.

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts
a ``while`` body ONCE, ignoring the trip count — under scan-over-layers
(and scan-over-KV-chunks, scan-over-loss-chunks) that undercounts a
94-layer model by ~100x. The optimized HLO text annotates every while
with ``backend_config={"known_trip_count":{"n":...}}``, so this module
re-derives the counts with proper loop multipliers:

* **flops** — 2 * prod(output dims) * prod(contracting dims) for every
  ``dot``; recursion into called computations (fusions, while bodies,
  conditionals) carries the trip-count multiplier. Dots are >95% of
  model FLOPs; elementwise/transcendental ops are excluded (they are
  not MXU work).
* **bytes** — two estimates. ``bytes_raw``: operand+output bytes for
  every non-free instruction (CPU-fusion granularity — an upper bound:
  the CPU backend leaves hundreds of elementwise ops unfused that TPU
  XLA would fuse). ``bytes`` (used for the roofline memory term):
  TPU-fusion-aware — only *materialization points* count (dot operands/
  outputs, reduces, scatters, gathers, transposes/copies, dynamic
  (update-)slices, concats, collectives); elementwise / broadcast /
  convert / select chains and kLoop fusions wrapping only such ops are
  treated as fused epilogues with no incremental HBM traffic. This
  mirrors how TPU XLA actually schedules these graphs; both numbers are
  recorded so the bound is visible.
* **collectives** — operand bytes per all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, trip-multiplied;
  ring-model wire bytes (all-reduce counts 2x) reported alongside.

All sizes are PER-DEVICE (post-SPMD shapes are shard shapes). Roofline
terms (TPU v5e, per the brief):

    compute    = FLOPs_per_dev  / 197e12 FLOP/s
    memory     = bytes_per_dev  / 819e9  B/s
    collective = coll_bytes_per_dev / 50e9 B/s (per-ICI-link)

(equivalently global_quantity / (chips * rate)).
"""

from __future__ import annotations

import json
import re
from typing import Any

# v5e per-chip constants (per the brief)
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "custom-call", "domain", "opt-barrier",
}

# ops TPU XLA reliably fuses into producers/consumers (no extra HBM trip)
_FUSIBLE_OPS = _FREE_OPS | {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "sqrt", "rsqrt", "power", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "is-finite", "not", "and",
    "or", "xor", "compare", "select", "clamp", "convert", "broadcast",
    "reshape", "slice", "reduce-precision", "erf", "atan2", "cbrt",
    "cosine", "sine", "tan", "expm1", "log1p", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "popcnt",
    "count-leading-zeros", "rng-bit-generator", "rng-get-and-update-state",
    "stochastic-convert", "real", "imag", "complex", "map",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_ARRAY_TYPE_RE = re.compile(r"[a-z][\w]*\[[0-9,]*\](?:\{[^}]*\})?")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")


def _split_instr(line: str):
    """Robustly split ``%name = <type> op(...rest`` — tuple types may
    contain ``/*index=N*/`` comments and layout braces, so the type part
    is consumed with a matching-paren scan rather than a regex."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), line[m.end():]
    if rest.startswith("("):
        depth = 0
        idx = 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rem = rest[: idx + 1], rest[idx + 1:]
    else:
        m2 = _ARRAY_TYPE_RE.match(rest)
        if not m2:
            return None
        type_str, rem = m2.group(0), rest[m2.end():]
    m3 = _OP_RE.match(rem)
    if not m3:
        return None
    return name, type_str, m3.group(1), rem[m3.end():]


def _shape_list_bytes(type_str: str) -> int:
    return sum(
        _DTYPE_BYTES.get(dt, 4) * _dims_prod(dims)
        for dt, dims in _SHAPE_RE.findall(type_str)
    )


def _dims_prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


class _Instr:
    __slots__ = ("name", "type_str", "op", "rest", "out_bytes")

    def __init__(self, name: str, type_str: str, op: str, rest: str):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.rest = rest
        self.out_bytes = _shape_list_bytes(type_str)


def _parse_computations(hlo: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    entry_alias: str | None = None
    current: list[_Instr] | None = None
    for line in hlo.splitlines():
        if current is None:
            m = _COMP_RE.match(line.strip()) if "{" in line and "->" in line else None
            if m:
                name = m.group(1)
                current = comps.setdefault(name, [])
                if line.strip().startswith("ENTRY"):
                    entry_alias = name
            continue
        s = line.strip()
        if s == "}":
            current = None
            continue
        parts = _split_instr(line)
        if parts:
            current.append(_Instr(*parts))
    if entry_alias:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _operand_names(rest: str) -> list[str]:
    """Names inside the top-level call parens of an instruction line."""
    depth, args = 1, ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    return re.findall(r"%([\w.\-]+)", args)


def _trip_count(rest: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
    return int(m.group(1)) if m else 1


def _called_comps(rest: str) -> list[str]:
    names: list[str] = []
    for key in ("body=", "calls=", "true_computation=", "false_computation=",
                "branch_computations="):
        for m in re.finditer(re.escape(key) + r"\{?%?([\w.\-]+(?:, ?%[\w.\-]+)*)", rest):
            names += re.findall(r"[\w.\-]+", m.group(1).replace("%", ""))
    return names


def _dot_flops(instr: _Instr, sizes: dict[str, int], shapes: dict[str, str]) -> float:
    """2 * prod(out dims) * prod(lhs contracting dims)."""
    out_elems = sum(_dims_prod(d) for _, d in _SHAPE_RE.findall(instr.type_str))
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    ops = _operand_names(instr.rest)
    if not mc or not ops:
        return 0.0
    lhs_shape_str = shapes.get(ops[0], "")
    mm = _SHAPE_RE.search(lhs_shape_str)
    if not mm:
        return 0.0
    lhs_dims = [int(d) for d in mm.group(2).split(",") if d]
    contract = 1
    for idx in mc.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze_hlo_text(hlo: str) -> dict[str, Any]:
    comps = _parse_computations(hlo)
    # per-computation symbol tables (name -> bytes / type string)
    tables: dict[str, tuple[dict[str, int], dict[str, str]]] = {}
    for cname, instrs in comps.items():
        sizes = {i.name: i.out_bytes for i in instrs}
        shapes = {i.name: i.type_str for i in instrs}
        tables[cname] = (sizes, shapes)

    memo: dict[str, dict[str, float]] = {}
    per_kind: dict[str, dict[str, float]] = {
        k: {"count": 0.0, "operand_bytes": 0.0, "wire_bytes": 0.0} for k in _COLLECTIVES
    }

    elementwise_fusion: dict[str, bool] = {}
    _SLICE_OPS = ("dynamic-slice", "slice", "gather")

    def _is_elementwise_fusion(cname: str) -> bool:
        if cname not in elementwise_fusion:
            elementwise_fusion[cname] = cname in comps and all(
                i.op in _FUSIBLE_OPS or i.op in _SLICE_OPS for i in comps[cname]
            )
        return elementwise_fusion[cname]

    fusion_in_traffic: dict[str, int] = {}

    def _fusion_input_traffic(cname: str) -> int:
        """Input HBM bytes of a fused kernel: parameters consumed only
        through slice-like ops are charged at the SLICE size (a
        dynamic-slice of a stacked per-layer buffer reads one layer's
        slice, not the whole stack — charging the full operand was a
        ~5x over-count on scanned models)."""
        if cname in fusion_in_traffic:
            return fusion_in_traffic[cname]
        il = comps.get(cname, [])
        uses: dict[str, list[_Instr]] = {}
        for u in il:
            for n in _operand_names(u.rest):
                uses.setdefault(n, []).append(u)
        t = 0
        for x in il:
            if x.op != "parameter":
                continue
            users = uses.get(x.name, [])
            if users and all(u.op in _SLICE_OPS for u in users):
                t += sum(u.out_bytes for u in users)
            else:
                t += x.out_bytes
        fusion_in_traffic[cname] = t
        return t

    def _instr_traffic(i: _Instr, sizes: dict[str, int]) -> int:
        """Slice-aware HBM traffic of one instruction."""
        if i.op in _SLICE_OPS:
            return 2 * i.out_bytes  # read slice + write result
        if i.op == "dynamic-update-slice":
            ops = _operand_names(i.rest)
            upd = sizes.get(ops[1], 0) if len(ops) > 1 else 0
            return 2 * upd  # in-place: read update + write window
        if i.op == "fusion":
            called = _called_comps(i.rest)
            inp = _fusion_input_traffic(called[0]) if called else 0
            return inp + i.out_bytes
        return i.out_bytes + sum(sizes.get(n, 0) for n in _operand_names(i.rest))

    def walk(cname: str, mult: float) -> dict[str, float]:
        # flops/bytes are multiplier-independent per computation; collect
        # collectives with the live multiplier (can't memo those), so:
        # memo stores per-execution totals and a (kind, bytes) coll list.
        if cname not in comps:
            return {"flops": 0.0, "raw": 0.0, "fused": 0.0}
        if cname in memo:
            acc = memo[cname]
        else:
            sizes, shapes = tables[cname]
            acc = {"flops": 0.0, "raw": 0.0, "fused": 0.0, "colls": [], "children": []}
            for i in comps[cname]:
                base = i.op.replace("-start", "")
                io_bytes = _instr_traffic(i, sizes)
                if base in _COLLECTIVES:
                    ob = sum(sizes.get(n, 0) for n in _operand_names(i.rest))
                    acc["colls"].append((base, float(ob)))
                if i.op == "while":
                    tc = _trip_count(i.rest)
                    for child in _called_comps(i.rest):
                        if "cond" not in child:
                            acc["children"].append((child, float(tc), "full"))
                elif i.op == "fusion":
                    acc["raw"] += io_bytes
                    if not _is_elementwise_fusion(_called_comps(i.rest)[0]
                                                  if _called_comps(i.rest) else ""):
                        acc["fused"] += io_bytes
                    for child in _called_comps(i.rest):
                        acc["children"].append((child, 1.0, "flops_only"))
                elif i.op in ("call", "conditional"):
                    for child in _called_comps(i.rest):
                        acc["children"].append((child, 1.0, "full"))
                elif i.op == "dot":
                    acc["flops"] += _dot_flops(i, sizes, shapes)
                    acc["raw"] += io_bytes
                    acc["fused"] += io_bytes
                elif i.op in _FUSIBLE_OPS:
                    if i.op not in _FREE_OPS:
                        acc["raw"] += io_bytes
                else:
                    # materialization points: reduce, scatter, copy,
                    # transpose, concatenate, (dynamic-)slice/DUS,
                    # sort, convolution, pad, ...
                    acc["raw"] += io_bytes
                    acc["fused"] += io_bytes
            memo[cname] = acc

        total = {"flops": acc["flops"], "raw": acc["raw"], "fused": acc["fused"]}
        for kind, ob in acc["colls"]:
            wire = ob * (2.0 if kind == "all-reduce" else 1.0)
            per_kind[kind]["count"] += mult
            per_kind[kind]["operand_bytes"] += ob * mult
            per_kind[kind]["wire_bytes"] += wire * mult
        for child, cm, mode in acc["children"]:
            if mode == "flops_only":
                total["flops"] += walk_flops_only(child, mult * cm)
            else:
                sub = walk(child, mult * cm)
                total["flops"] += sub["flops"] * cm
                total["raw"] += sub["raw"] * cm
                total["fused"] += sub["fused"] * cm
        return total

    flops_memo: dict[str, float] = {}

    def walk_flops_only(cname: str, mult: float) -> float:
        if cname not in comps:
            return 0.0
        if cname in flops_memo:
            return flops_memo[cname]
        sizes, shapes = tables[cname]
        f = 0.0
        for i in comps[cname]:
            if i.op == "dot":
                f += _dot_flops(i, sizes, shapes)
            elif i.op in ("fusion", "call", "while", "conditional"):
                tc = _trip_count(i.rest) if i.op == "while" else 1
                for child in _called_comps(i.rest):
                    if i.op == "while" and "cond" in child:
                        continue
                    f += walk_flops_only(child, 1.0) * tc
        flops_memo[cname] = f
        return f

    top = walk("__entry__", 1.0)
    total_ob = sum(v["operand_bytes"] for v in per_kind.values())
    total_wire = sum(v["wire_bytes"] for v in per_kind.values())
    return {
        "flops_per_dev": top["flops"],
        "bytes_per_dev": top["fused"],
        "bytes_raw_per_dev": top["raw"],
        "coll_per_kind": per_kind,
        "coll_operand_bytes_per_dev": total_ob,
        "coll_wire_bytes_per_dev": total_wire,
    }


def roofline_terms(
    flops_per_dev: float, bytes_per_dev: float, coll_bytes_per_dev: float
) -> dict[str, Any]:
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = coll_bytes_per_dev / ICI_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }


def analyze_compiled(compiled, n_chips: int) -> dict[str, Any]:
    """Full per-cell record: trip-aware cost, memory, collectives, roofline."""
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):  # older jax: one dict per program
        xla_cost = xla_cost[0] if xla_cost else {}
    text = compiled.as_text()
    acc = analyze_hlo_text(text)
    mem = compiled.memory_analysis()
    terms = roofline_terms(
        acc["flops_per_dev"], acc["bytes_per_dev"], acc["coll_operand_bytes_per_dev"]
    )
    return {
        # global quantities (= per-dev * chips; shapes in HLO are shards)
        "flops": acc["flops_per_dev"] * n_chips,
        "bytes_accessed": acc["bytes_per_dev"] * n_chips,
        "flops_per_dev": acc["flops_per_dev"],
        "bytes_per_dev": acc["bytes_per_dev"],
        "bytes_raw_per_dev": acc["bytes_raw_per_dev"],
        "xla_cost_flops_tripblind": float(xla_cost.get("flops", 0.0)),
        "collectives": {
            "per_kind": acc["coll_per_kind"],
            "operand_bytes": acc["coll_operand_bytes_per_dev"],
            "wire_bytes": acc["coll_wire_bytes_per_dev"],
        },
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_per_device_bytes": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "roofline": terms,
    }
