"""Launch layer: production meshes, the multi-pod dry-run, and the
train/serve drivers."""
