"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt /tmp/run1

On real hardware the same entry point runs the full config on the
production mesh (``--mesh single|multi``); on this CPU container use
``--smoke`` (reduced config, 1 device) — the code path (data -> step ->
checkpoint -> resume) is identical.

Latency-hiding flags: on TPU, set
    XLA_FLAGS="--xla_tpu_enable_latency_hiding_scheduler=true \
               --xla_tpu_megacore_fusion_allow_ags=true"
(collective/compute overlap); they are set here when a TPU backend is
detected so the launcher is copy-paste deployable.
"""

from __future__ import annotations

import argparse
import os


def _tpu_flags() -> None:
    if "libtpu" in os.environ.get("TPU_LIBRARY_PATH", "") or os.environ.get("TPU_NAME"):
        os.environ.setdefault(
            "XLA_FLAGS",
            "--xla_tpu_enable_latency_hiding_scheduler=true",
        )


_tpu_flags()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--quant", default="none", choices=["none", "bnn"],
                    help="bnn = the paper's technique on all hidden projections")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config, get_smoke_config
    from repro.train import TrainLoopConfig, train

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.quant != "none":
        cfg = dataclasses.replace(cfg, quant=args.quant)
    loop = TrainLoopConfig(
        total_steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        peak_lr=args.lr,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt,
        seed=args.seed,
    )
    out = train(cfg, loop)
    print(
        f"[train] arch={cfg.name} quant={cfg.quant} final_step={out['final_step']} "
        f"loss[first->last]={out['losses'][0]:.4f}->{out['losses'][-1]:.4f} "
        f"steps/s={out.get('steps_per_s', 0):.2f}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
