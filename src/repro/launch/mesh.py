"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before the first jax initialization.

Topology mapping (TPU v5e pods): ``model`` is the innermost axis (ICI-
adjacent chips — TP/EP collectives ride the fastest links), ``data``
spans the pod (FSDP/DP all-reduces), and ``pod`` crosses the DCN (only
pure-DP gradient reductions — optionally int8-compressed — cross pods).
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; Auto is the default either way
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}

except ImportError:  # older jax: make_mesh has no axis_types parameter

    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before the first jax import (see launch/dryrun.py)"
        )
    return jax.make_mesh(shape, axes, devices=devices, **_axis_kwargs(len(axes)))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n], **_axis_kwargs(len(axes))
    )
