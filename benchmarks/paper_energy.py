"""Fig. 8 reproduction: normalized energy vs Baseline-ePCM.

Paper claims:
  * TacitMap-ePCM ~5.35x MORE energy (ADCs vs sense amps)
  * EinsteinBarrier ~1.56x LESS than Baseline-ePCM
    (~11.94x less than TacitMap-ePCM)
"""

from __future__ import annotations

import statistics

from repro.core import costmodel as cm
from repro.core.networks import NETWORKS


def run() -> dict:
    rows = []
    for name, net in NETWORKS.items():
        r = cm.evaluate_all(net)
        base = r["Baseline-ePCM"]["energy_j"]
        rows.append({
            "network": name,
            "baseline_j": base,
            "tm_ratio": r["TacitMap-ePCM"]["energy_j"] / base,     # >1 = worse
            "eb_ratio": r["EinsteinBarrier"]["energy_j"] / base,   # <1 = better
        })
    tm = [r["tm_ratio"] for r in rows]
    eb = [r["eb_ratio"] for r in rows]
    summary = {
        "tm_avg_ratio": statistics.mean(tm),
        "eb_avg_ratio": statistics.mean(eb),
        "tm_over_eb": statistics.mean(t / e for t, e in zip(tm, eb)),
    }
    checks = {
        "tm ~5.35x worse (band 3.5-7.5)": 3.5 <= summary["tm_avg_ratio"] <= 7.5,
        "eb ~1.56x better (band 1.2-2.2)": 1.2 <= 1 / summary["eb_avg_ratio"] <= 2.2,
        "eb ~11.94x better than tm (band 7-18)": 7 <= summary["tm_over_eb"] <= 18,
    }
    return {"rows": rows, "summary": summary, "checks": checks}


def main() -> int:
    out = run()
    print("\n== Fig. 8: energy normalized to Baseline-ePCM ==")
    print(f"{'network':8s} {'TacitMap-ePCM':>14s} {'EinsteinBarrier':>16s}")
    for r in out["rows"]:
        print(f"{r['network']:8s} {r['tm_ratio']:13.2f}x {r['eb_ratio']:15.3f}x")
    s = out["summary"]
    print(f"\nTacitMap avg {s['tm_avg_ratio']:.2f}x worse (paper ~5.35x)")
    print(f"EinsteinBarrier avg {1/s['eb_avg_ratio']:.2f}x better (paper ~1.56x); "
          f"{s['tm_over_eb']:.1f}x better than TacitMap (paper ~11.94x)")
    ok = True
    for name, passed in out["checks"].items():
        print(f"  [{'PASS' if passed else 'FAIL'}] {name}")
        ok &= passed
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
