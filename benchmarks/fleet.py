"""Fleet-serving gate — N replicas behind the prefix-affinity router
(``BENCH_fleet.json``).

The PR 10 fleet layer (``repro.fleet``) must be semantically invisible
and measurably useful. This section drives both claims the way the
scheduler section drives PR 7:

* **Gate (a) — routed == solo**: a shared-prefix request mix through
  every routing policy x replica count x engine; every FINISHED
  generation must be byte-identical to its solo single-slot reference.
  Routing decides *where* a request runs and *how much* prefix it
  skips — never *what* it generates.
* **Gate (b) — prefix routing earns its index**: on a workload where
  half the prompts share a block-aligned prefix, the ``prefix`` policy
  must score a strictly higher hit rate than ``round-robin`` (which
  must score zero) and prefill strictly fewer prompt tokens — the
  grafted tokens are prefill work the fleet measurably skipped.
* **Gate (c) — failover drains clean**: a two-replica fleet where
  replica 0 injects a mid-serve tile failure with zero spare tiles
  (tolerance out of moves -> degrade). The pool must fail the lost
  requests over to the healthy replica and drain with ZERO fleet-wide
  FAILED requests, still solo-exact.
* **Modeled**: ``costmodel.fleet_price`` across replica counts —
  tiles/write energy linear in N, wall-clock programming flat, fleet
  throughput linear in N (replication is an area trade on
  program-once CIM).

    PYTHONPATH=src python -m benchmarks.fleet [--smoke]
"""

from __future__ import annotations

import dataclasses

TICK_CAP = 2_000   # deadlock gate: no smoke run needs remotely this many
BLOCK = 4          # router hash-block width (smoke prompts are short)


def _bench_model():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _shared_prefix_prompts(n, *, shared_len=2 * BLOCK):
    """Half the prompts share one block-aligned prefix with distinct
    tails; the rest are unrelated — the prefix policy has something to
    find and round-robin has nothing to lose."""
    import numpy as np

    rng = np.random.default_rng(0)
    shared = rng.integers(1, 1000, (shared_len,), dtype=np.int32)
    prompts = []
    for i in range(n):
        if i % 2 == 0:
            tail = rng.integers(1, 1000, (2 + i % 3,), dtype=np.int32)
            prompts.append(np.concatenate([shared, tail]))
        else:
            prompts.append(rng.integers(1, 1000, (5,), dtype=np.int32))
    return prompts


def _solo_refs(cm, prompts, gen, max_len):
    """Each request alone in a 1-slot pool: the byte-exactness oracle."""
    from repro.serving import Request

    refs = {}
    for i, p in enumerate(prompts):
        se = cm.serve(max_batch=1, max_len=max_len)
        st = se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        se.drain()
        refs[i] = tuple(st.generated)
    return refs


def _drive(fleet, prompts, refs, *, gen):
    """Staggered arrival (one submit per fleet tick, so the prefix
    library is live for later arrivals), then drain."""
    from repro.serving import Request, RequestStatus

    states = []
    for i, p in enumerate(prompts):
        states.append(fleet.submit(Request(rid=i, prompt=p,
                                           max_new_tokens=gen)))
        fleet.step()
    fleet.drain(max_ticks=TICK_CAP)
    exact = all(
        st.status is RequestStatus.FINISHED
        and tuple(st.generated) == refs[st.request.rid]
        for st in states
    )
    return states, exact


def routed_vs_solo(engines, replica_counts, policies, *, n_requests, gen):
    """Gate (a) + (b): the policy x replicas x engine grid, solo-exact
    everywhere, with the prefix policy's hit/graft ledger per row."""
    from repro import compiler as compiler_lib
    from repro.fleet import FleetEngine, Replica

    cfg, params = _bench_model()
    prompts = _shared_prefix_prompts(n_requests)
    max_len = max(len(p) for p in prompts) + gen + 2

    rows = []
    for engine in engines:
        cm = compiler_lib.compile(
            cfg, params, compiler_lib.HardwareTarget(engine=engine)
        )
        refs = _solo_refs(cm, prompts, gen, max_len)
        for n in replica_counts:
            for policy in policies:
                # clean replicas can share one CompiledModel: serving
                # state lives on each ServingEngine, and sharing the jit
                # caches keeps the grid affordable
                fleet = FleetEngine(
                    [Replica(r, cm, max_batch=2, max_len=max_len)
                     for r in range(n)],
                    routing=policy, block_size=BLOCK,
                )
                states, exact = _drive(fleet, prompts, refs, gen=gen)
                s = fleet.stats()
                rows.append({
                    "engine": engine,
                    "replicas": n,
                    "policy": policy,
                    "exact": exact,
                    "finished": s.finished,
                    "failed": s.failed,
                    "prefix_hits": s.prefix_hits,
                    "hit_rate": s.prefix_hit_rate,
                    "grafted_tokens": s.grafted_tokens,
                    "prefill_tokens": s.prefill_tokens,
                    "ticks": s.ticks,
                })
    return rows


def failover_drain(*, n_requests, gen=16, fail_after=2):
    """Gate (c): replica 0 (fault-injected, zero spares) degrades
    mid-drain; the fleet must finish everything on replica 1, exact.

    ``gen`` stays long enough that the health monitor's sampled sweep
    (every ``check_interval`` ticks) fires AFTER the planted failure
    while requests are still in flight — a too-short run would finish
    before detection and prove nothing."""
    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.faults import FaultModel
    from repro.fleet import FleetEngine, Replica

    cfg, params = _bench_model()
    prompts = _shared_prefix_prompts(n_requests)
    max_len = max(len(p) for p in prompts) + gen + 2
    clean = HardwareTarget(
        engine="tiled", mapping_policy="tacitmap", spare_tiles=0
    )
    cm_ref = compiler_lib.compile(cfg, params, clean)
    refs = _solo_refs(cm_ref, prompts, gen, max_len)

    cm0 = compiler_lib.compile(
        cfg, params, dataclasses.replace(clean, fault_model=FaultModel())
    )
    r0 = Replica(0, cm0, max_batch=n_requests, max_len=max_len)
    r1 = Replica(1, cm_ref, max_batch=n_requests, max_len=max_len)
    fleet = FleetEngine([r0, r1], routing="least-loaded")

    from repro.serving import Request, RequestStatus

    states = [
        fleet.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        for i, p in enumerate(prompts)
    ]
    resolved = sorted({
        t for pw in cm0._fault_artifacts()
        for *_, t in cm0.engine._placement_blocks(pw.m, pw.n)
    })
    ticks = 0
    while not fleet.idle() and ticks <= TICK_CAP:
        if ticks == fail_after:
            cm0.engine.fail_tile(resolved[0])
            cm0.refresh_faults()
            r0.serving._rebind()
        fleet.step()
        ticks += 1

    s = fleet.stats()
    exact = all(
        st.status is RequestStatus.FINISHED
        and tuple(st.generated) == refs[st.request.rid]
        for st in states
    )
    return {
        "victim_tile": resolved[0],
        "failed_at_tick": fail_after,
        "ticks": ticks,
        "degraded_replica": 0,
        "degraded_reason": r0.degraded_reason,
        "failovers": s.failovers,
        "salvaged": s.salvaged,
        "finished": s.finished,
        "failed": s.failed,
        "healthy_replicas": s.healthy_replicas,
        "bit_exact_vs_solo": exact,
        "drained": ticks <= TICK_CAP,
    }


def modeled_fleet_price(replica_counts):
    """Replication pricing through the costmodel seam."""
    from repro import compiler as compiler_lib
    from repro.core import costmodel

    cfg, params = _bench_model()
    cm = compiler_lib.compile(
        cfg, params,
        compiler_lib.HardwareTarget(engine="tiled", mapping_policy="tacitmap"),
    )
    base = cm.price(n_active=4)
    return [costmodel.fleet_price(base, n, n_active=4)
            for n in replica_counts]


def run(smoke: bool = False) -> tuple[int, dict]:
    from repro.fleet import ROUTING_POLICIES

    if smoke:
        engines = ("reference", "packed")
        replica_counts = (2,)
        sizes = dict(n_requests=6, gen=4)
        priced = (1, 2, 4)
    else:
        engines = ("reference", "wdm", "packed", "tiled")
        replica_counts = (2, 3)
        sizes = dict(n_requests=10, gen=6)
        priced = (1, 2, 4, 8)

    rows = routed_vs_solo(engines, replica_counts, ROUTING_POLICIES, **sizes)

    print("\n== fleet routed-vs-solo grid (smoke LM, shared-prefix "
          f"workload, {sizes['n_requests']} requests, gen={sizes['gen']}) ==")
    print(f"{'engine':>10s} {'N':>3s} {'policy':>13s} {'fin':>4s} "
          f"{'hits':>5s} {'rate':>6s} {'grafted':>8s} {'prefilled':>9s} "
          f"{'exact':>6s}")
    for r in rows:
        print(f"{r['engine']:>10s} {r['replicas']:3d} {r['policy']:>13s} "
              f"{r['finished']:4d} {r['prefix_hits']:5d} "
              f"{r['hit_rate']:6.0%} {r['grafted_tokens']:8d} "
              f"{r['prefill_tokens']:9d} {str(r['exact']):>6s}")

    exact = all(r["exact"] for r in rows)
    # gate (b), per engine x replica count: prefix must strictly beat
    # round-robin on hit rate AND on prompt tokens actually prefilled
    prefix_wins = True
    for engine in engines:
        for n in replica_counts:
            by = {
                r["policy"]: r for r in rows
                if r["engine"] == engine and r["replicas"] == n
            }
            pfx, rr = by["prefix"], by["round-robin"]
            if not (pfx["hit_rate"] > rr["hit_rate"]
                    and pfx["prefill_tokens"] < rr["prefill_tokens"]):
                prefix_wins = False
    print(f"\nrouted == solo (every policy x replicas x engine): {exact}")
    print("prefix beats round-robin (hit rate strictly higher, prefill "
          f"tokens strictly lower) on every grid point: {prefix_wins}")

    fo = failover_drain(n_requests=sizes["n_requests"])
    print("\n== mid-serve replica degrade -> failover ==")
    print(f"tile {fo['victim_tile']} failed at fleet tick "
          f"{fo['failed_at_tick']}; replica 0 degraded "
          f"({str(fo['degraded_reason'])[:60]}...)")
    print(f"failovers={fo['failovers']} (salvaged={fo['salvaged']}) "
          f"finished={fo['finished']} failed={fo['failed']} "
          f"healthy={fo['healthy_replicas']}/2 exact="
          f"{fo['bit_exact_vs_solo']} drained={fo['drained']}")
    failover_ok = (
        fo["failed"] == 0 and fo["failovers"] > 0
        and fo["bit_exact_vs_solo"] and fo["drained"]
        and fo["healthy_replicas"] == 1
    )
    print(f"failover drained with zero fleet-wide FAILED, solo-exact: "
          f"{failover_ok}")

    prices = modeled_fleet_price(priced)
    print("\n== modeled fleet pricing (tacitmap plan) ==")
    print(f"{'N':>3s} {'tiles':>6s} {'prog_uJ':>8s} {'prog_us':>8s} "
          f"{'tick_pJ':>9s} {'fleet tok/s':>12s}")
    for p in prices:
        print(f"{p.n_replicas:3d} {p.tiles_total:6d} "
              f"{p.programming_uj:8.2f} {p.programming_us:8.1f} "
              f"{p.tick_energy_pj:9.1f} {p.fleet_tokens_per_s:12.2e}")
    base = prices[0]
    # replication is linear in area/energy, flat in wall-clock
    scaling_ok = all(
        p.tiles_total == p.n_replicas * base.tiles_total
        and abs(p.programming_uj - p.n_replicas * base.programming_uj) < 1e-9
        and p.programming_us == base.programming_us
        and abs(p.fleet_tokens_per_s
                - p.n_replicas * base.fleet_tokens_per_s) < 1e-3
        for p in prices
    )
    print(f"pricing linear in N (tiles, write energy, throughput) with "
          f"flat wall-clock programming: {scaling_ok}")

    rc = 0 if (exact and prefix_wins and failover_ok and scaling_ok) else 1
    payload = {
        "routed": rows,
        "failover": fo,
        "modeled": [
            {k: v for k, v in dataclasses.asdict(p).items() if k != "base"}
            for p in prices
        ],
        "bit_exact_vs_solo": exact,
        "prefix_beats_round_robin": prefix_wins,
        "failover_clean": failover_ok,
        "pricing_linear": scaling_ok,
    }
    return rc, payload


def main(smoke: bool = False) -> int:
    return run(smoke=smoke)[0]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    raise SystemExit(main(smoke=ap.parse_args().smoke))
