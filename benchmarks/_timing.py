"""Shared interleaved paired-timing helpers for the benchmark drivers.

``serving_latency``, ``kernels_fused`` and ``scheduler`` each grew a
private copy of the same measurement loop; PR 8 hoists the methodology
here so every section times the same way:

* **Interleave the pair** (a, b, a, b, ...): the structural delta under
  test is the per-call graph difference, and interleaving cancels
  machine drift that sequential phases would alias into the comparison.
  Each (a, b) pair is adjacent in time, so the per-pair difference is
  the robust statistic — a noise spike only perturbs one pair.
* **Warm up first**: the first calls pay compilation/admission/prefill;
  they are excluded from every timed window.
* **Fence the dispatch**: JAX dispatch is async — without
  ``block_until_ready`` a "timing" measures the enqueue, not the work.
  ``timed(fn, fence=True)`` drains the call's returned arrays before
  stopping the clock (serving-tick timing leaves it off: the engine's
  token-emission host sync is the natural fence, and double-fencing
  would add a sync the served path never pays).
* **Pool, then median**: gates aggregate the per-pair deltas across a
  sweep's rows and take one median — ``pooled_median`` — rather than
  averaging medians of unequal windows.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable


def timed(fn: Callable[[], object], *, fence: bool = False) -> float:
    """Wall seconds of one ``fn()`` call. ``fence=True`` drains the
    returned JAX arrays with ``block_until_ready`` before stopping the
    clock (else async dispatch makes the number an enqueue time)."""
    t0 = time.perf_counter()
    out = fn()
    if fence:
        import jax

        jax.block_until_ready(out)
    return time.perf_counter() - t0


class Stopwatch:
    """Wall-clock a block::

        with Stopwatch() as sw:
            ...work...
        print(sw.seconds)
    """

    seconds: float | None = None

    def __enter__(self) -> "Stopwatch":
        self.seconds = None
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.seconds = time.perf_counter() - self._t0
        return False


def paired_times(
    a: Callable[[], object],
    b: Callable[[], object],
    *,
    reps: int,
    warmup: int = 1,
    fence: bool = True,
) -> tuple[list[float], list[float]]:
    """Interleaved (a, b) call pairs -> (a seconds, b seconds), each of
    length ``reps``; ``warmup`` unrecorded pairs run first."""
    for _ in range(warmup):
        timed(a, fence=fence)
        timed(b, fence=fence)
    ta, tb = [], []
    for _ in range(reps):
        ta.append(timed(a, fence=fence))
        tb.append(timed(b, fence=fence))
    return ta, tb


def interleaved_ticks(servers: dict, *, ticks: int) -> dict[str, list[float]]:
    """One timed ``step()`` per server per round, rounds interleaved
    across the (already warmed-up) servers — the serving-tick analogue
    of :func:`paired_times`. Returns {label: [tick seconds]}."""
    times: dict[str, list[float]] = {label: [] for label in servers}
    for _ in range(ticks):
        for label, se in servers.items():
            times[label].append(timed(se.step))
    return times


def paired_deltas(
    base: list[float], other: list[float], scale: float = 1.0
) -> list[float]:
    """Per-pair (other - base) differences, optionally scaled (1e3 for
    ms, 1e6 for us). Positive = ``base`` faster."""
    return [(o - b) * scale for b, o in zip(base, other)]


def pooled_median(deltas: list[float]) -> float:
    """The gate statistic: one median over all pooled per-pair deltas."""
    return statistics.median(deltas) if deltas else 0.0
