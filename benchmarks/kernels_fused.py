"""Fused decode-tick kernel gate — wall time + bit-exactness.

Two layers of evidence for the PR-6 fused path, both against the same
unfused baseline (binarize -> ``pack_bits`` -> Hamming kernel -> affine
correction -> rescale as separate XLA ops):

  1. kernel-level: ``ops.fused_bnn_matmul`` vs the unfused op chain at
     decode-shaped operands, interleaved paired timing + exactness
     against the raw ``dense`` reference math (f32 einsum);
  2. serving-level: ``serving_latency.fused_sweep`` decode ticks — the
     fused target vs the same target with ``fused=False``, decode
     streams required bit-identical.

Gate: every comparison bit-exact AND the pooled median paired delta
(unfused - fused) strictly positive at both levels. Interpret mode on
CPU CI is acceptable per the acceptance criteria; the shapes are wide
enough (512/1024 features) that the structural difference dominates the
interpreter's fixed per-launch floor.
"""

from __future__ import annotations

import statistics

from benchmarks import _timing

# decode-shaped operands: (rows, m) x (m, n) as served by a 512/1024
# model — qkv (8 heads + 2 kv of head-dim 64, concatenated), o-proj,
# and the two FF projections.
SHAPES = (
    ("qkv", 4, 512, 768),
    ("o_proj", 4, 512, 512),
    ("ff_in", 4, 512, 1024),
    ("ff_out", 4, 1024, 512),
)


def kernel_rows(*, reps):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import bnn
    from repro.core.engine import PackedEngine

    eng = PackedEngine()
    rng = np.random.default_rng(0)
    rows = []
    for name, b, m, n in SHAPES:
        x = jnp.asarray(rng.normal(size=(b, m)), jnp.bfloat16)
        w = bnn.binarize_ste(jnp.asarray(rng.normal(size=(m, n)), jnp.float32))
        pw = eng.prepare(w)
        alpha = jnp.asarray(rng.uniform(0.5, 2.0, size=(n,)), jnp.float32)

        fused = jax.jit(lambda x, pw=pw, alpha=alpha: eng.fused_dense(x, pw, alpha))

        def unfused(x, pw=pw, alpha=alpha):
            beta = jnp.mean(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True)
            xb = bnn.binarize_ste(x.astype(jnp.float32))
            return eng.binary_vmm(xb, pw).astype(jnp.float32) * (alpha * beta)

        unfused = jax.jit(unfused)

        # oracle: the dense() reference math, no kernels involved
        beta = jnp.mean(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True)
        xb = bnn.binarize_ste(x.astype(jnp.float32))
        ref = jnp.einsum("bk,kn->bn", xb, w).astype(jnp.float32) * (alpha * beta)

        exact = bool(jnp.array_equal(ref, fused(x))) and bool(
            jnp.array_equal(ref, unfused(x))
        )
        # interleaved fenced pairs — the shared _timing methodology
        tf, tu = _timing.paired_times(
            lambda: fused(x), lambda: unfused(x), reps=reps
        )
        deltas = _timing.paired_deltas(tf, tu, scale=1e6)
        rows.append({
            "shape": name,
            "dims": f"({b},{m})x({m},{n})",
            "fused_us": statistics.median(tf) * 1e6,
            "unfused_us": statistics.median(tu) * 1e6,
            "paired_deltas_us": deltas,
            "paired_delta_us": _timing.pooled_median(deltas),
            "exact": exact,
        })
    return rows


def run(smoke: bool = False) -> tuple[int, dict]:
    from benchmarks import serving_latency

    reps = 50 if smoke else 200
    sizes = (dict(max_batch=4, prompt_len=5, warmup=3, ticks=20) if smoke
             else dict(max_batch=4, prompt_len=6, warmup=3, ticks=32))

    rows = kernel_rows(reps=reps)
    print("\n== fused BitLinear kernel vs unfused op chain "
          f"(median of {reps} interleaved call pairs) ==")
    print(f"{'shape':>8s} {'dims':>18s} {'fused_us':>9s} {'unfused_us':>11s} "
          f"{'pair_d_us':>10s} {'exact':>6s}")
    for r in rows:
        print(f"{r['shape']:>8s} {r['dims']:>18s} {r['fused_us']:9.1f} "
              f"{r['unfused_us']:11.1f} {r['paired_delta_us']:10.1f} "
              f"{str(r['exact']):>6s}")

    kernel_deltas = [d for r in rows for d in r["paired_deltas_us"]]
    kernel_faster = _timing.pooled_median(kernel_deltas) > 0
    kernel_exact = all(r["exact"] for r in rows)
    print(f"kernel pooled median delta (unfused - fused): "
          f"{_timing.pooled_median(kernel_deltas):+.1f}us; "
          f"strictly faster: {kernel_faster}; bit-exact vs reference: "
          f"{kernel_exact}")

    tick_rows = serving_latency.fused_sweep((1, 4), **sizes)
    print("\n== packed decode tick: fused vs unfused "
          f"(median of {sizes['ticks']} interleaved tick pairs) ==")
    print(f"{'K':>3s} {'fused_ms':>9s} {'unfused_ms':>11s} {'pair_d_ms':>10s} "
          f"{'exact':>6s}")
    for r in tick_rows:
        print(f"{r['k']:3d} {r['tick_ms_fused']:9.2f} "
              f"{r['tick_ms_unfused']:11.2f} {r['paired_delta_ms']:10.3f} "
              f"{str(r['exact']):>6s}")
    tick_deltas = [d for r in tick_rows for d in r["paired_deltas_ms"]]
    tick_faster = _timing.pooled_median(tick_deltas) > 0
    tick_exact = all(r["exact"] for r in tick_rows)
    print(f"tick pooled median delta (unfused - fused): "
          f"{_timing.pooled_median(tick_deltas):+.3f}ms; strictly faster: "
          f"{tick_faster}; decode streams bit-identical: {tick_exact}")

    rc = 0 if (kernel_exact and tick_exact and kernel_faster and tick_faster) else 1
    payload = {
        "kernel": rows,
        "ticks": tick_rows,
        "kernel_strictly_faster": kernel_faster,
        "kernel_bit_exact": kernel_exact,
        "tick_strictly_faster": tick_faster,
        "tick_bit_exact": tick_exact,
    }
    return rc, payload


def main(smoke: bool = False) -> int:
    return run(smoke=smoke)[0]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    args = ap.parse_args()
    raise SystemExit(main(smoke=args.smoke))
