"""Roofline report (deliverable g): reads the dry-run JSON records and
emits the per-(arch x shape x mesh) three-term table as markdown.

    PYTHONPATH=src python -m benchmarks.roofline [--runs runs/dryrun] [--md]

Terms (TPU v5e): compute = FLOPs/(chips*197e12); memory =
bytes/(chips*819e9); collective = coll_bytes/(chips*50e9). The perf
iteration log lives in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(runs_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(runs_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {'mp' if r['multi_pod'] else 'sp'} | "
                f"skip | — | — | — | — | — | {r['reason'][:40]} |")
    if r["status"] == "error":
        return (f"| {r['arch']} | {r['shape']} | {'mp' if r['multi_pod'] else 'sp'} | "
                f"ERROR | — | — | — | — | — | {r['error'][:60]} |")
    t = r["roofline"]
    mem_gib = r["memory"]["peak_per_device_bytes"] / 2**30
    ratio = r.get("useful_flop_ratio")
    ratio_s = f"{ratio:.2f}" if ratio else "—"
    name = r["arch"] + (f" ({r['quant']})" if r.get("quant", "none") != "none" else "")
    return (
        f"| {name} | {r['shape']} | {'mp' if r['multi_pod'] else 'sp'} | ok "
        f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} | {t['collective_s']:.3e} "
        f"| **{t['dominant'][:4]}** | {mem_gib:.2f} | {ratio_s} |"
    )


HEADER = (
    "| arch | shape | mesh | st | compute (s) | memory (s) | collective (s) "
    "| dom | GiB/dev | 6ND/HLO |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", default="runs/dryrun")
    args = ap.parse_args()
    recs = load(args.runs)
    if not recs:
        print(f"[roofline] no records under {args.runs} — run "
              "`python -m repro.launch.dryrun` first")
        return 0
    print("\n== Roofline (from compiled dry-run artifacts) ==")
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    ok = [r for r in recs if r["status"] == "ok"]
    err = [r for r in recs if r["status"] == "error"]
    print(f"\n{len(ok)} ok, {len(err)} errors, "
          f"{len([r for r in recs if r['status'] == 'skipped'])} documented skips")
    if ok:
        doms = {}
        for r in ok:
            doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
        print(f"dominant-term histogram: {doms}")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
